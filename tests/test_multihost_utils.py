"""Single-process unit coverage for ``launch.multihost`` — the helpers
must degrade gracefully when there is no cluster (every call site is
unconditional), and the bootstrap argument/env resolution must fail
loudly on half-specified clusters.  The real multi-process semantics
live in ``tests/multihost/`` (subprocess harness)."""
import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import multihost
from repro.launch.mesh import make_federation_mesh


def test_initialize_is_noop_without_processes(monkeypatch):
    for k in (multihost.ENV_COORDINATOR, multihost.ENV_NUM_PROCESSES,
              multihost.ENV_PROCESS_ID):
        monkeypatch.delenv(k, raising=False)
    assert multihost.initialize() is False
    assert multihost.initialize(num_processes=1) is False
    assert multihost.initialize(num_processes=0) is False
    assert jax.process_count() == 1
    assert multihost.is_primary()


def test_initialize_env_resolution(monkeypatch):
    monkeypatch.setenv(multihost.ENV_NUM_PROCESSES, "1")
    assert multihost.initialize() is False  # env says single-process


def test_initialize_rejects_half_specified_cluster(monkeypatch):
    for k in (multihost.ENV_COORDINATOR, multihost.ENV_PROCESS_ID):
        monkeypatch.delenv(k, raising=False)
    with pytest.raises(ValueError, match="coordinator"):
        multihost.initialize(num_processes=2)


def test_process_row_slice_single_device():
    mesh = make_federation_mesh(6)  # single CPU -> width 1
    sh = NamedSharding(mesh, P("node"))
    assert multihost.process_row_slice(sh, (6,)) == slice(0, 6)
    assert multihost.process_row_slice(sh, (6, 3)) == slice(0, 6)


def test_addressable_node_rows_single_process():
    from repro.core.distributed import addressable_node_rows

    mesh = make_federation_mesh(8)
    assert addressable_node_rows(mesh, 8) == slice(0, 8)


def test_shard_rows_and_replicate_roundtrip():
    mesh = make_federation_mesh(4)
    x = np.arange(12, dtype=np.float32).reshape(4, 3)
    gx = multihost.shard_rows(mesh, x)
    assert isinstance(gx, jax.Array) and gx.shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(gx), x)
    v = multihost.replicate(mesh, np.float32([1.0, 2.0]))
    np.testing.assert_array_equal(np.asarray(v), [1.0, 2.0])


def test_place_federation_shapes_and_values():
    mesh = make_federation_mesh(4)
    x = np.random.default_rng(0).normal(size=(4, 5, 3)).astype(np.float32)
    y = x.sum(-1)
    counts = np.full((4,), 5, np.int32)
    val = (np.ones((2, 3), np.float32), np.ones((2,), np.float32))
    gx, gy, gc, gval = multihost.place_federation(mesh, x, y, counts, val)
    np.testing.assert_array_equal(np.asarray(gx), x)
    np.testing.assert_array_equal(np.asarray(gy), y)
    np.testing.assert_array_equal(np.asarray(gc), counts)
    assert len(gval) == 2
    gx2, gy2, gc2, gval2 = multihost.place_federation(mesh, x, y, counts, None)
    assert gval2 is None


def test_fetch_replicated_passthrough_and_numpy():
    tree = {"a": jax.numpy.arange(3.0), "b": np.float32([1, 2])}
    host = multihost.fetch_replicated(tree)
    assert isinstance(host["a"], np.ndarray)
    np.testing.assert_array_equal(host["a"], [0.0, 1.0, 2.0])
    np.testing.assert_array_equal(host["b"], [1.0, 2.0])


def test_barrier_is_noop_single_process():
    multihost.barrier("unit")  # must not raise or hang


def test_state_shardings_key_stays_replicated():
    """num_nodes == 2 must not shard the (2,)-shaped RNG key over the
    node axis (the leading-dim heuristic's one false positive)."""
    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.models import LSTMModel
    from repro.optim import sgd

    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2),
                 FLConfig(num_nodes=2, rounds=1), mixer="sharded")
    mesh = make_federation_mesh(2)
    sh = tr.state_shardings(mesh)
    assert sh.key.spec == P()
    assert sh.round.spec == P()
    assert sh.staleness.spec == P("node")
    assert all(s.spec == P("node") for s in jax.tree.leaves(sh.params))
    state = tr.init_sharded(jax.random.PRNGKey(0), mesh)
    ref = tr.init(jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
