"""Launcher-layer unit tests: HLO collective parsing, divisibility-aware
sharding helpers, checkpoint round-trip, config overrides."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ExperimentConfig, apply_overrides, get_arch_config
from repro.launch.dryrun import _shape_bytes, collective_schedule


SAMPLE_HLO = """
  %all-gather.1 = bf16[16,1024]{1,0} all-gather(%param.1), replica_groups={}
  %all-reduce.2 = f32[8,256]{1,0} all-reduce(%x), to_apply=%add
  %all-reduce-start.3 = f32[128]{0} all-reduce-start(%y), to_apply=%add
  %all-reduce-done.3 = f32[128]{0} all-reduce-done(%all-reduce-start.3)
  %reduce-scatter.4 = bf16[4,64]{1,0} reduce-scatter(%z), dimensions={0}
  %collective-permute.5 = s32[32]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %tuple.9 = (f32[2,2]{1,0}, f32[4]{0}) all-to-all(%a, %b), dimensions={0}
"""


def test_collective_schedule_counts_and_bytes():
    out = collective_schedule(SAMPLE_HLO)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 1024 * 2
    # -start counted once, -done skipped
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 8 * 256 * 4 + 128 * 4
    # wire model: all-reduce moves 2x
    assert out["all-reduce"]["wire_bytes"] == 2 * (8 * 256 * 4 + 128 * 4)
    assert out["reduce-scatter"]["count"] == 1
    assert out["collective-permute"]["bytes"] == 32 * 4
    # tuple-result all-to-all sums both components
    assert out["all-to-all"]["bytes"] == 2 * 2 * 4 + 4 * 4
    assert out["total_wire_bytes"] > 0


def test_shape_bytes_parses_dtypes():
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("(f32[2], bf16[4,4])") == 8 + 32


def test_apply_overrides_nested():
    cfg = ExperimentConfig()
    cfg = apply_overrides(cfg, ["fl.comm_batch=3", "train.lr=0.01", "data.dataset=ctr3"])
    assert cfg.fl.comm_batch == 3
    assert cfg.train.lr == pytest.approx(0.01)
    assert cfg.data.dataset == "ctr3"
    with pytest.raises(KeyError):
        apply_overrides(cfg, ["fl.nonexistent=1"])


def test_checkpoint_roundtrip(tmp_path):
    from repro.launch.train import load_checkpoint, save_checkpoint
    from repro.models import LSTMModel

    m = LSTMModel(hidden=16)
    params = m.init(jax.random.PRNGKey(0))
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, params)
    back = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_param_pspecs_divisibility_fallback():
    """kv-projection output (8 heads x 128) shards 16 ways via the fused
    dim; a 7-wide dim must fall back to replication."""
    from jax.sharding import PartitionSpec as P

    from repro.arch.sharding import param_pspecs

    params = {
        "wk": jnp.zeros((128, 8 * 128)),     # fused kv dim 1024 % 16 == 0
        "odd": jnp.zeros((7, 13)),            # nothing divisible
        "layers": {"wq": jnp.zeros((4, 128, 256))},  # stacked
    }
    specs = param_pspecs(params, axis_size=16)
    assert specs["wk"] == P(None, "model")
    assert specs["odd"] == P(None, None)
    assert specs["layers"]["wq"] == P(None, None, "model")


def test_reduced_configs_under_cpu_limits():
    for name in ("mistral-large-123b", "mixtral-8x22b", "whisper-medium"):
        r = get_arch_config(name).reduced()
        assert r.num_layers == 2
        assert r.d_model <= 512
        assert (r.num_experts or 0) <= 4


def test_gossip_dp_ring_specs_roundtrip():
    """ring_mix_params with shard-aware specs matches the unsharded
    reference on a single device (specs degenerate to replicated)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.gossip_dp import ring_mix_params

    mesh = jax.make_mesh((1,), ("node",))
    params = {"w": jnp.arange(12.0).reshape(3, 4)}
    specs = {"w": P(None, None)}
    out = ring_mix_params(params, mesh, ("node",), specs=specs)
    # single node: mix = (w + w + w)/3 = w
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(params["w"]), atol=1e-6)


def test_sweep_mesh_width_search():
    """The (grid, node) width search: both widths divide their extents,
    devices used are maximized, ties break toward the node axis (the
    memory-scaled one)."""
    from repro.launch.mesh import _sweep_mesh_widths

    # Fig-5 grid (15 scenarios) x 32 nodes on 8 devices: full node shard
    assert _sweep_mesh_widths(15, 32, 8) == (1, 8)
    # G=4, N=6: (4, 2) uses all 8 devices, beating the (1, 6)/(2, 3) layouts
    assert _sweep_mesh_widths(4, 6, 8) == (4, 2)
    # equal-total candidates (2, 4) vs (4, 2): node axis wins the tie
    assert _sweep_mesh_widths(4, 4, 8) == (2, 4)
    # degenerate: nothing divides -> (1, 1) local fallback
    assert _sweep_mesh_widths(7, 13, 4) == (1, 1)
    # single device
    assert _sweep_mesh_widths(15, 226, 1) == (1, 1)


def test_make_sweep_mesh_contract():
    """The sweep mesh always keeps the 2-D ("grid", "node") contract
    and divides its extents — down to the degenerate one-device (1, 1)
    local fallback (``devices=1`` caps the search regardless of how
    many devices the test process exposes); invalid explicit widths
    refuse."""
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(15, 32, devices=1)
    assert mesh.axis_names == ("grid", "node")
    assert dict(mesh.shape) == {"grid": 1, "node": 1}
    auto = make_sweep_mesh(15, 32)
    assert auto.axis_names == ("grid", "node")
    assert 15 % auto.shape["grid"] == 0 and 32 % auto.shape["node"] == 0
    with pytest.raises(ValueError, match="divide"):
        make_sweep_mesh(15, 32, grid_width=2, node_width=1)


def test_choose_gossip_impl_memory_heuristic():
    """--gossip-impl auto: allgather while the gathered (N, D) federation
    fits the per-device budget, psum above it; single-shard meshes always
    allgather (the gather is a no-op copy)."""
    from repro.launch.mesh import choose_gossip_impl

    # 32 nodes x 1 KiB fits any sane budget
    assert choose_gossip_impl(32, 1024, shards=8) == "allgather"
    # 256 nodes x 64 MiB = 16 GiB gathered per device -> memory-scaled
    assert choose_gossip_impl(256, 64 << 20, shards=8) == "psum"
    # explicit budget boundary is inclusive
    assert choose_gossip_impl(4, 100, shards=4, budget_bytes=400) == "allgather"
    assert choose_gossip_impl(4, 101, shards=4, budget_bytes=400) == "psum"
    # one shard: nothing to scale
    assert choose_gossip_impl(7, 1 << 40, shards=1) == "allgather"
