"""Per-architecture smoke tests: REDUCED variant of each assigned
architecture family runs one forward/train step and one decode step on
CPU with shape + finiteness asserts (harness requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch import build_arch
from repro.arch.common import init_train_state, make_train_step
from repro.config import get_arch_config, list_archs
from repro.nn.layers import pad_vocab

ARCHS = [a for a in list_archs() if a != "glucose-lstm"]


def _batch_for(arch, B, S):
    specs = arch.input_specs("train_4k", override_batch=B, override_seq=S)
    return jax.tree.map(
        lambda sp: jnp.ones(sp.shape, sp.dtype)
        if sp.dtype == jnp.int32
        else jnp.full(sp.shape, 0.1, sp.dtype),
        specs,
    )


@pytest.mark.parametrize("name", ARCHS)
def test_arch_reduced_train_step(name):
    cfg = get_arch_config(name).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    arch = build_arch(cfg)
    params = arch.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(arch, B=2, S=32)
    step = make_train_step(arch.loss_fn, num_microbatches=2, lr=1e-3)
    state = init_train_state(params)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert moved, name


@pytest.mark.parametrize("name", ARCHS)
def test_arch_reduced_decode_step(name):
    cfg = get_arch_config(name).reduced()
    arch = build_arch(cfg)
    params = arch.init_params(jax.random.PRNGKey(0))
    B, cache_len = 2, 64
    state = arch.init_decode_state(params, B, cache_len)
    dec = jax.jit(arch.decode_fn)
    st = state
    for pos in range(3):
        batch = {"token": jnp.full((B, 1), 3, jnp.int32), "pos": jnp.asarray(pos, jnp.int32)}
        logits, st = dec(params, st, batch)
    vp = pad_vocab(cfg.vocab_size)
    assert logits.shape == (B, 1, vp), (name, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name


@pytest.mark.parametrize("name", ARCHS)
def test_arch_reduced_prefill(name):
    cfg = get_arch_config(name).reduced()
    arch = build_arch(cfg)
    params = arch.init_params(jax.random.PRNGKey(0))
    specs = arch.input_specs("prefill_32k", override_batch=2, override_seq=32)
    batch = jax.tree.map(
        lambda sp: jnp.ones(sp.shape, sp.dtype)
        if sp.dtype == jnp.int32
        else jnp.full(sp.shape, 0.1, sp.dtype),
        specs,
    )
    logits, cache = jax.jit(arch.prefill_fn)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    }
    for name, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_arch_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), name
    m = get_arch_config("mamba2-370m")
    assert (m.num_layers, m.d_model, m.vocab_size, m.ssm_state) == (48, 1024, 50280, 128)
    x = get_arch_config("mixtral-8x22b")
    assert (x.num_experts, x.experts_per_token) == (8, 2)
    g = get_arch_config("granite-moe-1b-a400m")
    assert (g.num_experts, g.experts_per_token) == (32, 8)


def test_vlm_concat_lengths():
    cfg = get_arch_config("llava-next-mistral-7b").reduced()
    arch = build_arch(cfg)
    specs = arch.input_specs("train_4k", override_batch=2, override_seq=32)
    tv = cfg.vision_tokens
    assert specs["patches"].shape[1] == tv
    assert specs["tokens"].shape[1] == 32 - tv
    assert specs["labels"].shape[1] == 32


def test_long_500k_support_flags():
    support = {a: build_arch(get_arch_config(a)).supports("long_500k") for a in ARCHS}
    assert support["mamba2-370m"] and support["recurrentgemma-9b"]
    assert support["mistral-large-123b"] and support["mixtral-8x22b"]
    assert support["llava-next-mistral-7b"]
    assert not support["yi-34b"] and not support["yi-6b"]
    assert not support["qwen2.5-3b"] and not support["whisper-medium"]
    assert not support["granite-moe-1b-a400m"]
