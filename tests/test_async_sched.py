"""core/async_sched.py invariants — the wait-free participation model.

The schedules gate every round's communication AND local step, so their
edge cases are trainer-correctness bugs: a zero-active round would make
the mixing matrix the identity and the loss denominator hit its clamp,
and a staleness counter that fails to reset breaks the beyond-paper
staleness study.  Property tests run under hypothesis when installed
(CI's property job); the rest is tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_sched import (
    bernoulli_active,
    markov_active,
    round_robin_active,
    staleness_update,
)


# ---------------------------------------------------------------- bernoulli
@pytest.mark.parametrize("ratio", [0.9, 0.99, 0.999, 1.0])
@pytest.mark.parametrize("n", [1, 2, 7, 64])
def test_bernoulli_always_at_least_one_active(ratio, n):
    """Even at inactive_ratio=1.0 (every node nominally dropped) the
    round keeps >= 1 active node — otherwise gossip and the active-mean
    loss degenerate."""
    for seed in range(25):
        active = bernoulli_active(jax.random.PRNGKey(seed), n, ratio)
        assert active.shape == (n,)
        assert active.dtype == jnp.float32
        a = np.asarray(active)
        assert set(np.unique(a)).issubset({0.0, 1.0})
        assert a.sum() >= 1.0, f"zero active nodes at ratio={ratio} seed={seed}"


def test_bernoulli_ratio_zero_is_all_active():
    a = bernoulli_active(jax.random.PRNGKey(0), 16, 0.0)
    np.testing.assert_array_equal(np.asarray(a), 1.0)


def test_bernoulli_matches_ratio_in_expectation():
    n, ratio = 4096, 0.3
    a = np.asarray(bernoulli_active(jax.random.PRNGKey(1), n, ratio))
    assert abs(a.mean() - (1 - ratio)) < 0.03


def test_bernoulli_jit_and_grad_safe():
    """The schedule runs inside the scanned round body — it must jit
    with the ratio static and produce identical masks."""
    f = jax.jit(bernoulli_active, static_argnums=(1, 2))
    key = jax.random.PRNGKey(3)
    np.testing.assert_array_equal(
        np.asarray(f(key, 8, 0.5)), np.asarray(bernoulli_active(key, 8, 0.5))
    )


# ------------------------------------------------------------------- markov
def test_markov_shapes_and_binary():
    prev = jnp.ones((32,), jnp.float32)
    nxt = markov_active(jax.random.PRNGKey(0), prev)
    assert nxt.shape == prev.shape
    assert set(np.unique(np.asarray(nxt))).issubset({0.0, 1.0})


def test_markov_extreme_stickiness():
    """p_stay=1 freezes the chain in both states."""
    key = jax.random.PRNGKey(0)
    prev = (jax.random.uniform(key, (64,)) > 0.5).astype(jnp.float32)
    frozen = markov_active(jax.random.PRNGKey(1), prev,
                           p_stay_active=1.0, p_stay_inactive=1.0)
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(prev))


def test_markov_escapes_all_inactive_absorbing_state():
    """The all-busy state must not absorb the federation: from
    prev=zeros with p_stay_inactive=1 the raw draw activates NOBODY
    (u < 0 never fires), which pre-fix made every later round a silent
    global no-op.  The >=1-active fallback flips exactly one node on."""
    n = 32
    prev = jnp.zeros((n,), jnp.float32)
    nxt = markov_active(jax.random.PRNGKey(0), prev,
                        p_stay_active=0.9, p_stay_inactive=1.0)
    assert float(nxt.sum()) == 1.0, np.asarray(nxt)
    # and it is not an absorbing one-node orbit: the chain keeps moving
    nxt2 = markov_active(jax.random.PRNGKey(1), nxt,
                         p_stay_active=0.9, p_stay_inactive=1.0)
    assert float(nxt2.sum()) >= 1.0


def test_markov_always_at_least_one_active():
    """Across many keys at brutal stickiness, every round has >= 1
    active node (mirrors the bernoulli guarantee)."""
    prev = jnp.zeros((16,), jnp.float32)
    for seed in range(50):
        nxt = markov_active(jax.random.PRNGKey(seed), prev,
                            p_stay_active=0.05, p_stay_inactive=0.98)
        assert float(nxt.sum()) >= 1.0, seed


def _markov_chain(n, steps, p_a, p_i, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    state = jnp.ones((n,), jnp.float32)
    states = []
    for k in keys:
        state = markov_active(k, state, p_stay_active=p_a, p_stay_inactive=p_i)
        states.append(np.asarray(state))
    return np.stack(states)


def test_markov_stationary_fraction():
    """Long-run active fraction matches the chain's stationary
    distribution pi_active = q / (p + q) with p = 1 - p_stay_active and
    q = 1 - p_stay_inactive."""
    p_a, p_i = 0.9, 0.7
    chain = _markov_chain(256, 300, p_a, p_i)
    stationary = (1 - p_i) / ((1 - p_a) + (1 - p_i))
    assert abs(chain[100:].mean() - stationary) < 0.03


def test_markov_is_sticky_vs_iid():
    """Consecutive-round agreement must exceed the iid baseline — the
    whole point of the markov schedule (busy phones stay busy)."""
    p_a, p_i = 0.9, 0.7
    chain = _markov_chain(512, 200, p_a, p_i)
    agree = (chain[1:] == chain[:-1]).mean()
    frac = chain.mean()
    iid_agree = frac**2 + (1 - frac) ** 2
    assert agree > iid_agree + 0.05


# ------------------------------------------------------------- round robin
def test_round_robin_rotates_and_covers():
    n, frac = 8, 0.25
    seen = np.zeros(n)
    for t in range(4):
        a = np.asarray(round_robin_active(t, n, frac))
        assert a.sum() == 2
        seen += a
    np.testing.assert_array_equal(seen, 1.0)  # full coverage, no overlap


# --------------------------------------------------------------- staleness
def test_staleness_resets_on_activity_and_counts_gaps():
    s = jnp.zeros((4,), jnp.float32)
    masks = [
        jnp.array([1.0, 0.0, 0.0, 1.0]),
        jnp.array([1.0, 0.0, 1.0, 0.0]),
        jnp.array([0.0, 1.0, 1.0, 0.0]),
    ]
    for m in masks:
        s = staleness_update(s, m)
    # node0: active,active,inactive -> 1; node1: inactive x2 then active -> 0
    # node2: reset at rounds 2,3 -> 0; node3: active then 2 misses -> 2
    np.testing.assert_array_equal(np.asarray(s), [1.0, 0.0, 0.0, 2.0])


def test_staleness_invariant_random_walk():
    """Invariant over random masks: staleness == rounds since last
    activity (0 while active), computed against a numpy oracle."""
    rng = np.random.default_rng(0)
    n, rounds = 16, 50
    s = jnp.zeros((n,), jnp.float32)
    oracle = np.zeros(n)
    for _ in range(rounds):
        m = (rng.random(n) > 0.5).astype(np.float32)
        s = staleness_update(s, jnp.asarray(m))
        oracle = np.where(m > 0, 0.0, oracle + 1)
        np.testing.assert_array_equal(np.asarray(s), oracle)


# ------------------------------------------------ property layer
# Runs under hypothesis when installed (CI's property job explores the
# space); falls back to a deterministic grid in plain tier-1 so the
# invariants are ALWAYS exercised (no skip — tier-1 stays at its seed
# skip budget).
def _bernoulli_never_empty(n, seed, ratio):
    a = np.asarray(bernoulli_active(jax.random.PRNGKey(seed), n, ratio))
    assert a.sum() >= 1.0
    assert set(np.unique(a)).issubset({0.0, 1.0})


def _staleness_matches_oracle_under_markov(n, seed, p_a, p_i, steps):
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    active = jnp.ones((n,), jnp.float32)
    s = jnp.zeros((n,), jnp.float32)
    oracle = np.zeros(n)
    for k in keys:
        active = markov_active(k, active, p_stay_active=p_a,
                               p_stay_inactive=p_i)
        s = staleness_update(s, active)
        a = np.asarray(active)
        oracle = np.where(a > 0, 0.0, oracle + 1)
        np.testing.assert_array_equal(np.asarray(s), oracle)


def test_schedule_properties():
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for n, seed, ratio in [(1, 0, 1.0), (2, 7, 0.99), (17, 3, 0.5),
                               (64, 11, 0.9), (5, 2, 0.0)]:
            _bernoulli_never_empty(n, seed, ratio)
        for n, seed, p_a, p_i, steps in [(1, 0, 0.0, 0.0, 5),
                                         (8, 1, 1.0, 1.0, 5),
                                         (16, 2, 0.9, 0.7, 8),
                                         (32, 3, 0.3, 0.6, 4)]:
            _staleness_matches_oracle_under_markov(n, seed, p_a, p_i, steps)
        return

    settings(max_examples=25, deadline=None)(given(
        n=st.integers(1, 64), seed=st.integers(0, 2**16),
        ratio=st.floats(0.0, 1.0),
    )(_bernoulli_never_empty))()

    settings(max_examples=25, deadline=None)(given(
        n=st.integers(1, 32), seed=st.integers(0, 2**16),
        p_a=st.floats(0.0, 1.0), p_i=st.floats(0.0, 1.0),
        steps=st.integers(1, 10),
    )(_staleness_matches_oracle_under_markov))()
