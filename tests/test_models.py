"""Glucose-predictor model tests (LSTM + baselines) and trainers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MAML, MetaSGD, personalize, train_supervised
from repro.models import GradientBoostedTrees, LinearModel, LSTMModel, NBeatsModel, NHiTSModel
from repro.models.linear import fit_closed_form
from repro.optim import adam, sgd


def _toy(m=400, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, L)).astype(np.float32)
    w = rng.normal(size=(L,)).astype(np.float32)
    y = (x @ w + 0.05 * rng.normal(size=m)).astype(np.float32)
    return x, y


@pytest.mark.parametrize("cls", [LSTMModel, NBeatsModel, NHiTSModel, LinearModel])
def test_model_shapes_and_finiteness(cls):
    m = cls(history_len=12, hidden=32) if cls is not LinearModel else cls(history_len=12)
    model = m.as_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(7, 12)), jnp.float32)
    out = model.apply(params, x)
    assert out.shape == (7,)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("cls", [LSTMModel, NBeatsModel, NHiTSModel])
def test_models_learn_linear_teacher(cls):
    x, y = _toy()
    model = cls(history_len=12, hidden=32).as_model()
    params, hist = train_supervised(
        model, adam(3e-3), jax.random.PRNGKey(0), x, y, steps=300, batch_size=64
    )
    pred = model.apply(params, jnp.asarray(x))
    mse = float(jnp.mean((pred - jnp.asarray(y)) ** 2))
    assert mse < 0.5 * float(np.var(y)), mse


def test_linear_closed_form_beats_noise():
    x, y = _toy()
    params = fit_closed_form(jnp.asarray(x), jnp.asarray(y))
    model = LinearModel(history_len=12).as_model()
    pred = model.apply(params, jnp.asarray(x))
    mse = float(jnp.mean((pred - jnp.asarray(y)) ** 2))
    assert mse < 0.05 * float(np.var(y))


def test_gbt_fits_step_function():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(500, 12)).astype(np.float32)
    y = np.where(x[:, 0] > 0, 2.0, -1.0).astype(np.float32)
    gbt = GradientBoostedTrees(num_trees=20, depth=3, lr=0.3)
    params = gbt.fit(x, y)
    pred = np.asarray(gbt.predict(params, jnp.asarray(x)))
    assert np.mean((pred - y) ** 2) < 0.15


def test_maml_adapts_faster_than_random():
    # two tasks with opposite teachers; MAML init should adapt in 3 steps
    rng = np.random.default_rng(0)
    L, m = 12, 64
    w = rng.normal(size=(L,)).astype(np.float32)
    x = rng.normal(size=(2, m, L)).astype(np.float32)
    y = np.stack([x[0] @ w, x[1] @ (-w)]).astype(np.float32)
    counts = np.full((2,), m, np.int32)
    model = LSTMModel(hidden=16).as_model()
    maml = MAML(model, adam(1e-3), inner_lr=0.05, inner_steps=3)
    params, lrs, hist = maml.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=32, steps=40
    )
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_metasgd_learns_rates():
    x = np.random.default_rng(0).normal(size=(2, 64, 12)).astype(np.float32)
    y = x[..., -1].astype(np.float32)
    counts = np.full((2,), 64, np.int32)
    model = LSTMModel(hidden=8).as_model()
    ms = MetaSGD(model, adam(1e-3), inner_lr=0.02, inner_steps=2)
    params, lrs, hist = ms.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=16, steps=20
    )
    flat = np.concatenate([np.ravel(l) for l in jax.tree.leaves(lrs)])
    assert np.std(flat) > 0  # rates actually moved per-parameter


def test_personalize_improves_on_population(fed_ohio):
    model = LSTMModel(hidden=16).as_model()
    pat = fed_ohio.patients[0]
    pop, _ = train_supervised(
        model, adam(3e-3), jax.random.PRNGKey(0),
        np.concatenate([p.train_x for p in fed_ohio.patients]),
        np.concatenate([p.train_y for p in fed_ohio.patients]),
        steps=150, batch_size=64,
    )
    pers = personalize(model, adam(1e-3), pop, jax.random.PRNGKey(1),
                       pat.train_x, pat.train_y, steps=80)
    mse_pop = float(jnp.mean((model.apply(pop, jnp.asarray(pat.val_x)) - jnp.asarray(pat.val_y)) ** 2))
    mse_pers = float(jnp.mean((model.apply(pers, jnp.asarray(pat.val_x)) - jnp.asarray(pat.val_y)) ** 2))
    assert mse_pers < mse_pop * 1.3  # personalization must not catastrophically hurt
