"""Streaming-eval parity: the in-scan lax.cond eval branch must produce
records identical to the loop engine's host callback on the same key
stream — including with DP noise and inactive masks — and the scan
engine must be the one true path (no per-round host dispatch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL
from repro.models import LSTMModel
from repro.optim import adam, sgd


def _toy_fed(n=6, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, m)).astype(np.float32)
    counts = np.full((n,), m, np.int32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)


def _val_set(m=24, L=12, seed=7):
    rng = np.random.default_rng(seed)
    vx = rng.normal(size=(m, L)).astype(np.float32)
    vy = (vx @ rng.normal(size=(L,)).astype(np.float32)).astype(np.float32)
    return jnp.asarray(vx), jnp.asarray(vy)


@pytest.mark.parametrize("dp_sigma,inactive", [(0.0, 0.0), (0.05, 0.4)])
def test_scan_eval_records_bitwise_match_loop(dp_sigma, inactive):
    """Scan-engine eval records (losses + val RMSE at every eval_every
    boundary) bitwise-match the loop-engine callback on the same key
    stream, including with DP noise and inactive masks."""
    rounds, eval_every = 9, 2
    x, y, counts = _toy_fed()
    val = _val_set()
    cfg = FLConfig(topology="random", num_nodes=6, rounds=rounds,
                   comm_batch=3, inactive_ratio=inactive)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                 dp_noise_sigma=dp_sigma)
    pop_s, hist_s, st_s = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8,
        eval_every=eval_every, val_data=val, chunk=4,
    )
    pop_l, hist_l, st_l = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8,
        eval_every=eval_every, val_data=val, engine="loop",
    )
    assert len(hist_s) == len(hist_l) == rounds
    for hs, hl in zip(hist_s, hist_l):
        assert set(hs) == set(hl), (hs, hl)
        assert hs["loss"] == hl["loss"]  # bitwise: same program numerics
        if (hs["round"] + 1) % eval_every == 0:
            assert "val_rmse" in hs
            assert hs["val_rmse"] == hl["val_rmse"]
            assert np.isfinite(hs["val_rmse"])
        else:
            assert "val_rmse" not in hs
    np.testing.assert_array_equal(np.asarray(st_s.key), np.asarray(st_l.key))


def test_eval_runs_through_scan_not_per_round_dispatch():
    """train(eval_every=...) must go through train_chunk with NO
    per-round host dispatch: stub out the per-round jit and the run must
    still succeed (the loop engine would crash)."""
    x, y, counts = _toy_fed()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=7)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg)

    def boom(*a, **kw):
        raise AssertionError("per-round dispatch used by the scan engine")

    tr._round_jit = boom
    pop, hist, st = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8,
        eval_every=2, val_data=_val_set(), chunk=3,  # 2 full chunks + tail
    )
    assert [h["round"] for h in hist] == list(range(7))
    assert [h["round"] for h in hist if "val_rmse" in h] == [1, 3, 5]
    assert int(st.round) == 7


def test_train_chunk_eval_records_nan_off_boundary():
    """train_chunk returns (losses, metrics) with the eval value at
    boundaries and the NaN sentinel elsewhere — eval never leaves the
    compiled program."""
    k, eval_every = 6, 3
    x, y, counts = _toy_fed()
    vx, vy = _val_set()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=k)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), adam(5e-3), cfg)
    s0 = tr.init(jax.random.PRNGKey(0), x[0, :1])
    s1, (losses, metrics) = tr.train_chunk(
        s0, x, y, counts, batch_size=8, chunk=k,
        val_x=vx, val_y=vy, eval_every=eval_every,
        eval_fn=tr._resolve_eval_fn(None),
    )
    assert losses.shape == (k,)
    rmse = np.asarray(metrics["val_rmse"])
    assert rmse.shape == (k,)
    boundary = (np.arange(1, k + 1) % eval_every) == 0
    assert np.isfinite(rmse[boundary]).all()
    assert np.isnan(rmse[~boundary]).all()


def test_custom_traceable_eval_fn_legacy_and_canonical():
    """Both eval_fn spellings run in-scan: legacy f(pop) (auto-wrapped)
    and canonical f(pop, val_x, val_y); histories agree when they
    compute the same metric."""
    x, y, counts = _toy_fed()
    vx, vy = _val_set()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=4)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg)

    def canonical(pop, val_x, val_y):
        pred = tr.model.apply(pop, val_x)
        return {"val_rmse": jnp.sqrt(jnp.mean(jnp.square(pred - val_y)))}

    def legacy(pop):  # closes over the val set, ignores scan constants
        pred = tr.model.apply(pop, vx)
        return {"val_rmse": jnp.sqrt(jnp.mean(jnp.square(pred - vy)))}

    _, h_canon, _ = tr.train(jax.random.PRNGKey(3), x, y, counts, batch_size=8,
                             eval_every=2, eval_fn=canonical, val_data=(vx, vy))
    _, h_legacy, _ = tr.train(jax.random.PRNGKey(3), x, y, counts, batch_size=8,
                              eval_every=2, eval_fn=legacy)
    assert [h["round"] for h in h_canon if "val_rmse" in h] == [1, 3]
    for a, b in zip(h_canon, h_legacy):
        if "val_rmse" in a:
            np.testing.assert_allclose(a["val_rmse"], b["val_rmse"], atol=1e-6)


def test_non_float_eval_output_rejected():
    """The NaN off-boundary sentinel needs float outputs — an int metric
    must raise, not silently corrupt."""
    x, y, counts = _toy_fed()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=2)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg)
    with pytest.raises(TypeError, match="floating"):
        tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8,
                 eval_every=1, eval_fn=lambda pop, vx, vy: {"n": jnp.int32(1)})


def test_loop_engine_still_honors_host_callbacks():
    """engine="loop" remains the debug path for impure host callbacks
    (side effects between rounds) — explicitly requested, never
    auto-selected."""
    x, y, counts = _toy_fed()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=6)
    tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg)
    calls = []
    pop, hist, _ = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8, engine="loop",
        eval_every=2, eval_fn=lambda p: calls.append(1) or {"evald": len(calls)},
    )
    assert len(hist) == 6 and len(calls) == 3
    assert hist[1]["evald"] == 1 and hist[5]["evald"] == 3
