"""Checkpoint round-trip: ``launch/train.py`` save/load must restore a
pytree BITWISE, and the committed experiment checkpoints must stay
loadable against a freshly-inited ``like`` tree (they are the repo's
only persisted artifacts — silent format drift would orphan them)."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import load_checkpoint, save_checkpoint
from repro.models import LSTMModel
from repro.utils.pytree import tree_to_vector

ROOT = Path(__file__).resolve().parents[1]
COMMITTED = sorted((ROOT / "experiments" / "checkpoints").glob("*.npz"))


def test_roundtrip_is_bitwise(tmp_path):
    model = LSTMModel(hidden=8).as_model()
    params = model.init(jax.random.PRNGKey(42))
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, params)
    restored = load_checkpoint(path, params)
    assert jax.tree.structure(restored) == jax.tree.structure(params)
    for orig, back in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert orig.shape == back.shape
        assert orig.dtype == back.dtype
        np.testing.assert_array_equal(np.asarray(orig), np.asarray(back))


def test_roundtrip_mixed_dtypes_and_scalars(tmp_path):
    """Optimizer-state-shaped trees (scalar leaves, float32) survive."""
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7.0,
        "nested": {"b": jnp.array(3.5, jnp.float32),
                   "v": jnp.linspace(-1, 1, 5)},
    }
    path = tmp_path / "tree.npz"
    save_checkpoint(path, tree)
    restored = load_checkpoint(path, tree)
    for orig, back in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(orig), np.asarray(back))


def test_saved_meta_describes_leaves(tmp_path):
    model = LSTMModel(hidden=8).as_model()
    params = model.init(jax.random.PRNGKey(0))
    path = tmp_path / "meta.npz"
    save_checkpoint(path, params)
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["meta"]))
    leaves = jax.tree.leaves(params)
    assert len(meta) == len(leaves)
    for (name, shape, dtype), leaf in zip(meta, leaves):
        assert tuple(shape) == leaf.shape
        assert dtype == str(leaf.dtype)
    assert data["vec"].shape == tree_to_vector(params).shape


def _hidden_for(vec_len: int) -> int | None:
    """Recover the LSTM width a committed checkpoint was trained at from
    its flat parameter count (the checkpoint stores shapes in meta; the
    like-tree must be inited at the same width)."""
    for hidden in (4, 8, 16, 32, 64, 128):
        model = LSTMModel(hidden=hidden).as_model()
        n = int(tree_to_vector(model.init(jax.random.PRNGKey(0))).shape[0])
        if n == vec_len:
            return hidden
    return None


def test_committed_checkpoints_exist():
    """Guard for the parametrized loader below: an empty glob would
    silently generate zero test cases, not a failure."""
    assert COMMITTED, "no committed checkpoints under experiments/checkpoints/"


@pytest.mark.parametrize("path", COMMITTED, ids=lambda p: p.stem)
def test_committed_checkpoints_load_against_fresh_like_tree(path):
    vec = np.load(path, allow_pickle=False)["vec"]
    hidden = _hidden_for(len(vec))
    assert hidden is not None, (
        f"{path.name}: {len(vec)} params match no known LSTM width — "
        f"the checkpoint format or model drifted"
    )
    model = LSTMModel(hidden=hidden).as_model()
    like = model.init(jax.random.PRNGKey(0))
    restored = load_checkpoint(path, like)
    assert jax.tree.structure(restored) == jax.tree.structure(like)
    for l_like, l_back in zip(jax.tree.leaves(like), jax.tree.leaves(restored)):
        assert l_like.shape == l_back.shape
        assert np.isfinite(np.asarray(l_back)).all()
    # the restored population model must actually run
    out = model.apply(restored, jnp.zeros((2, 12), jnp.float32))
    assert np.isfinite(np.asarray(out)).all()
