"""Docs-suite tests: the doc lint stays green and the README quickstart
code block actually executes — so the docs can't rot.  CI runs these in
the dedicated ``docs`` job (`pytest -m docs`); a plain local ``pytest``
run still executes everything."""
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(cmd, **kw):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=600, **kw)


@pytest.mark.docs
def test_every_module_has_a_docstring():
    """tools/check_docstrings.py: no module under src/repro/ may ship
    without a module docstring (package __init__ files included)."""
    out = _run([sys.executable, str(ROOT / "tools" / "check_docstrings.py")])
    assert out.returncode == 0, out.stderr


@pytest.mark.docs
def test_readme_quickstart_block_executes(tmp_path):
    """The README's first ``python`` fence is the quickstart; it must run
    end-to-end (train + cross-predict) exactly as written."""
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", readme, flags=re.DOTALL)
    assert blocks, "README.md lost its python quickstart block"
    script = tmp_path / "readme_quickstart.py"
    script.write_text(blocks[0])
    out = _run([sys.executable, str(script)])
    assert out.returncode == 0, f"README quickstart failed:\n{out.stderr[-3000:]}"
    assert "UNSEEN patient" in out.stdout


@pytest.mark.docs
def test_readme_sweep_snippet_is_consistent():
    """The README sweep snippet names real API: SweepGrid.build and
    train_sweep must exist with the documented signature."""
    import inspect

    from repro.core import GluADFL, SweepGrid

    sig = inspect.signature(SweepGrid.build)
    for param in ("topologies", "inactive_ratios", "seeds", "num_nodes"):
        assert param in sig.parameters
    sig = inspect.signature(GluADFL.train_sweep)
    for param in ("grid", "batch_size", "rounds", "eval_every", "val_data"):
        assert param in sig.parameters
