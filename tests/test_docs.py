"""Docs-suite tests: the doc lint stays green and the README quickstart
code block actually executes — so the docs can't rot.  CI runs these in
the dedicated ``docs`` job (`pytest -m docs`); a plain local ``pytest``
run still executes everything."""
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(cmd, **kw):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=600, **kw)


@pytest.mark.docs
def test_every_module_has_a_docstring():
    """tools/check_docstrings.py: no module under src/repro/ may ship
    without a module docstring (package __init__ files included)."""
    out = _run([sys.executable, str(ROOT / "tools" / "check_docstrings.py")])
    assert out.returncode == 0, out.stderr


@pytest.mark.docs
def test_no_gossip_knob_dispatch_outside_plan():
    """tools/check_gossip_dispatch.py: core/ may not string-dispatch on
    mixer / gossip_impl / gossip_repr outside core/gossip_plan.py — the
    plan resolver is the only dispatcher."""
    out = _run([sys.executable, str(ROOT / "tools" / "check_gossip_dispatch.py")])
    assert out.returncode == 0, out.stderr


@pytest.mark.docs
def test_knob_matrix_matches_registry():
    """tools/gen_knob_matrix.py --check: the committed ARCHITECTURE.md
    knob matrix equals the block generated from the backend registry
    (regenerate with --write after registering/changing a backend)."""
    out = _run([sys.executable, str(ROOT / "tools" / "gen_knob_matrix.py"),
                "--check"])
    assert out.returncode == 0, out.stdout + out.stderr


@pytest.mark.docs
def test_readme_quickstart_block_executes(tmp_path):
    """The README's first ``python`` fence is the quickstart; it must run
    end-to-end (train + cross-predict) exactly as written."""
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", readme, flags=re.DOTALL)
    assert blocks, "README.md lost its python quickstart block"
    script = tmp_path / "readme_quickstart.py"
    script.write_text(blocks[0])
    out = _run([sys.executable, str(script)])
    assert out.returncode == 0, f"README quickstart failed:\n{out.stderr[-3000:]}"
    assert "UNSEEN patient" in out.stdout


@pytest.mark.docs
def test_readme_serving_block_executes(tmp_path):
    """The README's Serving section block must run exactly as written:
    load the committed checkpoint, cold-start a cohort as one batched
    program, and serve forecasts through the micro-batcher.  Selected by
    content (the load_population call), not by fence index, so adding a
    snippet elsewhere can't silently retarget this test."""
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", readme, flags=re.DOTALL)
    serving = [b for b in blocks if "load_population" in b]
    assert len(serving) == 1, "README.md lost its serving quickstart block"
    script = tmp_path / "readme_serving.py"
    script.write_text(serving[0])
    # cwd=ROOT: the block names the committed checkpoint by repo-relative
    # path, exactly as a reader pasting it from a fresh clone would
    out = _run([sys.executable, str(script)], cwd=ROOT)
    assert out.returncode == 0, f"README serving block failed:\n{out.stderr[-3000:]}"
    assert "forecasts (mg/dL)" in out.stdout


@pytest.mark.docs
def test_serving_doc_names_real_api():
    """docs/SERVING.md documents knobs by name — they must exist with
    the documented spelling (the doc can't rot past the API)."""
    import inspect

    from repro.serve import GlucoseServable, MicroBatcher

    doc = (ROOT / "docs" / "SERVING.md").read_text()
    batcher_params = inspect.signature(MicroBatcher).parameters
    servable_params = inspect.signature(GlucoseServable).parameters
    for knob in ("buckets", "flush_timeout", "max_live_batches"):
        assert knob in doc and knob in batcher_params, knob
    for knob in ("batch_mode", "personalize_steps"):
        assert knob in doc and knob in servable_params, knob
    for method in ("warmup", "personalize", "forecast",
                   "row_of_or_population"):
        assert method in doc and hasattr(GlucoseServable, method), method


@pytest.mark.docs
def test_readme_sweep_snippet_is_consistent():
    """The README sweep snippet names real API: SweepGrid.build and
    train_sweep must exist with the documented signature."""
    import inspect

    from repro.core import GluADFL, SweepGrid

    sig = inspect.signature(SweepGrid.build)
    for param in ("topologies", "inactive_ratios", "seeds", "num_nodes",
                  "schedules", "skews", "dp_sigmas"):
        assert param in sig.parameters
    assert hasattr(SweepGrid, "label_dict")
    sig = inspect.signature(GluADFL.train_sweep)
    for param in ("grid", "batch_size", "rounds", "eval_every", "val_data"):
        assert param in sig.parameters
