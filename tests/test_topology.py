"""Topology + mixing-matrix unit tests (Algorithm 1 lines 5-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import (
    cluster_adjacency,
    densify_neighbor_table,
    full_adjacency,
    mixing_matrix,
    neighbor_candidates,
    neighbor_table,
    neighbor_table_from_candidates,
    random_adjacency,
    ring_adjacency,
    round_adjacency,
    spectral_gap,
    stacked_neighbor_table,
    star_adjacency,
    static_adjacency,
)


@pytest.mark.parametrize("n", [4, 12, 25, 226])
def test_ring_degree(n):
    a = np.asarray(ring_adjacency(n))
    assert (a.sum(1) == 2).all()
    assert np.allclose(a, a.T)
    assert np.diag(a).sum() == 0


@pytest.mark.parametrize("n,cs", [(12, 4), (25, 5), (30, 4)])
def test_cluster_connected(n, cs):
    a = np.asarray(cluster_adjacency(n, cs))
    assert np.allclose(a, a.T)
    # connectivity: (I + A)^n has no zeros
    reach = np.linalg.matrix_power(np.eye(n) + a, n) > 0
    assert reach.all(), "cluster graph must be connected"


def test_star_is_fedavg_topology():
    a = np.asarray(star_adjacency(10))
    assert a[0, 1:].sum() == 9 and a[1:, 0].sum() == 9
    assert a[1:, 1:].sum() == 0


@pytest.mark.parametrize("degree", [1, 3, 7])
def test_random_adjacency_degree_bound(degree):
    a = np.asarray(random_adjacency(jax.random.PRNGKey(0), 20, degree))
    assert np.allclose(a, a.T)
    assert np.diag(a).sum() == 0
    assert (a.sum(1) >= degree).all()  # symmetrization only adds edges


def test_random_adjacency_time_varying():
    a1 = random_adjacency(jax.random.PRNGKey(1), 16, 3)
    a2 = random_adjacency(jax.random.PRNGKey(2), 16, 3)
    assert not np.allclose(np.asarray(a1), np.asarray(a2))


def test_mixing_matrix_row_stochastic():
    n = 12
    adj = ring_adjacency(n)
    active = jnp.ones((n,))
    m = np.asarray(mixing_matrix(adj, active, 7))
    np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-6)
    assert (m >= 0).all()


def test_mixing_matrix_inactive_rows_identity():
    n = 8
    adj = full_adjacency(n)
    active = jnp.asarray([1, 0, 1, 0, 1, 1, 0, 1], jnp.float32)
    m = np.asarray(mixing_matrix(adj, active, 7))
    for i in range(n):
        if active[i] == 0:
            expect = np.zeros(n)
            expect[i] = 1.0
            np.testing.assert_allclose(m[i], expect, atol=1e-6)
        else:
            # active rows never average with inactive neighbours
            assert (m[i][np.asarray(active) == 0] == 0).all()


def test_mixing_matrix_comm_batch_cap():
    n = 10
    adj = full_adjacency(n)  # 9 neighbours each
    m = np.asarray(mixing_matrix(adj, jnp.ones((n,)), 3))
    # each row: self + at most B=3 neighbours
    assert ((m > 0).sum(1) <= 4).all()


def test_mixing_matrix_cap_keeps_lowest_index():
    """Pins WHICH neighbours survive the comm_batch cap: the cumulative-
    count mask keeps the B LOWEST-index active neighbours of each row
    (the docstring's promise must match ``csum <= comm_batch``)."""
    n, B = 6, 2
    adj = full_adjacency(n)
    # all active: row i keeps its first B non-self columns
    m = np.asarray(mixing_matrix(adj, jnp.ones((n,)), B))
    for i in range(n):
        kept = [j for j in range(n) if j != i and m[i, j] > 0]
        expect = [j for j in range(n) if j != i][:B]
        assert kept == expect, (i, kept, expect)
        np.testing.assert_allclose(m[i, kept + [i]], 1.0 / (B + 1), atol=1e-6)
    # inactive neighbours don't consume cap slots: with node 0 inactive,
    # row 5 keeps active neighbours {1, 2}, not {0, 1}
    active = jnp.ones((n,)).at[0].set(0.0)
    m = np.asarray(mixing_matrix(adj, active, B))
    kept = [j for j in range(n) if j != 5 and m[5, j] > 0]
    assert kept == [1, 2], kept


def test_spectral_gap_ordering():
    """More connectivity => larger spectral gap (faster gossip mixing) —
    the paper's Fig 4 explanation (random > cluster > ring)."""
    n = 24
    ones = jnp.ones((n,))
    g_ring = spectral_gap(mixing_matrix(ring_adjacency(n), ones, 7))
    g_cluster = spectral_gap(mixing_matrix(cluster_adjacency(n, 4), ones, 7))
    g_full = spectral_gap(mixing_matrix(full_adjacency(n), ones, 23))
    assert g_ring < g_cluster < g_full


def test_round_adjacency_dispatch():
    k = jax.random.PRNGKey(0)
    for topo in ("ring", "cluster", "random", "star", "full"):
        a = round_adjacency(topo, 12, k, 7)
        assert a.shape == (12, 12)
    with pytest.raises(KeyError):
        round_adjacency("hypercube", 12, k, 7)


# ---------------------------------------------------------------------------
# sparse neighbor tables (the O(N·B) twin of mixing_matrix)
# ---------------------------------------------------------------------------


def _mask(key, n, ratio):
    if ratio <= 0:
        return jnp.ones((n,), jnp.float32)
    m = (jax.random.uniform(key, (n,)) >= ratio).astype(jnp.float32)
    return m.at[0].set(1.0)  # keep >= 1 active


@pytest.mark.parametrize("topology", ["ring", "cluster", "star", "full", "random"])
@pytest.mark.parametrize("n", [6, 13, 226])
@pytest.mark.parametrize("ratio", [0.0, 0.4])
def test_neighbor_table_densifies_to_mixing_matrix(topology, n, ratio):
    """The sparse table is a REPRESENTATION change, not a semantics
    change: scattering (idx, wgt) back to (N, N) must reproduce
    ``mixing_matrix`` BITWISE (same 1/denom divisions, same kept set)."""
    key = jax.random.PRNGKey(n)
    adj = round_adjacency(topology, n, key, 7)
    active = _mask(jax.random.PRNGKey(n + 1), n, ratio)
    for B in (2, 7):
        idx, wgt = neighbor_table(adj, active, B)
        dense = np.asarray(mixing_matrix(adj, active, B))
        np.testing.assert_array_equal(
            np.asarray(densify_neighbor_table(idx, wgt)), dense,
            err_msg=f"{topology} n={n} B={B}",
        )


def test_neighbor_table_structure():
    """Slot 0 is always self; padding slots point at self with weight 0;
    inactive rows are exactly (self, 1.0).  From a dense adjacency the
    width is min(B, N)+1 — the trainer's candidate-list path narrows it
    to min(B, max_degree)+1 (see the candidates test below)."""
    n, B = 10, 3
    adj = ring_adjacency(n)  # degree 2 < B
    active = jnp.ones((n,)).at[4].set(0.0)
    idx, wgt = neighbor_table(adj, active, B)
    assert idx.shape == wgt.shape == (n, B + 1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.arange(n))
    i, w = np.asarray(idx), np.asarray(wgt)
    # padding: zero-weight slots always index self (gathers stay in-bounds
    # and contribute nothing)
    assert (i[w == 0] == np.broadcast_to(np.arange(n)[:, None], i.shape)[w == 0]).all()
    # inactive row 4: identity
    assert w[4, 0] == 1.0 and (w[4, 1:] == 0).all()
    # active rows sum to 1 with uniform 1/(deg+1) weights
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)


def test_neighbor_table_cap_keeps_lowest_index():
    """Mirror of ``test_mixing_matrix_cap_keeps_lowest_index`` on the
    sparse side: kept slots are the B lowest-index ACTIVE neighbours in
    ascending order."""
    n, B = 6, 2
    adj = full_adjacency(n)
    idx, wgt = neighbor_table(adj, jnp.ones((n,)), B)
    for i in range(n):
        kept = [int(j) for j, w in zip(np.asarray(idx[i, 1:]),
                                       np.asarray(wgt[i, 1:])) if w > 0]
        assert kept == [j for j in range(n) if j != i][:B], (i, kept)
    active = jnp.ones((n,)).at[0].set(0.0)
    idx, wgt = neighbor_table(adj, active, B)
    kept = [int(j) for j, w in zip(np.asarray(idx[5, 1:]),
                                   np.asarray(wgt[5, 1:])) if w > 0]
    assert kept == [1, 2], kept


@pytest.mark.parametrize("topology", ["ring", "cluster", "star", "full"])
@pytest.mark.parametrize("n", [2, 3, 6, 226])
def test_neighbor_candidates_match_dense_build(topology, n):
    """The static candidate-list path (what the trainer caches so the
    jitted round never materializes (N, N)) builds the SAME table as
    densifying the full adjacency."""
    cand = neighbor_candidates(topology, n)
    assert cand is not None
    cand_idx, cand_valid = cand
    adj = static_adjacency(topology, n)
    key = jax.random.PRNGKey(n)
    for ratio in (0.0, 0.5):
        active = _mask(key, n, ratio)
        via_cand = neighbor_table_from_candidates(cand_idx, cand_valid,
                                                  active, 7)
        via_dense = neighbor_table(adj, active, 7)
        np.testing.assert_array_equal(
            np.asarray(densify_neighbor_table(*via_cand)),
            np.asarray(densify_neighbor_table(*via_dense)),
            err_msg=f"{topology} n={n} ratio={ratio}",
        )


def test_neighbor_candidates_random_is_none():
    assert neighbor_candidates("random", 16) is None


def test_stacked_neighbor_table_matches_per_scenario():
    n, G, B = 12, 4, 3
    adjs = jnp.stack([
        ring_adjacency(n), cluster_adjacency(n, 4), star_adjacency(n),
        random_adjacency(jax.random.PRNGKey(0), n, 3),
    ])
    acts = jnp.stack([_mask(jax.random.PRNGKey(g), n, 0.3) for g in range(G)])
    si, sw = stacked_neighbor_table(adjs, acts, B)
    for g in range(G):
        ig, wg = neighbor_table(adjs[g], acts[g], B)
        np.testing.assert_array_equal(np.asarray(si[g]), np.asarray(ig))
        np.testing.assert_array_equal(np.asarray(sw[g]), np.asarray(wg))
