"""Pins for the personalization engines (``core/personalize.py``): the
scan engine, the vmap-batched serving engine, and the historical Python
loop must be the SAME fine-tune — bitwise — under every input layout the
service feeds them (padded histories, per-patient counts, cold-start
histories shorter than a batch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import personalize, personalize_batch, personalize_batch_fn
from repro.core.personalize import personalize_loop
from repro.models import LSTMModel
from repro.optim import adam

HIDDEN, L, STEPS = 4, 8, 6


@pytest.fixture(scope="module")
def setup():
    model = LSTMModel(history_len=L, hidden=HIDDEN).as_model()
    pop = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    P, M = 3, 12
    x = rng.normal(size=(P, M, L)).astype(np.float32)
    y = rng.normal(size=(P, M)).astype(np.float32)
    counts = np.array([M, 5, 1], np.int32)  # full, short, single-window
    keys = jax.random.split(jax.random.PRNGKey(0), P)
    return model, adam(5e-4), pop, x, y, counts, keys


def _bitwise(a, b):
    return all(
        (np.asarray(u) == np.asarray(v)).all()
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_scan_engine_matches_historical_loop(setup):
    """The lax.scan rewrite is a re-compilation, not a re-definition:
    same key stream, same draws, same params — bitwise."""
    model, opt, pop, x, y, counts, keys = setup
    for i in range(x.shape[0]):
        scan = personalize(model, opt, pop, keys[i], x[i], y[i],
                           steps=STEPS, count=counts[i])
        loop = personalize_loop(model, opt, pop, keys[i], x[i], y[i],
                                steps=STEPS, count=counts[i])
        assert _bitwise(scan, loop), f"patient {i} (count {counts[i]})"


def test_batched_rows_match_serial_per_patient(setup):
    """personalize_batch row i == personalize(..., keys[i], x[i], y[i],
    count=counts[i]) — batching over the cohort is invisible to each
    patient's numbers."""
    model, opt, pop, x, y, counts, keys = setup
    stacked = personalize_batch(model, opt, pop, keys, x, y, counts,
                                steps=STEPS)
    for i in range(x.shape[0]):
        row = jax.tree.map(lambda l: l[i], stacked)
        serial = personalize(model, opt, pop, keys[i], x[i], y[i],
                             steps=STEPS, count=counts[i])
        assert _bitwise(row, serial), f"patient {i} (count {counts[i]})"


def test_batch_fn_closure_matches_batch(setup):
    """The reusable serving closure (one jit cache) computes exactly
    personalize_batch, and its losses trace the fine-tune per step."""
    model, opt, pop, x, y, counts, keys = setup
    fn = personalize_batch_fn(model, opt, steps=STEPS, n_rows=x.shape[1])
    params, losses = fn(pop, keys, x, y, counts)
    assert losses.shape == (x.shape[0], STEPS)
    assert np.isfinite(np.asarray(losses)).all()
    assert _bitwise(params, personalize_batch(model, opt, pop, keys, x, y,
                                              counts, steps=STEPS))


def test_batch_size_clamped_to_short_history(setup):
    """The cold-start bugfix: batch_size > available rows trains on the
    whole history (clamped), bitwise the explicit batch_size=rows call —
    not on silently duplicated oversampling."""
    model, opt, pop, x, y, _, keys = setup
    sx, sy = x[0, :3], y[0, :3]
    big = personalize(model, opt, pop, keys[0], sx, sy,
                      steps=STEPS, batch_size=32)
    exact = personalize(model, opt, pop, keys[0], sx, sy,
                        steps=STEPS, batch_size=3)
    assert _bitwise(big, exact)
    # the loop twin clamps identically
    loop = personalize_loop(model, opt, pop, keys[0], sx, sy,
                            steps=STEPS, batch_size=32)
    assert _bitwise(big, loop)


def test_padding_rows_never_sampled(setup):
    """Rows past ``count`` are padding: poisoning them with NaN must not
    change the fine-tune (one NaN draw would wipe the params)."""
    model, opt, pop, x, y, counts, keys = setup
    i, c = 1, int(counts[1])
    poisoned_x = np.array(x[i])
    poisoned_y = np.array(y[i])
    poisoned_x[c:] = np.nan
    poisoned_y[c:] = np.nan
    out = personalize(model, opt, pop, keys[i], poisoned_x, poisoned_y,
                      steps=STEPS, count=c)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(out))
    clean = personalize(model, opt, pop, keys[i], x[i], y[i],
                        steps=STEPS, count=c)
    assert _bitwise(out, clean)


def test_fine_tune_actually_learns(setup):
    """Sanity beyond parity: on learnable (linear-teacher) patients the
    fine-tune trajectory ends well below where it started."""
    model, _, pop, _, _, _, keys = setup
    rng = np.random.default_rng(7)
    P, M = 2, 12
    x = rng.normal(size=(P, M, L)).astype(np.float32)
    w = rng.normal(size=(L,)).astype(np.float32)
    y = (x @ w).astype(np.float32)
    fn = personalize_batch_fn(model, adam(1e-2), steps=80, n_rows=M)
    _, losses = fn(pop, keys[:P], jnp.asarray(x), jnp.asarray(y),
                   jnp.full((P,), M, jnp.int32))
    losses = np.asarray(losses)
    assert np.isfinite(losses).all()
    assert (losses[:, -10:].mean(axis=1) < 0.7 * losses[:, :10].mean(axis=1)).all()
