"""Data-pipeline invariants feeding every trainer: windows are NaN-free
(zero-imputation happens BEFORE windowing, targets with missing raw
values are dropped), per-node counts equal the realizable window totals,
the padded federation tensors are clean, and normalization round-trips
through (fed.mean, fed.sd) at float32 resolution."""
import numpy as np
import pytest

from repro.data import load_federated_dataset
from repro.data.pipeline import batch_iterator, denormalize
from repro.data.synth import generate_dataset
from repro.data.windowing import make_windows, normalize, split_by_time, zscore_stats

L, H = 12, 6


def _fed(name="ohiot1dm", **kw):
    return load_federated_dataset(name, fast=True, **kw)


def test_windows_never_contain_nan():
    """Every split's X/y for every patient — and the stacked padded
    (N, M, L) federation tensors — must be finite: NaNs entering the
    compiled trainers would poison whole parameter trees."""
    fed = _fed()
    for p in fed.patients:
        for arr in (p.train_x, p.train_y, p.val_x, p.val_y, p.test_x,
                    p.test_y, p.test_y_raw):
            assert np.isfinite(arr).all()
    assert np.isfinite(fed.x).all() and np.isfinite(fed.y).all()


def test_counts_match_realizable_window_totals():
    """fed.counts[i] == the node's realizable train-window count:
    ``len(train_split) - L - H + 1`` sliding positions minus the windows
    whose RAW target sample is missing — recomputed here from the
    generator output, independently of make_windows."""
    name, n_pat = "ohiot1dm", 6
    fed = _fed(name, max_patients=n_pat)
    raw = generate_dataset(name, fast=True, max_patients=n_pat)
    assert fed.num_nodes == n_pat
    for i, series in enumerate(raw):
        tr, _, _ = split_by_time(series)
        m = len(tr) - L - H + 1
        tgt = np.arange(m) + L + H - 1
        realizable = int((~np.isnan(tr[tgt])).sum())
        assert fed.counts[i] == realizable
        assert fed.patients[i].train_x.shape == (realizable, L)
        assert fed.patients[i].train_y.shape == (realizable,)
    # padding: rows past counts[i] are zero, never garbage
    M = fed.x.shape[1]
    assert M == fed.counts.max()
    for i in range(fed.num_nodes):
        k = int(fed.counts[i])
        assert np.all(fed.x[i, k:] == 0.0) and np.all(fed.y[i, k:] == 0.0)
        np.testing.assert_array_equal(fed.x[i, :k], fed.patients[i].train_x)
        np.testing.assert_array_equal(fed.y[i, :k], fed.patients[i].train_y)


def test_normalization_roundtrip_float32_resolution():
    """Denormalizing the stored normalized targets with (fed.mean,
    fed.sd) reproduces the raw mg/dL targets to float32 resolution
    (|x| <= 400 -> eps ~ 3e-5); the z-scored train tensors map back into
    the CGM range the same way."""
    fed = _fed()
    atol = 400 * np.finfo(np.float32).eps  # ~4.9e-5 mg/dL
    checked = 0
    for p in fed.patients:
        assert p.mean == fed.mean and p.sd == fed.sd
        if len(p.test_y) == 0:
            continue
        rt = denormalize(p.test_y, fed.mean, fed.sd)
        np.testing.assert_allclose(rt, p.test_y_raw, atol=atol, rtol=0)
        checked += len(p.test_y)
    assert checked > 0
    # round-trip of normalize itself on a raw series (NaNs -> 0 pinned)
    s = np.array([40.0, 155.5, np.nan, 400.0], np.float32)
    norm = normalize(s, fed.mean, fed.sd)
    assert norm.dtype == np.float32
    assert norm[2] == 0.0  # paper: missing -> zero AFTER normalization
    rt = denormalize(norm[[0, 1, 3]], fed.mean, fed.sd)
    np.testing.assert_allclose(rt, s[[0, 1, 3]], atol=atol, rtol=0)


def test_make_windows_target_validity():
    """Windows whose raw target is NaN are dropped; windows with NaN
    HISTORY are kept as zeros (the paper's imputation policy); an
    all-too-short series yields empty (0, L) arrays."""
    n = 40
    raw = np.linspace(100, 200, n).astype(np.float32)
    raw[L + H - 1] = np.nan   # kills exactly window 0's target
    raw[0] = np.nan           # history NaN: window 0..L-1 keep zeros
    mean, sd = zscore_stats([raw])
    norm = normalize(raw, mean, sd)
    X, y, y_raw = make_windows(norm, raw, L, H)
    m_full = n - L - H + 1
    assert X.shape == (m_full - 1, L)
    assert np.isfinite(X).all() and np.isfinite(y).all()
    # the dropped window is the one targeting the NaN sample
    tgt = np.arange(m_full) + L + H - 1
    kept = ~np.isnan(raw[tgt])
    np.testing.assert_array_equal(y_raw, raw[tgt][kept])
    # short series
    Xe, ye, ye_raw = make_windows(norm[: L + H - 1], raw[: L + H - 1], L, H)
    assert Xe.shape == (0, L) and ye.shape == (0,) and ye_raw.shape == (0,)


def test_zscore_stats_nan_aware_and_batch_iterator():
    """Dataset stats ignore NaNs (a dropout-heavy patient doesn't poison
    the z-score) and the batch iterator only ever yields full batches of
    real rows."""
    a = np.array([100.0, np.nan, 200.0], np.float32)
    b = np.array([np.nan, 150.0], np.float32)
    mean, sd = zscore_stats([a, b])
    np.testing.assert_allclose(mean, 150.0)
    assert sd > 1.0
    fed = _fed(max_patients=2)
    p = fed.patients[0]
    it = batch_iterator(p.train_x, p.train_y, batch_size=8, seed=0)
    for _ in range(3):
        bx, by = next(it)
        assert bx.shape == (8, L) and by.shape == (8,)
        assert np.isfinite(bx).all() and np.isfinite(by).all()
