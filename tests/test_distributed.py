"""Sharded-gossip + gossip-DP + small-mesh dry-run integration tests.

These spawn subprocesses with XLA_FLAGS for multi-device CPU (the main
test process must keep the default single device).  The ``multidevice``
marker routes them to CI's forced-8-device job (`pytest -m
multidevice`); a plain local `pytest` run still executes everything."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.multidevice
def test_sharded_ring_gossip_matches_reference():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_gossip
        from repro.core.topology import mixing_matrix, ring_adjacency
        from repro.utils.pytree import tree_weighted_mix
        mesh = jax.make_mesh((8,), ("data",))
        N, D = 8, 96
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (N, D)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (N, 3, 5))}
        active = jnp.ones((N,))
        out = jax.jit(make_sharded_gossip(mesh, ("data",), "ring"))(w, active)
        ref = tree_weighted_mix(w, mixing_matrix(ring_adjacency(N), active, 7))
        for k in w:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=1e-5)
        print("RING_OK")
    """))


@pytest.mark.multidevice
def test_sharded_general_gossip_matches_reference():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_gossip
        from repro.core.topology import mixing_matrix, cluster_adjacency
        from repro.utils.pytree import tree_weighted_mix
        mesh = jax.make_mesh((8,), ("data",))
        N, D = 8, 64
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (N, D))}
        active = (jax.random.uniform(jax.random.PRNGKey(2), (N,)) > 0.4).astype(jnp.float32)
        mix = mixing_matrix(cluster_adjacency(N, 4), active, 3)
        out = jax.jit(make_sharded_gossip(mesh, ("data",), "cluster"))(w, mix)
        ref = tree_weighted_mix(w, mix)
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(ref["a"]), rtol=2e-5, atol=1e-5)
        print("GENERAL_OK")
    """))


@pytest.mark.multidevice
def test_ring_gossip_multi_node_shards_match_reference():
    """BUGFIX PIN: with >1 node per shard the ring body must average row
    i with its ACTUAL ring neighbours i±1 — the pre-fix code ppermuted
    whole shard blocks, handing interior rows the params of rows
    i±nodes_per_shard.  2 nodes/shard (N=8 over 4 shards) against the
    dense mixing-matrix ring, all-active and partially-active."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_gossip
        from repro.core.topology import mixing_matrix, ring_adjacency
        from repro.utils.pytree import tree_weighted_mix
        mesh = jax.make_mesh((4,), ("data",))  # 8 nodes -> 2 per shard
        N, D = 8, 24
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (N, D)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (N, 3, 5))}
        gossip = jax.jit(make_sharded_gossip(mesh, ("data",), "ring"))
        for label, active in (
            ("all-active", jnp.ones((N,))),
            ("partial", jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.float32)),
        ):
            out = gossip(w, active)
            ref = tree_weighted_mix(w, mixing_matrix(ring_adjacency(N), active, 7))
            for k in w:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=1e-5,
                    err_msg=f"{label}/{k}")
            # inactive rows bit-exact
            idx = np.where(np.asarray(active) == 0)[0]
            for k in w:
                np.testing.assert_array_equal(
                    np.asarray(out[k])[idx], np.asarray(w[k])[idx])
        print("RING_BLOCK_OK")
    """, devices=4))


@pytest.mark.multidevice
def test_grid_sharded_gossip_mix_matches_dense():
    """The 2-D (grid, node) sweep mesh: ONE shard_map with P("grid", ...)
    in_specs mixes every scenario's federation — each scenario g must
    match the dense per-scenario contraction, for BOTH collective
    schedules, with bit-exact inactive rows; and the explicit grid call
    must agree with vmap(spmd_axis_name="grid") over the per-scenario
    call (the trainer's swept-sharded lowering)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_gossip_mix
        from repro.core.topology import mixing_matrix, random_adjacency
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh(4, 8, grid_width=2, node_width=4)
        assert dict(mesh.shape) == {"grid": 2, "node": 4}
        G, N, D = 4, 8, 48
        k = jax.random.split(jax.random.PRNGKey(0), 3)
        w = {"a": jax.random.normal(k[0], (G, N, D)),
             "b": jax.random.normal(k[1], (G, N, 3, 5))}
        active = (jax.random.uniform(k[2], (G, N)) > 0.4).astype(jnp.float32)
        mix = jnp.stack([
            mixing_matrix(random_adjacency(jax.random.PRNGKey(g), N, 3),
                          active[g], 3)
            for g in range(G)
        ])
        for impl in ("allgather", "psum"):
            out = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(
                ww, mm, aa, mesh=mesh, impl=impl))(w, mix, active)
            batched = jax.jit(jax.vmap(
                lambda ww, mm, aa: sharded_gossip_mix(
                    ww, mm, aa, mesh=mesh, impl=impl),
                spmd_axis_name="grid"))(w, mix, active)
            for kk in w:
                flat = w[kk].reshape(G, N, -1)
                ref = jnp.einsum("gnm,gmd->gnd", mix, flat).reshape(w[kk].shape)
                np.testing.assert_allclose(
                    np.asarray(out[kk]), np.asarray(ref), atol=1e-5)
                np.testing.assert_allclose(
                    np.asarray(batched[kk]), np.asarray(ref), atol=1e-5)
                idx = np.where(np.asarray(active) == 0)
                np.testing.assert_array_equal(
                    np.asarray(out[kk])[idx], np.asarray(w[kk])[idx])
        print("GRID_MIX_OK")
    """))


def test_sharded_gossip_mix_shape_mismatch_fails_at_trace():
    """Mismatched scenario grids must fail with readable shapes at trace
    time, not inside the collective (single-device (1, 1) sweep mesh)."""
    import jax.numpy as jnp

    from repro.core.distributed import sharded_gossip_mix
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(3, 8, grid_width=1, node_width=1)
    w = {"a": jnp.ones((3, 8, 4))}
    with pytest.raises(ValueError, match="leading dim"):
        sharded_gossip_mix(w, jnp.stack([jnp.eye(8)] * 4), mesh=mesh)
    # a 2-D mix on a grid mesh is a mis-shaped call, not a silent demotion
    with pytest.raises(ValueError, match="mixing matrix"):
        sharded_gossip_mix(w, jnp.eye(8), mesh=mesh, grid_axis="grid")


@pytest.mark.multidevice
def test_sharded_ring_gossip_respects_inactive():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_gossip
        mesh = jax.make_mesh((8,), ("data",))
        N, D = 8, 16
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (N, D))}
        active = jnp.zeros((N,)).at[0].set(1.0)
        out = jax.jit(make_sharded_gossip(mesh, ("data",), "ring"))(w, active)
        # inactive nodes keep their rows bit-exact
        np.testing.assert_array_equal(np.asarray(out["a"])[1:], np.asarray(w["a"])[1:])
        print("INACTIVE_OK")
    """))


@pytest.mark.multidevice
def test_mixer_parity_tree_kernel_sharded():
    """The three interchangeable gossip mixers agree on random
    row-stochastic matrices with inactive nodes (the sharded one under a
    real 8-device node-sharded mesh)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.gossip import gossip_mix_tree, gossip_mix_kernel, sharded_gossip_mix
        from repro.core.topology import mixing_matrix, random_adjacency
        N, D = 8, 96
        k = jax.random.split(jax.random.PRNGKey(0), 4)
        w = {"a": jax.random.normal(k[0], (N, D)),
             "b": jax.random.normal(k[1], (N, 3, 7))}
        active = (jax.random.uniform(k[2], (N,)) > 0.4).astype(jnp.float32)
        mix = mixing_matrix(random_adjacency(jax.random.PRNGKey(7), N, 3), active, 3)
        np.testing.assert_allclose(np.asarray(mix).sum(1), 1.0, atol=1e-5)
        a = gossip_mix_tree(w, mix)
        b = gossip_mix_kernel(w, mix, active)
        c = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(ww, mm, aa))(w, mix, active)
        for kk in w:
            np.testing.assert_allclose(np.asarray(a[kk]), np.asarray(b[kk]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(a[kk]), np.asarray(c[kk]), atol=1e-5)
            # inactive rows: kernel and sharded paths copy bit-exact
            idx = np.where(np.asarray(active) == 0)[0]
            np.testing.assert_array_equal(np.asarray(b[kk])[idx], np.asarray(w[kk])[idx])
            np.testing.assert_array_equal(np.asarray(c[kk])[idx], np.asarray(w[kk])[idx])
        print("MIXER_PARITY_OK")
    """))


@pytest.mark.multidevice
def test_sharded_mixer_trains_like_tree_mixer():
    """GluADFL end-to-end with mixer="sharded" (scan engine, N nodes over
    8 devices) matches the tree mixer's population model."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_l2_norm, tree_sub
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8, 40, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((8,), 40, np.int32)
        cfg = FLConfig(topology="ring", num_nodes=8, rounds=6, inactive_ratio=0.25)
        def train(mixer, sigma=0.0):
            tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                         mixer=mixer, dp_noise_sigma=sigma)
            return tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8)
        p_tree, h_tree, _ = train("tree")
        p_shard, h_shard, _ = train("sharded")
        assert len(h_tree) == len(h_shard) == 6
        assert float(tree_l2_norm(tree_sub(p_tree, p_shard))) < 1e-4
        for a, b in zip(h_tree, h_shard):
            assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)
        # DP broadcast noise: the composed shard_map restore path matches
        # the tree mixer's composed path (same key stream -> same noise)
        p_tree_dp, _, _ = train("tree", sigma=0.05)
        p_shard_dp, _, _ = train("sharded", sigma=0.05)
        assert float(tree_l2_norm(tree_sub(p_tree_dp, p_shard_dp))) < 1e-4
        assert float(tree_l2_norm(tree_sub(p_tree_dp, p_tree))) > 1e-4  # noise bites
        print("SHARDED_TRAIN_OK")
    """))


@pytest.mark.multidevice
def test_mini_dryrun_dense_and_moe():
    """End-to-end mini dry-run: reduced archs on an 8-device (4,2) mesh,
    lower + compile + cost analysis — the same path as the 512-device
    production dry-run."""
    print(_run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.arch import build_arch
        from repro.arch.common import init_train_state, make_train_step
        from repro.arch.sharding import param_pspecs
        from repro.config import get_arch_config
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for name in ("yi-6b", "granite-moe-1b-a400m", "mamba2-370m"):
            cfg = get_arch_config(name).reduced()
            arch = build_arch(cfg)
            pspec = jax.eval_shape(arch.init_params, jax.random.PRNGKey(0))
            prules = param_pspecs(pspec, axis_size=2)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), prules,
                               is_leaf=lambda x: isinstance(x, P))
            step = make_train_step(arch.loss_fn, num_microbatches=2, lr=1e-3,
                                   data_axes=("data",))
            st_spec = jax.eval_shape(init_train_state, pspec)
            from repro.arch.common import TrainState
            st_sh = TrainState(params=psh, m=psh, v=psh, step=NamedSharding(mesh, P()))
            batch = arch.input_specs("train_4k", override_batch=8, override_seq=32)
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, P("data", *([None]*(s.ndim-1)))) if s.ndim else NamedSharding(mesh, P()), batch)
            with mesh:
                fn = jax.jit(step, in_shardings=(st_sh, bsh))
                compiled = fn.lower(st_spec, batch).compile()
            from repro.utils.compat import cost_analysis
            cost = cost_analysis(compiled)
            assert cost.get("flops", 0) > 0, name
            print("MINI_DRYRUN_OK", name, int(cost["flops"]))
    """))


def test_gossip_dp_schedule():
    from repro.core.gossip_dp import GossipDPSchedule

    sched = GossipDPSchedule("random", 8, comm_batch=3, mix_every=4)
    assert [sched.should_mix(s) for s in range(8)] == [False, False, False, True] * 2
    m1 = sched.next_mix()
    m2 = sched.next_mix()
    import numpy as np

    assert m1.shape == (8, 8)
    np.testing.assert_allclose(np.asarray(m1).sum(1), 1.0, atol=1e-5)
    assert not np.allclose(np.asarray(m1), np.asarray(m2))  # time-varying


@pytest.mark.multidevice
def test_psum_gossip_matches_allgather_and_reference():
    """gossip_impl="psum" (reduce-scatter of local contributions) matches
    the allgather impl AND the single-device reference numerically on 8
    forced CPU devices, with bit-exact inactive rows."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.gossip import gossip_mix_tree, sharded_gossip_mix
        from repro.core.topology import mixing_matrix, random_adjacency
        N, D = 8, 96
        k = jax.random.split(jax.random.PRNGKey(0), 4)
        w = {"a": jax.random.normal(k[0], (N, D)),
             "b": jax.random.normal(k[1], (N, 3, 7))}
        active = (jax.random.uniform(k[2], (N,)) > 0.4).astype(jnp.float32)
        mix = mixing_matrix(random_adjacency(jax.random.PRNGKey(7), N, 3), active, 3)
        ref = gossip_mix_tree(w, mix)
        ag = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(ww, mm, aa, impl="allgather"))(w, mix, active)
        ps = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(ww, mm, aa, impl="psum"))(w, mix, active)
        for kk in w:
            np.testing.assert_allclose(np.asarray(ref[kk]), np.asarray(ag[kk]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(ref[kk]), np.asarray(ps[kk]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(ag[kk]), np.asarray(ps[kk]), atol=1e-5)
            idx = np.where(np.asarray(active) == 0)[0]
            np.testing.assert_array_equal(np.asarray(ps[kk])[idx], np.asarray(w[kk])[idx])
        print("PSUM_PARITY_OK")
    """))


@pytest.mark.multidevice
def test_psum_impl_trains_like_allgather_impl():
    """GluADFL end-to-end: mixer="sharded" with gossip_impl="psum" (scan
    engine + in-scan streaming eval) matches the allgather impl's
    population model, losses, and eval records."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_l2_norm, tree_sub
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8, 40, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((8,), 40, np.int32)
        vx = rng.normal(size=(16, 12)).astype(np.float32)
        vy = rng.normal(size=(16,)).astype(np.float32)
        cfg = FLConfig(topology="random", num_nodes=8, rounds=6,
                       comm_batch=3, inactive_ratio=0.25)
        def train(impl):
            tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                         mixer="sharded", gossip_impl=impl)
            return tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8,
                            eval_every=3, val_data=(vx, vy), chunk=6)
        p_ag, h_ag, _ = train("allgather")
        p_ps, h_ps, _ = train("psum")
        assert len(h_ag) == len(h_ps) == 6
        assert float(tree_l2_norm(tree_sub(p_ag, p_ps))) < 1e-4
        for a, b in zip(h_ag, h_ps):
            assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)
            assert ("val_rmse" in a) == ("val_rmse" in b)
            if "val_rmse" in a:
                assert abs(a["val_rmse"] - b["val_rmse"]) < 1e-4, (a, b)
        assert sum("val_rmse" in h for h in h_ag) == 2
        print("PSUM_TRAIN_OK")
    """))


@pytest.mark.multidevice
def test_gossip_dp_psum_scatter_matches_full_psum():
    """gossip_mix_params impl="psum" (psum_scatter, memory-scaled) agrees
    with the impl="allgather" baseline (full psum + slice) for
    node-replicated params on a (node, model) mesh."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.gossip_dp import gossip_mix_params
        from repro.core.topology import mixing_matrix, random_adjacency
        mesh = jax.make_mesh((4, 2), ("node", "model"))
        k = jax.random.split(jax.random.PRNGKey(0), 2)
        params = {"w": jax.random.normal(k[0], (4, 8, 6)), "b": jnp.zeros((3,))}
        mix = mixing_matrix(random_adjacency(jax.random.PRNGKey(3), 4, 2),
                            jnp.ones((4,)), 2)
        pa = jax.jit(lambda p: gossip_mix_params(p, mix, mesh, ("node",), impl="allgather"))(params)
        pb = jax.jit(lambda p: gossip_mix_params(p, mix, mesh, ("node",), impl="psum"))(params)
        for kk in params:
            np.testing.assert_allclose(np.asarray(pa[kk]), np.asarray(pb[kk]), atol=1e-5)
        print("GOSSIP_DP_PSUM_OK")
    """))


def test_bad_gossip_impl_rejected():
    """Unknown gossip_impl must raise at construction, not at trace."""
    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.models import LSTMModel
    from repro.optim import sgd

    with pytest.raises(ValueError, match="gossip_impl"):
        GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2),
                FLConfig(num_nodes=4, rounds=1), gossip_impl="ringz")


@pytest.mark.multidevice
def test_gossip_dp_ring_mix_on_mesh():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.gossip_dp import ring_mix_params
        mesh = jax.make_mesh((4, 2), ("node", "model"))
        params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((3,))}
        out = jax.jit(lambda p: ring_mix_params(p, mesh, ("node",)))(params)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, atol=1e-6)
        print("GOSSIP_DP_OK")
    """))
