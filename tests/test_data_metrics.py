"""Data pipeline + clinical metric tests."""
import numpy as np
import pytest

from repro.data import DATASET_SPECS, generate_patient_series, load_federated_dataset
from repro.data.windowing import make_windows, normalize, split_by_time, zscore_stats
from repro.metrics import all_metrics, grmse, rmse, time_lag_minutes


def test_dataset_specs_match_paper_table1():
    assert DATASET_SPECS["ohiot1dm"].num_patients == 12
    assert DATASET_SPECS["abc4d"].num_patients == 25
    assert DATASET_SPECS["ctr3"].num_patients == 30
    assert DATASET_SPECS["replace-bg"].num_patients == 226
    assert DATASET_SPECS["replace-bg"].num_days == 251


@pytest.mark.parametrize("name", list(DATASET_SPECS))
def test_synth_statistics_calibrated(name):
    """Generated population must land near Table 1's mean/SD (±15%)."""
    spec = DATASET_SPECS[name]
    n = min(spec.num_patients, 12)
    series = [generate_patient_series(spec, p, days=10) for p in range(n)]
    means = [np.nanmean(s) for s in series]
    sds = [np.nanstd(s) for s in series]
    assert abs(np.mean(means) - spec.mean_bg) < 0.15 * spec.mean_bg, (np.mean(means), spec.mean_bg)
    assert abs(np.mean(sds) - spec.sd_bg) < 0.25 * spec.sd_bg, (np.mean(sds), spec.sd_bg)


def test_synth_range_and_missingness():
    spec = DATASET_SPECS["abc4d"]
    s = generate_patient_series(spec, 0, days=10)
    valid = s[~np.isnan(s)]
    assert valid.min() >= 40.0 and valid.max() <= 400.0
    assert 0 < np.isnan(s).mean() < 0.25


def test_synth_deterministic():
    spec = DATASET_SPECS["ctr3"]
    a = generate_patient_series(spec, 3, days=3)
    b = generate_patient_series(spec, 3, days=3)
    np.testing.assert_array_equal(a, b)
    c = generate_patient_series(spec, 4, days=3)
    assert not np.array_equal(np.nan_to_num(a), np.nan_to_num(c))


def test_split_fractions():
    s = np.arange(1000, dtype=np.float32)
    tr, va, te = split_by_time(s)
    assert len(tr) == 600 and len(va) == 200 and len(te) == 200
    np.testing.assert_array_equal(np.concatenate([tr, va, te]), s)


def test_windows_drop_missing_targets():
    s = np.arange(100, dtype=np.float32)
    raw = s.copy()
    raw[50] = np.nan
    norm = np.nan_to_num(raw)
    x, y, y_raw = make_windows(norm, raw, history_len=12, horizon=6)
    # the window whose target is index 50 must be dropped
    assert len(x) == 100 - 12 - 6 + 1 - 1
    assert not np.isnan(y_raw).any()


def test_window_alignment():
    """Target is exactly H steps after the last history sample."""
    s = np.arange(60, dtype=np.float32)
    x, y, y_raw = make_windows(s, s, history_len=12, horizon=6)
    np.testing.assert_array_equal(x[0], np.arange(12))
    assert y[0] == 12 + 6 - 1  # index L+H-1
    assert y_raw[0] == y[0]


def test_federated_load_shapes(fed_ohio):
    assert fed_ohio.num_nodes == 12
    assert fed_ohio.x.ndim == 3 and fed_ohio.x.shape[2] == 12
    assert (fed_ohio.counts > 0).all()
    # padding zeros beyond counts
    i = int(np.argmin(fed_ohio.counts))
    assert np.allclose(fed_ohio.x[i, fed_ohio.counts[i]:], 0.0)


def test_normalization_zero_imputation(fed_ohio):
    # normalized train data has |mean| small and missing -> exactly 0
    assert abs(np.mean([p.train_x.mean() for p in fed_ohio.patients])) < 0.5


def test_grmse_penalizes_clinically_dangerous_errors():
    """Overestimating in hypoglycemia must cost more than the same
    error in euglycemia (Del Favero penalty)."""
    y_hypo = np.full(10, 55.0)
    y_eu = np.full(10, 120.0)
    over = 30.0
    assert grmse(y_hypo, y_hypo + over) > grmse(y_eu, y_eu + over)
    # underestimation in hyperglycemia likewise
    y_hyper = np.full(10, 260.0)
    assert grmse(y_hyper, y_hyper - over) > grmse(y_eu, y_eu - over)


def test_time_lag_detects_shift():
    t = np.arange(500)
    y = np.sin(t / 20.0) * 50 + 150
    yhat = np.roll(y, 4)  # prediction lags truth by 4 samples = 20 min
    assert time_lag_minutes(y, yhat) == pytest.approx(20.0)
    assert time_lag_minutes(y, y) == 0.0


def test_all_metrics_keys():
    y = np.random.default_rng(0).uniform(60, 300, 100)
    m = all_metrics(y, y + 5)
    assert set(m) == {"rmse", "mard", "mae", "grmse", "time_lag"}
    assert m["rmse"] == pytest.approx(5.0)
