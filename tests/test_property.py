"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.config import FLConfig
from repro.core import GluADFL, SweepGrid
from repro.core.async_sched import bernoulli_active, markov_active
from repro.core.topology import (
    cluster_adjacency,
    full_adjacency,
    mixing_matrix,
    random_adjacency,
    ring_adjacency,
)
from repro.models import LSTMModel
from repro.optim import sgd
from repro.kernels.ops import gossip_mix
from repro.kernels.ref import gossip_mix_ref
from repro.metrics import grmse, mae, mard, rmse
from repro.utils.pytree import tree_to_vector, tree_weighted_mix, vector_to_tree

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(2, 32),
    topo=st.sampled_from(["ring", "cluster", "full", "random"]),
    comm_batch=st.integers(1, 8),
    inactive=st.floats(0.0, 0.9),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_mixing_matrix_always_row_stochastic(n, topo, comm_batch, inactive, seed):
    """Invariant: every round's mixing matrix is row-stochastic with
    non-negative entries, whatever the topology/activity — gossip never
    creates or destroys parameter mass."""
    key = jax.random.PRNGKey(seed)
    if topo == "ring":
        adj = ring_adjacency(n)
    elif topo == "cluster":
        adj = cluster_adjacency(n, 4)
    elif topo == "full":
        adj = full_adjacency(n)
    else:
        adj = random_adjacency(key, n, min(comm_batch, n - 1))
    active = (jax.random.uniform(key, (n,)) >= inactive).astype(jnp.float32)
    m = np.asarray(mixing_matrix(adj, active, comm_batch))
    assert (m >= -1e-7).all()
    np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-5)


@given(
    n=st.integers(2, 16),
    d=st.integers(1, 300),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_gossip_preserves_mean(n, d, seed):
    """Invariant: with a DOUBLY-stochastic mix (symmetric topologies,
    all active), the federation mean parameter vector is conserved —
    the fixed point of Algorithm 1 is consensus on the average."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, d))
    # symmetric doubly-stochastic mix: Metropolis weights on a ring
    adj = np.asarray(ring_adjacency(n))
    m = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                m[i, j] = 1.0 / 3.0
        m[i, i] = 1.0 - m[i].sum()
    out = gossip_mix_ref(jnp.asarray(m, jnp.float32), w)
    np.testing.assert_allclose(
        np.asarray(out).mean(axis=0), np.asarray(w).mean(axis=0), atol=1e-4
    )


@given(n=st.integers(2, 12), d=st.integers(1, 200), seed=st.integers(0, 500))
@settings(**SETTINGS)
def test_gossip_kernel_equals_oracle(n, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    mix = jax.nn.softmax(jax.random.normal(keys[0], (n, n)), axis=-1)
    w = jax.random.normal(keys[1], (n, d))
    active = (jax.random.uniform(keys[2], (n,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gossip_mix(mix, w, active)),
        np.asarray(gossip_mix_ref(mix, w, active)),
        atol=1e-5,
    )


@given(
    shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=5),
    seed=st.integers(0, 100),
)
@settings(**SETTINGS)
def test_tree_vector_roundtrip(shapes, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s)
            for i, s in enumerate(shapes)}
    vec = tree_to_vector(tree)
    back = vector_to_tree(vec, tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(tree[k]), np.asarray(back[k]), atol=1e-6)


@given(
    m=st.integers(2, 200),
    scale=st.floats(1.0, 100.0),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_metric_invariants(m, scale, seed):
    """RMSE >= MAE; gRMSE >= RMSE (penalty >= 1); all zero at y == yhat."""
    rng = np.random.default_rng(seed)
    y = rng.uniform(40, 400, m)
    yhat = y + rng.normal(0, scale, m)
    assert rmse(y, yhat) >= mae(y, yhat) - 1e-9
    assert grmse(y, yhat) >= rmse(y, yhat) - 1e-6
    assert rmse(y, y) == 0 and mae(y, y) == 0 and mard(y, y) == 0


@given(
    perm_seed=st.integers(0, 100),
    n=st.integers(4, 24),
)
@settings(**SETTINGS)
def test_gossip_equivariance_under_node_relabeling(perm_seed, n):
    """Permuting nodes and mixing = mixing and permuting (the gossip
    primitive has no hidden node-order dependence)."""
    rng = np.random.default_rng(perm_seed)
    perm = rng.permutation(n)
    d = 17
    w = rng.normal(size=(n, d)).astype(np.float32)
    mix = np.asarray(jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(perm_seed), (n, n)), axis=-1))
    out = np.asarray(gossip_mix_ref(jnp.asarray(mix), jnp.asarray(w)))
    out_perm = np.asarray(
        gossip_mix_ref(jnp.asarray(mix[np.ix_(perm, perm)]), jnp.asarray(w[perm]))
    )
    np.testing.assert_allclose(out[perm], out_perm, atol=1e-5)


# ----------------------------------------------------------------------
# scenario-axis invariants (the sweep's markov / skew / dp plumbing)
# ----------------------------------------------------------------------

@given(
    n=st.integers(2, 32),
    ratio=st.floats(0.0, 1.0),
    p_stay_active=st.floats(0.0, 1.0),
    p_stay_inactive=st.floats(0.0, 1.0),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_schedules_keep_one_active_at_traced_params(
    n, ratio, p_stay_active, p_stay_inactive, seed
):
    """Invariant: BOTH participation schedules — bernoulli at a TRACED
    inactive ratio (the sweep axis) and markov at traced stickiness,
    from any previous mask — yield binary masks with >= 1 active node
    (a silent all-inactive round would freeze the federation), and the
    resulting mixing matrix stays row-stochastic."""
    key = jax.random.PRNGKey(seed)
    bern = jax.jit(lambda r: bernoulli_active(key, n, r))(jnp.float32(ratio))
    prev = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,)) > 0.5)
    prev = prev.astype(jnp.float32)
    mark = jax.jit(
        lambda a, b: markov_active(key, prev, a, b)
    )(jnp.float32(p_stay_active), jnp.float32(p_stay_inactive))
    for mask in (bern, mark):
        m = np.asarray(mask)
        assert set(np.unique(m)).issubset({0.0, 1.0})
        assert m.sum() >= 1.0
        mm = np.asarray(mixing_matrix(ring_adjacency(n), mask, 3))
        assert (mm >= -1e-7).all()
        np.testing.assert_allclose(mm.sum(axis=1), 1.0, atol=1e-5)


@given(
    n=st.integers(2, 8),
    d=st.integers(1, 64),
    inactive=st.floats(0.0, 0.8),
    seed=st.integers(0, 500),
)
@settings(max_examples=10, deadline=None)
def test_dp_noise_off_is_bitwise_clean_gossip(n, d, inactive, seed):
    """Invariant: the DP gossip composition at a TRACED sigma=0 (what a
    sigma=0 scenario of a dp-armed sweep contracts) is BITWISE the plain
    noise-free mix — zero noise is exactly zero, never a perturbation —
    while any positive sigma perturbs some active node."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    model = LSTMModel(history_len=4, hidden=4).as_model()
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=n, comm_batch=3))
    premix = {
        "w": jax.random.normal(keys[0], (n, d)),
        "b": jax.random.normal(keys[1], (n, 1 + d % 3)),
    }
    active = bernoulli_active(keys[2], n, inactive)
    mix = mixing_matrix(ring_adjacency(n), active, 3)
    k_dp = keys[3]

    dp = jax.jit(
        lambda sig: tr._gossip_base(premix, mix, active, k_dp, None, sig)
    )
    clean = tr._plain_mix(premix, mix, None, active)
    for leaf_dp, leaf_clean in zip(
        jax.tree.leaves(dp(jnp.float32(0.0))), jax.tree.leaves(clean)
    ):
        np.testing.assert_array_equal(np.asarray(leaf_dp), np.asarray(leaf_clean))
    noisy = dp(jnp.float32(0.1))
    diff = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(noisy), jax.tree.leaves(clean))
    )
    assert diff > 0.0


@given(
    n_topo=st.integers(1, 2),
    n_ratio=st.integers(1, 2),
    n_seed=st.integers(1, 2),
    schedules=st.sampled_from(
        [None, ("bernoulli",), ("markov",), ("bernoulli", "markov")]
    ),
    skews=st.one_of(
        st.none(), st.lists(st.floats(0.0, 1.0), min_size=1, max_size=2)
    ),
    dp_sigmas=st.one_of(
        st.none(), st.lists(st.floats(0.0, 0.5), min_size=1, max_size=2)
    ),
)
@settings(**SETTINGS)
def test_sweep_grid_axes_product_layout(
    n_topo, n_ratio, n_seed, schedules, skews, dp_sigmas
):
    """Invariant: any combination of armed axes builds a grid of exactly
    the cross-product size, every armed axis is a (G,) float32 array,
    and ``label_dict(g)`` agrees with the g-th cross-product entry."""
    topos = ("ring", "random")[:n_topo]
    ratios = tuple(0.2 * i for i in range(n_ratio))
    seeds = tuple(range(n_seed))
    grid = SweepGrid.build(
        topos, ratios, seeds, num_nodes=4, schedules=schedules,
        skews=tuple(skews) if skews else None,
        dp_sigmas=tuple(dp_sigmas) if dp_sigmas else None,
    )
    armed = any(a is not None for a in (schedules, skews, dp_sigmas))
    g_expect = (
        n_topo * n_ratio * n_seed
        * len(schedules or ("bernoulli",))
        * len(skews or [0.0])
        * len(dp_sigmas or [0.0])
    )
    assert grid.size == g_expect
    for ax, vals in (
        (grid.markov, schedules), (grid.skew, skews), (grid.dp_sigma, dp_sigmas)
    ):
        if vals is None:
            assert ax is None
        else:
            assert ax.shape == (grid.size,) and ax.dtype == jnp.float32
    g = 0
    for t in topos:
        for r in ratios:
            for sc in (schedules or ("bernoulli",)):
                for sk in (skews or [0.0]):
                    for dp_s in (dp_sigmas or [0.0]):
                        for s in seeds:
                            lab = grid.label_dict(g)
                            assert lab["topology"] == t
                            assert lab["inactive_ratio"] == pytest.approx(r)
                            if armed:
                                assert lab["schedule"] == sc
                                assert lab["skew"] == pytest.approx(sk)
                                assert lab["dp_sigma"] == pytest.approx(dp_s)
                            assert lab["seed"] == s
                            g += 1
    assert g == grid.size
