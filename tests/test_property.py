"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.topology import (
    cluster_adjacency,
    full_adjacency,
    mixing_matrix,
    random_adjacency,
    ring_adjacency,
)
from repro.kernels.ops import gossip_mix
from repro.kernels.ref import gossip_mix_ref
from repro.metrics import grmse, mae, mard, rmse
from repro.utils.pytree import tree_to_vector, tree_weighted_mix, vector_to_tree

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(2, 32),
    topo=st.sampled_from(["ring", "cluster", "full", "random"]),
    comm_batch=st.integers(1, 8),
    inactive=st.floats(0.0, 0.9),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_mixing_matrix_always_row_stochastic(n, topo, comm_batch, inactive, seed):
    """Invariant: every round's mixing matrix is row-stochastic with
    non-negative entries, whatever the topology/activity — gossip never
    creates or destroys parameter mass."""
    key = jax.random.PRNGKey(seed)
    if topo == "ring":
        adj = ring_adjacency(n)
    elif topo == "cluster":
        adj = cluster_adjacency(n, 4)
    elif topo == "full":
        adj = full_adjacency(n)
    else:
        adj = random_adjacency(key, n, min(comm_batch, n - 1))
    active = (jax.random.uniform(key, (n,)) >= inactive).astype(jnp.float32)
    m = np.asarray(mixing_matrix(adj, active, comm_batch))
    assert (m >= -1e-7).all()
    np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-5)


@given(
    n=st.integers(2, 16),
    d=st.integers(1, 300),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_gossip_preserves_mean(n, d, seed):
    """Invariant: with a DOUBLY-stochastic mix (symmetric topologies,
    all active), the federation mean parameter vector is conserved —
    the fixed point of Algorithm 1 is consensus on the average."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, d))
    # symmetric doubly-stochastic mix: Metropolis weights on a ring
    adj = np.asarray(ring_adjacency(n))
    m = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                m[i, j] = 1.0 / 3.0
        m[i, i] = 1.0 - m[i].sum()
    out = gossip_mix_ref(jnp.asarray(m, jnp.float32), w)
    np.testing.assert_allclose(
        np.asarray(out).mean(axis=0), np.asarray(w).mean(axis=0), atol=1e-4
    )


@given(n=st.integers(2, 12), d=st.integers(1, 200), seed=st.integers(0, 500))
@settings(**SETTINGS)
def test_gossip_kernel_equals_oracle(n, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    mix = jax.nn.softmax(jax.random.normal(keys[0], (n, n)), axis=-1)
    w = jax.random.normal(keys[1], (n, d))
    active = (jax.random.uniform(keys[2], (n,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gossip_mix(mix, w, active)),
        np.asarray(gossip_mix_ref(mix, w, active)),
        atol=1e-5,
    )


@given(
    shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=5),
    seed=st.integers(0, 100),
)
@settings(**SETTINGS)
def test_tree_vector_roundtrip(shapes, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s)
            for i, s in enumerate(shapes)}
    vec = tree_to_vector(tree)
    back = vector_to_tree(vec, tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(tree[k]), np.asarray(back[k]), atol=1e-6)


@given(
    m=st.integers(2, 200),
    scale=st.floats(1.0, 100.0),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_metric_invariants(m, scale, seed):
    """RMSE >= MAE; gRMSE >= RMSE (penalty >= 1); all zero at y == yhat."""
    rng = np.random.default_rng(seed)
    y = rng.uniform(40, 400, m)
    yhat = y + rng.normal(0, scale, m)
    assert rmse(y, yhat) >= mae(y, yhat) - 1e-9
    assert grmse(y, yhat) >= rmse(y, yhat) - 1e-6
    assert rmse(y, y) == 0 and mae(y, y) == 0 and mard(y, y) == 0


@given(
    perm_seed=st.integers(0, 100),
    n=st.integers(4, 24),
)
@settings(**SETTINGS)
def test_gossip_equivariance_under_node_relabeling(perm_seed, n):
    """Permuting nodes and mixing = mixing and permuting (the gossip
    primitive has no hidden node-order dependence)."""
    rng = np.random.default_rng(perm_seed)
    perm = rng.permutation(n)
    d = 17
    w = rng.normal(size=(n, d)).astype(np.float32)
    mix = np.asarray(jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(perm_seed), (n, n)), axis=-1))
    out = np.asarray(gossip_mix_ref(jnp.asarray(mix), jnp.asarray(w)))
    out_perm = np.asarray(
        gossip_mix_ref(jnp.asarray(mix[np.ix_(perm, perm)]), jnp.asarray(w[perm]))
    )
    np.testing.assert_allclose(out[perm], out_perm, atol=1e-5)
