"""Multi-host federation semantics, proven with REAL processes.

Each test spawns N Python subprocesses that form a ``jax.distributed``
cluster over localhost TCP (every process forced to K CPU devices via
``XLA_FLAGS``), runs ``tests/multihost/_worker.py`` in lockstep, and
compares the primary's ``RESULT`` payload across process topologies:
the 2-process x 4-device federation must match the 1-process x 8-device
one numerically — population params, loss history, and streaming-eval
records, for both gossip impls.  The ``multihost`` marker routes these
to CI's dedicated subprocess job; a plain local ``pytest`` run still
executes everything (same convention as ``multidevice``).
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
WORKER = os.path.join(ROOT, "tests", "multihost", "_worker.py")

# population params must agree to float tolerance across process
# topologies (reduction orders differ across shardings, bitwise doesn't)
ATOL = 1e-5


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn(num_processes: int, devices_per_proc: int, *extra: str,
           timeout: int = 600) -> dict:
    """Launch the worker cluster; return process 0's RESULT payload."""
    port = _free_port()
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_proc}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # a worker must never inherit multihost env from an outer launcher
    for k in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES", "REPRO_PROCESS_ID"):
        env.pop(k, None)
    procs = [
        subprocess.Popen(
            [sys.executable, WORKER, "--num-processes", str(num_processes),
             "--process-id", str(i), "--port", str(port), *extra],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        )
        for i in range(num_processes)
    ]
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=timeout)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for i, (rc, out, err) in enumerate(outs):
        assert rc == 0, (
            f"worker {i}/{num_processes} failed (rc={rc})\n"
            f"--- stdout ---\n{out[-2000:]}\n--- stderr ---\n{err[-3000:]}"
        )
    for line in outs[0][1].splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no RESULT line from worker 0:\n{outs[0][1][-2000:]}")


@pytest.mark.multihost
def test_bootstrap_and_per_host_placement():
    """2x4 cluster forms, the federation mesh spans both processes, and
    per-host placement gives each process exactly its own contiguous
    half of the node rows (and the global view reconstructs)."""
    res = _spawn(2, 4, "--mode", "bootstrap", "--nodes", "8")
    assert res["process_count"] == 2
    assert res["device_count"] == 8
    assert res["local_device_count"] == 4
    assert res["mesh_width"] == 8
    assert res["mesh_process_span"] == 2
    # process 0 owns global rows [0, 4) and materializes only them
    assert res["rows"] == [0, 4]
    assert res["placed_first_local_row"] == 0
    assert res["placed_rows_elems"] == 4 * 3


@pytest.mark.multihost
def test_narrow_mesh_still_spans_every_process():
    """Regression: a mesh narrower than the device pool (N=4 on 2x4
    devices) must draw devices from EVERY process — taking the first 4
    global devices would strand process 1 with zero federation rows."""
    res = _spawn(2, 4, "--mode", "bootstrap", "--nodes", "4")
    assert res["mesh_width"] == 4
    assert res["mesh_process_span"] == 2
    assert res["rows"] == [0, 2]
    assert res["placed_rows_elems"] == 2 * 3


@pytest.mark.multihost
def test_two_process_run_matches_single_process():
    """The acceptance run: 2 processes x 4 devices == 1 process x 8
    devices — population params, per-round losses, and streaming-eval
    records, for BOTH gossip impls; and psum == allgather within the
    2-process run (cross-host collective parity)."""
    single = _spawn(1, 8)
    double = _spawn(2, 4)
    for res in (single, double):
        for impl in ("allgather", "psum"):
            assert impl in res, sorted(res)
    assert single["device_count"] == double["device_count"] == 8

    for impl in ("allgather", "psum"):
        s, d = single[impl], double[impl]
        np.testing.assert_allclose(
            np.asarray(s["pop_vec"]), np.asarray(d["pop_vec"]),
            atol=ATOL, err_msg=f"population params diverged ({impl})",
        )
        assert len(s["losses"]) == len(d["losses"]) == 6
        np.testing.assert_allclose(s["losses"], d["losses"], atol=ATOL)
        assert s["evals"].keys() == d["evals"].keys()
        assert len(s["evals"]) == 2  # rounds 2 and 5 at eval_every=3
        for r in s["evals"]:
            assert abs(s["evals"][r] - d["evals"][r]) < ATOL

    # psum-vs-allgather parity inside the REAL 2-process cluster
    np.testing.assert_allclose(
        np.asarray(double["allgather"]["pop_vec"]),
        np.asarray(double["psum"]["pop_vec"]), atol=ATOL,
    )
    np.testing.assert_allclose(
        double["allgather"]["losses"], double["psum"]["losses"], atol=ATOL
    )
