"""Multi-host test worker — one real process of a localhost federation.

Spawned by ``tests/multihost/test_multiprocess.py`` (never imported):
every worker of a run gets the same flags except ``--process-id``, forms
a ``jax.distributed`` cluster over localhost TCP (``--num-processes 1``
skips the cluster entirely — that run IS the single-process reference),
trains the identical small federation through ``GluADFL`` with
``mixer="sharded"`` for each requested gossip impl, and prints one
machine-readable ``RESULT {json}`` line from process 0.

The payload carries everything the harness compares across process
topologies: the population-parameter vector, the per-round loss history,
and the streaming-eval records per impl — plus bootstrap facts (device
counts, this process's addressable node rows) for the placement test.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--mode", default="train", choices=["train", "bootstrap"])
    ap.add_argument("--impls", default="allgather,psum")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--eval-every", type=int, default=3)
    args = ap.parse_args()

    import numpy as np

    from repro.launch import multihost

    distributed = multihost.initialize(
        f"127.0.0.1:{args.port}", args.num_processes, args.process_id
    )
    assert distributed == (args.num_processes > 1)

    import jax

    from repro.launch.mesh import make_federation_mesh

    result: dict = {
        "process_count": jax.process_count(),
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
    }

    if args.mode == "bootstrap":
        from repro.core.distributed import addressable_node_rows

        mesh = make_federation_mesh(args.nodes)
        rows = addressable_node_rows(mesh, args.nodes)
        result.update(
            mesh_width=mesh.shape["node"],
            mesh_process_span=len({d.process_index for d in mesh.devices.flat}),
            rows=[rows.start, rows.stop],
        )
        # per-host placement: only this process's rows are materialized
        x = np.arange(args.nodes * 3, dtype=np.float32).reshape(args.nodes, 3)
        gx = multihost.shard_rows(mesh, x)
        local_rows = sorted(
            s.index[0].start or 0 for s in gx.addressable_shards
        )
        result["placed_first_local_row"] = local_rows[0]
        result["placed_rows_elems"] = int(
            sum(np.asarray(s.data).size for s in gx.addressable_shards)
        )
        # the global view must reconstruct exactly on every process
        gathered = multihost.fetch_replicated(
            jax.jit(lambda a: a, out_shardings=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))(gx)
        )
        np.testing.assert_array_equal(gathered, x)
    else:
        from repro.config import FLConfig
        from repro.core import GluADFL
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_to_vector

        rng = np.random.default_rng(0)
        n = args.nodes
        x = rng.normal(size=(n, 40, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((n,), 40, np.int32)
        vx = rng.normal(size=(16, 12)).astype(np.float32)
        vy = rng.normal(size=(16,)).astype(np.float32)
        cfg = FLConfig(topology="random", num_nodes=n, rounds=args.rounds,
                       comm_batch=3, inactive_ratio=0.25)

        for impl in args.impls.split(","):
            trainer = GluADFL(
                LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                mixer="sharded", gossip_impl=impl,
            )
            pop, hist, _ = trainer.train(
                jax.random.PRNGKey(0), x, y, counts, batch_size=8,
                chunk=args.chunk, eval_every=args.eval_every,
                val_data=(vx, vy),
            )
            pop = multihost.fetch_replicated(pop)
            result[impl] = {
                "pop_vec": np.asarray(tree_to_vector(pop)).tolist(),
                "losses": [h["loss"] for h in hist],
                "evals": {str(h["round"]): h["val_rmse"]
                          for h in hist if "val_rmse" in h},
            }

    if multihost.is_primary():
        print("RESULT " + json.dumps(result), flush=True)
    multihost.barrier("worker_done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
