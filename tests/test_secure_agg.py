"""Pairwise-masked secure aggregation (``gossip_impl="masked"``) tests.

The contract under test (core/secure_agg.py):

  * the weighted mask sum is EXACTLY ``+0.0`` — so every masked trainer
    run is a bitwise twin of its unmasked counterpart (dense + sparse
    representations, tree/kernel mixers here, sharded in the
    ``multidevice``-marked subprocess tests, with and without DP noise,
    with mid-round dropouts);
  * no simulated wire tensor equals raw parameters for any row with two
    or more participants (the privacy claim);
  * the books balance: contracting the wires with the mixing weights
    reproduces the plain mix to float tolerance;
  * inactive (dropped-out) rows admit no pairs — cancellation survives
    nodes going inactive mid-round by construction.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core.gluadfl import GluADFL
from repro.core.secure_agg import masked_mix_zero, simulate_wires
from repro.core.topology import (
    densify_neighbor_table,
    neighbor_table,
    random_adjacency,
)
from repro.models import LSTMModel
from repro.optim import adam

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bits_equal(a, b) -> bool:
    eq = jax.tree.map(
        lambda x, y: np.array_equal(np.asarray(x), np.asarray(y)), a, b
    )
    return all(jax.tree.leaves(eq))


def _table(n=8, b=3, seed=0, active=None):
    adj = random_adjacency(jax.random.PRNGKey(seed), n, b)
    if active is None:
        active = jnp.ones((n,))
    return neighbor_table(adj, active, b), active


def _fed(n=8, m=20, L=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    y = rng.normal(size=(n, m)).astype(np.float32)
    return x, y, np.full((n,), m, np.int32)


def _train(gossip_impl, *, repr_="dense", sigma=0.0, mixer="tree", chunk=4):
    x, y, counts = _fed()
    n = x.shape[0]
    cfg = FLConfig(
        topology="random", num_nodes=n, rounds=chunk, comm_batch=3,
        inactive_ratio=0.5,  # dropouts every round — identity rows mid-stream
    )
    tr = GluADFL(
        LSTMModel(hidden=4).as_model(), adam(1e-2), cfg,
        gossip_impl=gossip_impl, gossip_repr=repr_,
        dp_noise_sigma=sigma, mixer=mixer,
    )
    st = tr.init(jax.random.PRNGKey(7))
    st, _ = tr.train_chunk(st, x, y, counts, batch_size=8, chunk=chunk)
    return st


# ----------------------------------------------------- the exact-zero core
def test_mask_cancellation_is_exactly_zero():
    (idx, wgt), _ = _table()
    stacked = {
        "w": jax.random.normal(jax.random.PRNGKey(1), (8, 17)),
        "b": jax.random.normal(jax.random.PRNGKey(2), (8, 3, 5)),
    }
    zero = jax.jit(masked_mix_zero)(stacked, idx, wgt, jax.random.PRNGKey(3))
    for leaf in jax.tree.leaves(zero):
        arr = np.asarray(leaf)
        assert np.all(arr == 0.0)
        # +0.0 specifically: adding it never flips a sign bit
        assert not np.any(np.signbit(arr))


def test_mask_cancellation_zero_with_dropouts():
    # nodes dropping out mid-round = identity mixing rows; their table
    # rows have a single valid slot (no pairs) and dropped neighbors'
    # slots carry weight 0 — cancellation must survive by construction
    active = jnp.asarray([1, 0, 1, 1, 0, 0, 1, 1], jnp.float32)
    (idx, wgt), _ = _table(active=active)
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, 33))}
    zero = masked_mix_zero(stacked, idx, wgt, jax.random.PRNGKey(5))
    arr = np.asarray(zero["w"])
    assert np.all(arr == 0.0) and not np.any(np.signbit(arr))


# ------------------------------------------------- trainer bitwise parity
@pytest.mark.parametrize("repr_", ["dense", "sparse"])
@pytest.mark.parametrize("sigma", [0.0, 0.05])
def test_masked_training_bitwise_equals_unmasked(repr_, sigma):
    a = _train("allgather", repr_=repr_, sigma=sigma)
    b = _train("masked", repr_=repr_, sigma=sigma)
    assert _bits_equal(a.params, b.params)
    assert _bits_equal(a.opt_state, b.opt_state)
    # the key chain too: masking folds its stream off the round key and
    # never splits, so it cannot perturb any other consumer
    assert _bits_equal(a.key, b.key)


def test_masked_kernel_mixer_bitwise():
    a = _train("allgather", mixer="kernel", chunk=2)
    b = _train("masked", mixer="kernel", chunk=2)
    assert _bits_equal(a.params, b.params)


def test_masked_sweep_bitwise():
    # the vmapped sweep engine threads the same mask context per scenario
    from repro.core.gluadfl import SweepGrid

    x, y, counts = _fed()
    cfg = FLConfig(topology="ring", num_nodes=8, rounds=3, comm_batch=3)
    grid = SweepGrid.build(("ring", "random"), (0.0, 0.5), num_nodes=8)

    def sweep(impl):
        tr = GluADFL(
            LSTMModel(hidden=4).as_model(), adam(1e-2), cfg, gossip_impl=impl
        )
        pops, _, _ = tr.train_sweep(x, y, counts, grid=grid, batch_size=8, rounds=3)
        return pops

    assert _bits_equal(sweep("allgather"), sweep("masked"))


# ------------------------------------------------------- the privacy claim
def test_wires_never_equal_raw_params():
    (idx, wgt), _ = _table()
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(6), (8, 29))}
    wires = simulate_wires(stacked, idx, wgt, jax.random.PRNGKey(7))["w"]
    flat = np.asarray(stacked["w"])
    idx_np, wgt_np = np.asarray(idx), np.asarray(wgt)
    wires = np.asarray(wires)
    checked = 0
    for n in range(idx_np.shape[0]):
        valid = wgt_np[n] > 0
        if valid.sum() < 2:
            continue  # single-participant rows transmit nothing to mask
        for b in np.flatnonzero(valid):
            raw = flat[idx_np[n, b]]
            assert not np.array_equal(wires[n, b], raw), (n, b)
            checked += 1
    assert checked > 0  # the fixture must actually exercise masked slots


def test_dropped_rows_put_nothing_masked_on_the_wire():
    active = jnp.asarray([1, 0, 1, 1, 1, 1, 1, 1], jnp.float32)
    (idx, wgt), _ = _table(active=active)
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(8), (8, 13))}
    wires = np.asarray(
        simulate_wires(stacked, idx, wgt, jax.random.PRNGKey(9))["w"]
    )
    # the inactive row's table is identity: its only valid slot is its
    # own unmasked row — an aggregation of one needs (and gets) no mask
    assert np.array_equal(wires[1, 0], np.asarray(stacked["w"])[1])
    assert float(np.asarray(wgt)[1, 0]) == 1.0


def test_wire_books_balance():
    # Σ_b wgt[n,b] * wire[n,b] reproduces the plain mix to float
    # tolerance (the bitwise path never materializes wires; this proves
    # the wires the privacy test inspects are the SAME protocol)
    (idx, wgt), _ = _table()
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(10), (8, 21))}
    wires = simulate_wires(stacked, idx, wgt, jax.random.PRNGKey(11))["w"]
    mixed = jnp.einsum("nb,nbd->nd", wgt.astype(jnp.float32), wires)
    dense = densify_neighbor_table(idx, wgt)
    ref = jnp.asarray(dense, jnp.float32) @ stacked["w"]
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(ref), atol=1e-4)


# ----------------------------------------------------------- knob plumbing
def test_choose_gossip_impl_secure():
    from repro.launch.mesh import choose_gossip_impl

    assert choose_gossip_impl(8, 1024, secure=True) == "masked"
    # masked rides allgather: past the gather budget on a real multi-
    # shard mesh it must refuse loudly, not silently drop the masking
    with pytest.raises(ValueError):
        choose_gossip_impl(
            8, 1 << 20, shards=4, budget_bytes=1 << 10, secure=True
        )


def test_gossip_impl_knob_accepts_masked():
    cfg = FLConfig(num_nodes=4, comm_batch=2)
    GluADFL(LSTMModel(hidden=4).as_model(), adam(1e-3), cfg, gossip_impl="masked")
    with pytest.raises(ValueError):
        GluADFL(
            LSTMModel(hidden=4).as_model(), adam(1e-3), cfg, gossip_impl="bogus"
        )


# ------------------------------------------- sharded mixers (8 devices)
def _run_sub(src: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.multidevice
@pytest.mark.parametrize("repr_", ["dense", "sparse"])
def test_sharded_masked_bitwise(repr_):
    # the shard_map mixers on the node axis: masked == allgather bitwise,
    # with DP noise and 50% dropouts, under real (forced) multi-device XLA
    print(_run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core.gluadfl import GluADFL
        from repro.models import LSTMModel
        from repro.optim import adam

        def run(impl):
            n, m, L = 8, 20, 6
            rng = np.random.default_rng(0)
            x = rng.normal(size=(n, m, L)).astype(np.float32)
            y = rng.normal(size=(n, m)).astype(np.float32)
            counts = np.full((n,), m, np.int32)
            cfg = FLConfig(topology="random", num_nodes=n, rounds=3,
                           comm_batch=3, inactive_ratio=0.5)
            tr = GluADFL(LSTMModel(hidden=4).as_model(), adam(1e-2), cfg,
                         mixer="sharded", gossip_impl=impl,
                         gossip_repr={repr_!r}, dp_noise_sigma=0.05)
            st = tr.init(jax.random.PRNGKey(7))
            st, _ = tr.train_chunk(st, x, y, counts, batch_size=8, chunk=3)
            return st

        a, b = run("allgather"), run("masked")
        eq = jax.tree.map(
            lambda p, q: np.array_equal(np.asarray(p), np.asarray(q)),
            (a.params, a.opt_state, a.key), (b.params, b.opt_state, b.key))
        assert all(jax.tree.leaves(eq)), "masked != allgather under sharded mixer"
        print("SHARDED_MASKED_OK")
    """))
