import numpy as np
import pytest


@pytest.fixture(scope="session")
def fed_ohio():
    """Small (fast) synthetic OhioT1DM twin shared across tests."""
    from repro.data import load_federated_dataset

    return load_federated_dataset("ohiot1dm", fast=True)


def assert_finite(x, name="value"):
    assert np.isfinite(np.asarray(x)).all(), f"{name} contains NaN/Inf"
