"""Scan-compiled training engine tests: train_chunk vs the per-round
loop, engine regression (history/population), and the bitwise freeze of
inactive nodes across a whole chunk."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL
from repro.models import LSTMModel
from repro.optim import adam, sgd
from repro.utils.pytree import tree_l2_norm, tree_sub


def _toy_fed(n=6, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, m)).astype(np.float32)
    counts = np.full((n,), m, np.int32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)


def _state_allclose(a, b, atol=1e-6):
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    assert int(a.round) == int(b.round)
    np.testing.assert_allclose(np.asarray(a.staleness), np.asarray(b.staleness))
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)
    for la, lb in zip(jax.tree.leaves(a.opt_state), jax.tree.leaves(b.opt_state)):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32), atol=atol
        )


@pytest.mark.parametrize("grad_at", ["premix", "mixed"])
@pytest.mark.parametrize("dp_sigma", [0.0, 0.05])
def test_train_chunk_matches_k_rounds(grad_at, dp_sigma):
    """train_chunk(chunk=k) == k sequential _round calls: same key, same
    data, same FLState to float32 tolerance (incl. DP-noise and the
    mixed-gradient ablation)."""
    k = 5
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="random", num_nodes=6, rounds=k,
                   comm_batch=3, inactive_ratio=0.3)
    tr = GluADFL(m, sgd(1e-2), cfg, grad_at=grad_at, dp_noise_sigma=dp_sigma)

    s_loop = tr.init(jax.random.PRNGKey(0), x[0, :1])
    loop_losses = []
    for _ in range(k):
        s_loop, loss = tr._round_jit(s_loop, x, y, counts, batch_size=8)
        loop_losses.append(float(loss))

    s0 = tr.init(jax.random.PRNGKey(0), x[0, :1])
    s_chunk, losses = tr.train_chunk(s0, x, y, counts, batch_size=8, chunk=k)

    assert losses.shape == (k,)
    np.testing.assert_allclose(np.asarray(losses), loop_losses, atol=1e-6)
    _state_allclose(s_loop, s_chunk)


@pytest.mark.parametrize("mixer", ["tree", "kernel"])
def test_train_chunk_matches_k_rounds_all_mixers(mixer):
    """The chunk/loop equivalence holds per mixer (the sharded mixer is
    covered under a multi-device mesh in test_distributed.py)."""
    k = 4
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=k)
    tr = GluADFL(m, sgd(1e-2), cfg, mixer=mixer, dp_noise_sigma=0.02)
    s_loop = tr.init(jax.random.PRNGKey(1), x[0, :1])
    for _ in range(k):
        s_loop, _ = tr._round_jit(s_loop, x, y, counts, batch_size=8)
    s0 = tr.init(jax.random.PRNGKey(1), x[0, :1])
    s_chunk, _ = tr.train_chunk(s0, x, y, counts, batch_size=8, chunk=k)
    _state_allclose(s_loop, s_chunk)


def test_train_scan_engine_matches_loop_engine():
    """Regression: the engine refactor changes throughput, not results —
    round count, history length, per-round losses, and the population
    average are identical between engines."""
    rounds = 9
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="random", num_nodes=6, rounds=rounds, comm_batch=3)
    tr = GluADFL(m, adam(5e-3), cfg)
    pop_s, hist_s, st_s = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8, chunk=4
    )
    pop_l, hist_l, st_l = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8, engine="loop"
    )
    assert len(hist_s) == len(hist_l) == rounds
    assert [h["round"] for h in hist_s] == list(range(rounds))
    for hs, hl in zip(hist_s, hist_l):
        assert abs(hs["loss"] - hl["loss"]) < 1e-6
    assert int(st_s.round) == int(st_l.round) == rounds
    assert float(tree_l2_norm(tree_sub(pop_s, pop_l))) < 1e-6


def test_eval_no_longer_forces_loop_engine():
    """The retired auto-fallback: an eval request must NOT silently drop
    train() back to the per-round Python loop — the scan engine runs it
    in-scan.  (Host-callback eval under the explicit engine="loop" debug
    flag is covered in test_streaming_eval.py.)"""
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=6)
    tr = GluADFL(m, sgd(1e-2), cfg)
    rng = np.random.default_rng(1)
    vx = rng.normal(size=(16, 12)).astype(np.float32)
    vy = rng.normal(size=(16,)).astype(np.float32)
    tr._round_jit = None  # scan path must never touch the per-round jit
    pop, hist, _ = tr.train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8,
        eval_every=2, val_data=(vx, vy), chunk=6,
    )
    assert len(hist) == 6
    assert [h["round"] for h in hist if "val_rmse" in h] == [1, 3, 5]


def test_inactive_nodes_bitwise_frozen_across_chunk():
    """Nodes that sit out every round of a chunk keep params AND
    optimizer state bit-for-bit (staleness == chunk identifies them)."""
    k = 6
    n = 8
    x, y, counts = _toy_fed(n=n)
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="random", num_nodes=n, rounds=k,
                   comm_batch=3, inactive_ratio=0.85)
    tr = GluADFL(m, adam(5e-3), cfg)
    # seed chosen so this activity stream strands 2 of 8 nodes for all 6
    # rounds (deterministic given the key)
    s0 = tr.init(jax.random.PRNGKey(2), x[0, :1])
    p_before = jax.tree.map(np.asarray, s0.params)
    o_before = jax.tree.map(np.asarray, s0.opt_state)
    s1, _ = tr.train_chunk(s0, x, y, counts, batch_size=8, chunk=k)

    frozen = np.asarray(s1.staleness) >= k  # never active in the chunk
    assert frozen.any(), "inactive_ratio=0.85 over 6 rounds should strand a node"
    assert not frozen.all()
    for before, after in zip(jax.tree.leaves(p_before), jax.tree.leaves(s1.params)):
        np.testing.assert_array_equal(before[frozen], np.asarray(after)[frozen])
    for before, after in zip(jax.tree.leaves(o_before), jax.tree.leaves(s1.opt_state)):
        before = np.asarray(before)
        if before.ndim >= 1 and before.shape[0] == n:
            np.testing.assert_array_equal(before[frozen], np.asarray(after)[frozen])


def test_use_kernel_mixer_conflict_rejected():
    """use_kernel=True with a contradicting explicit mixer must raise,
    not silently pick one."""
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(num_nodes=4, rounds=1)
    with pytest.raises(ValueError, match="use_kernel"):
        GluADFL(m, sgd(1e-2), cfg, use_kernel=True, mixer="tree")
    # compatible spellings still work
    assert GluADFL(m, sgd(1e-2), cfg, use_kernel=True).mixer == "kernel"
    assert GluADFL(m, sgd(1e-2), cfg, use_kernel=True, mixer="kernel").mixer == "kernel"


def test_scan_carry_is_type_stable():
    """The optimizer step counter must stay int32 through the masked
    update — a float-promoting mask would break the scan carry."""
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=2, inactive_ratio=0.4)
    tr = GluADFL(m, adam(5e-3), cfg)
    s0 = tr.init(jax.random.PRNGKey(0), x[0, :1])
    dtypes0 = [l.dtype for l in jax.tree.leaves(s0.opt_state)]
    s1, _ = tr.train_chunk(s0, x, y, counts, batch_size=8, chunk=2)
    assert [l.dtype for l in jax.tree.leaves(s1.opt_state)] == dtypes0


def test_train_chunk_remainder_and_default_chunk():
    """rounds not divisible by chunk: the tail chunk still runs and the
    history covers every round exactly once."""
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=7)
    tr = GluADFL(m, sgd(1e-2), cfg)
    pop, hist, st = tr.train(jax.random.PRNGKey(0), x, y, counts,
                             batch_size=8, chunk=3)
    assert [h["round"] for h in hist] == list(range(7))
    assert int(st.round) == 7
    assert all(np.isfinite(h["loss"]) for h in hist)
