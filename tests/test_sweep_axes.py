"""Scenario-axis parity tests: each optional sweep axis — Markov-sticky
staleness, non-IID data skew, DP noise level — must reproduce its SERIAL
twin exactly (same key chain, same losses/params/eval records as a plain
``train(PRNGKey(seed_g))`` with the matching ``FLConfig`` /
``dp_noise_sigma``), and the whole multi-axis grid must still run in the
chunked compiled-execution budget.  These are the fails-if-broken pins
for the axis plumbing: reverting the schedule select, the batch shift,
or the traced sigma breaks a bitwise (or 1e-5, for the XLA-fusion-
sensitive skew path) comparison here."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL, SweepGrid
from repro.data.synth import node_skew_offsets
from repro.models import LSTMModel
from repro.optim import sgd
from repro.utils.pytree import tree_index, tree_l2_norm, tree_sub


def _toy_fed(n=6, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, m)).astype(np.float32)
    counts = np.full((n,), m, np.int32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)


def _val_set(m=24, L=12, seed=7):
    rng = np.random.default_rng(seed)
    vx = rng.normal(size=(m, L)).astype(np.float32)
    vy = (vx @ rng.normal(size=(L,)).astype(np.float32)).astype(np.float32)
    return jnp.asarray(vx), jnp.asarray(vy)


def _losses(hist):
    return np.asarray([h["loss"] for h in hist])


def _serial_twin(model, lab, x, y, counts, *, rounds, chunk=None,
                 eval_every=0, val=None):
    """The serial run a swept scenario must reproduce: plain ``train``
    under the scenario's config (schedule / data_skew / dp sigma)."""
    cfg = FLConfig(
        topology=lab["topology"], num_nodes=int(x.shape[0]), comm_batch=3,
        rounds=rounds, inactive_ratio=lab["inactive_ratio"],
        schedule=lab["schedule"], data_skew=lab["skew"],
    )
    tr = GluADFL(model, sgd(1e-2), cfg, dp_noise_sigma=lab["dp_sigma"])
    return tr.train(
        jax.random.PRNGKey(lab["seed"]), x, y, counts, batch_size=8,
        chunk=chunk, eval_every=eval_every, val_data=val,
    )


# ----------------------------------------------------------------------
# grid layout
# ----------------------------------------------------------------------

def test_sweep_grid_axes_layout():
    """Armed grids carry 6-tuple labels in (topo, ratio, schedule, skew,
    dp, seed) document order with the new axes as (G,) arrays; unarmed
    grids keep the classic 3-tuple labels and ``None`` axes (identical
    compiled program); ``label_dict`` normalizes both."""
    grid = SweepGrid.build(
        ("ring",), (0.0, 0.4), (0,), num_nodes=6,
        schedules=("bernoulli", "markov"), skews=(0.0, 0.5),
        dp_sigmas=(0.0, 0.1),
    )
    assert grid.size == 2 * 2 * 2 * 2
    assert grid.labels[0] == ("ring", 0.0, "bernoulli", 0.0, 0.0, 0)
    # dp is the innermost axis before seed
    assert grid.labels[1] == ("ring", 0.0, "bernoulli", 0.0, 0.1, 0)
    assert grid.labels[2] == ("ring", 0.0, "bernoulli", 0.5, 0.0, 0)
    assert grid.labels[8] == ("ring", 0.4, "bernoulli", 0.0, 0.0, 0)
    assert grid.markov.shape == (16,) and grid.skew.shape == (16,)
    assert grid.dp_sigma.shape == (16,)
    # markov flag is 0/1 float, schedule-major inside each ratio block
    np.testing.assert_array_equal(
        np.asarray(grid.markov[:8]), [0, 0, 0, 0, 1, 1, 1, 1]
    )
    lab = grid.label_dict(5)
    assert lab == {
        "topology": "ring", "inactive_ratio": 0.0, "schedule": "markov",
        "skew": 0.0, "dp_sigma": 0.1, "seed": 0,
    }

    plain = SweepGrid.build(("ring",), (0.0,), (0, 1), num_nodes=6)
    assert plain.labels[0] == ("ring", 0.0, 0)
    assert plain.markov is None and plain.skew is None
    assert plain.dp_sigma is None
    assert plain.label_dict(1) == {
        "topology": "ring", "inactive_ratio": 0.0, "schedule": "bernoulli",
        "skew": 0.0, "dp_sigma": 0.0, "seed": 1,
    }

    with pytest.raises(ValueError, match="schedule"):
        SweepGrid.build(("ring",), (0.0,), (0,), num_nodes=6,
                        schedules=("poisson",))


# ----------------------------------------------------------------------
# per-axis serial parity
# ----------------------------------------------------------------------

def test_markov_axis_matches_serial():
    """Swept markov/bernoulli scenarios == serial ``FLConfig(schedule=
    ...)`` runs — key chain bitwise (the schedule select reads the same
    uniform draw), losses/params/eval records within the repo's 1e-5
    fusion tolerance — and the two schedules genuinely diverge."""
    rounds, chunk, eval_every = 6, 4, 2
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    val = _val_set()
    grid = SweepGrid.build(
        ("ring",), (0.3,), (0,), num_nodes=6,
        schedules=("bernoulli", "markov"),
    )
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6, comm_batch=3,
                                            rounds=rounds))
    pops, hists, states = tr.train_sweep(
        x, y, counts, grid=grid, batch_size=8, chunk=chunk,
        eval_every=eval_every, val_data=val,
    )
    for g in range(grid.size):
        lab = grid.label_dict(g)
        s_pop, s_hist, s_state = _serial_twin(
            model, lab, x, y, counts, rounds=rounds, chunk=chunk,
            eval_every=eval_every, val=val,
        )
        assert np.abs(_losses(hists[g]) - _losses(s_hist)).max() < 1e-5
        for hs, hl in zip(hists[g], s_hist):
            assert ("val_rmse" in hs) == ("val_rmse" in hl)
            if "val_rmse" in hs:
                assert abs(hs["val_rmse"] - hl["val_rmse"]) < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), s_pop))
        ) < 1e-5
        np.testing.assert_array_equal(
            np.asarray(states.key[g]), np.asarray(s_state.key)
        )
        np.testing.assert_array_equal(
            np.asarray(states.staleness[g]), np.asarray(s_state.staleness)
        )
    # the axis must DO something: sticky staleness is a different process
    assert np.abs(_losses(hists[0]) - _losses(hists[1])).max() > 1e-7


def test_skew_axis_matches_serial():
    """Swept non-IID skew == both of its twins: ``FLConfig(data_skew=s)``
    AND a plain train on host-pre-shifted arrays (the gather-commute
    contract).  The key chain stays bitwise; losses/params carry the
    repo's 1e-5 XLA-fusion tolerance."""
    rounds = 5
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    skews = (0.0, 0.7)
    grid = SweepGrid.build(("cluster",), (0.0,), (0,), num_nodes=6,
                           skews=skews)
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6, comm_batch=3,
                                            rounds=rounds))
    pops, hists, states = tr.train_sweep(x, y, counts, grid=grid,
                                         batch_size=8)
    offsets = node_skew_offsets(6)
    for g, skew in enumerate(skews):
        lab = grid.label_dict(g)
        assert lab["skew"] == skew
        s_pop, s_hist, s_state = _serial_twin(
            model, lab, x, y, counts, rounds=rounds,
        )
        assert np.abs(_losses(hists[g]) - _losses(s_hist)).max() < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), s_pop))
        ) < 1e-5
        np.testing.assert_array_equal(
            np.asarray(states.key[g]), np.asarray(s_state.key)
        )
        # gather-commute oracle: train on pre-shifted host arrays
        shift = np.float32(skew) * offsets
        cfg = FLConfig(topology="cluster", num_nodes=6, comm_batch=3,
                       rounds=rounds)
        o_pop, o_hist, _ = GluADFL(model, sgd(1e-2), cfg).train(
            jax.random.PRNGKey(0), x + shift[:, None, None],
            y + shift[:, None], counts, batch_size=8,
        )
        assert np.abs(_losses(hists[g]) - _losses(o_hist)).max() < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), o_pop))
        ) < 1e-5
    # reverting the shift would collapse the two scenarios onto each other
    assert np.abs(_losses(hists[0]) - _losses(hists[1])).max() > 1e-7


def test_dp_axis_matches_serial():
    """Swept DP sigmas == serial ``GluADFL(dp_noise_sigma=sigma_g)`` runs
    bitwise (python-float sigma and traced-f32 sigma scale the same
    normal draw), and different sigmas produce different trajectories."""
    rounds, chunk = 6, 4
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    sigmas = (0.05, 0.2)
    grid = SweepGrid.build(("ring",), (0.2,), (0,), num_nodes=6,
                           dp_sigmas=sigmas)
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6, comm_batch=3,
                                            rounds=rounds))
    pops, hists, states = tr.train_sweep(x, y, counts, grid=grid,
                                         batch_size=8, chunk=chunk)
    for g, sigma in enumerate(sigmas):
        lab = grid.label_dict(g)
        assert lab["dp_sigma"] == sigma
        s_pop, s_hist, s_state = _serial_twin(
            model, lab, x, y, counts, rounds=rounds, chunk=chunk,
        )
        assert np.abs(_losses(hists[g]) - _losses(s_hist)).max() < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), s_pop))
        ) < 1e-5
        np.testing.assert_array_equal(
            np.asarray(states.key[g]), np.asarray(s_state.key)
        )
    assert np.abs(_losses(hists[0]) - _losses(hists[1])).max() > 1e-7


def test_all_axes_combined_matches_serial_and_budget():
    """All three axes armed at once: the grid still runs in the chunked
    compiled-execution budget (one batched program per chunk shape), and
    a scenario engaging EVERY axis simultaneously (markov + skew + dp)
    still reproduces its serial twin."""
    rounds, chunk = 5, 4
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    grid = SweepGrid.build(
        ("ring",), (0.3,), (0,), num_nodes=6,
        schedules=("bernoulli", "markov"), skews=(0.0, 0.6),
        dp_sigmas=(0.05,),
    )
    assert grid.size == 4
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6, comm_batch=3,
                                            rounds=rounds))
    calls = []
    inner = tr._sweep_chunk_jit

    def counting(*a, **k):
        calls.append(k.get("chunk"))
        return inner(*a, **k)

    tr._sweep_chunk_jit = counting
    pops, hists, states = tr.train_sweep(x, y, counts, grid=grid,
                                         batch_size=8, chunk=chunk)
    assert len(calls) <= 2, calls  # 4 + 1 -> two chunk shapes
    # the fully-engaged scenario: markov schedule, skew 0.6, sigma 0.05
    g = next(
        i for i in range(grid.size)
        if grid.label_dict(i)["schedule"] == "markov"
        and grid.label_dict(i)["skew"] == 0.6
    )
    s_pop, s_hist, s_state = _serial_twin(
        model, grid.label_dict(g), x, y, counts, rounds=rounds, chunk=chunk,
    )
    assert np.abs(_losses(hists[g]) - _losses(s_hist)).max() < 1e-5
    assert float(
        tree_l2_norm(tree_sub(tree_index(pops, g), s_pop))
    ) < 1e-5
    np.testing.assert_array_equal(
        np.asarray(states.key[g]), np.asarray(s_state.key)
    )


def test_sweep_axes_need_ratio_grid_guards():
    """Axis tuples must be well-formed: an empty-axis build keeps the
    classic grid, a dp-armed grid keeps one key stream so sigma=0.0
    scenarios match sigma->0 limits (pinned in test_property), and the
    builder rejects unknown schedules (covered above) without mutating
    the classic label layout."""
    grid = SweepGrid.build(("ring", "random"), (0.0, 0.5), (0, 1),
                           num_nodes=6, schedules=None, skews=None,
                           dp_sigmas=None)
    assert grid.size == 8 and grid.labels[0] == ("ring", 0.0, 0)
    assert grid.markov is None and grid.skew is None and grid.dp_sigma is None
