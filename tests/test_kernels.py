"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode on
CPU executes the kernel body exactly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    gossip_mix,
    gossip_mix_dp,
    gossip_mix_sparse,
    gossip_mix_sparse_dp,
    lstm_cell,
    swa_attention,
)
from repro.kernels.ref import (
    gossip_mix_dp_ref,
    gossip_mix_ref,
    gossip_mix_sparse_dp_ref,
    gossip_mix_sparse_ref,
    lstm_cell_ref,
    swa_attention_ref,
)


# ---------------------------------------------------------------------------
# gossip_mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 12, 25, 30])
@pytest.mark.parametrize("d", [64, 512, 1000, 1537])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_sweep(n, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 1000 + d))
    mix = jax.nn.softmax(jax.random.normal(k1, (n, n)), axis=-1)
    w = jax.random.normal(k2, (n, d)).astype(dtype)
    out = gossip_mix(mix, w)
    ref = gossip_mix_ref(mix, w)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


@pytest.mark.parametrize("inactive_frac", [0.0, 0.3, 0.9])
def test_gossip_mix_active_mask(inactive_frac):
    n, d = 16, 512
    mix = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (n, n)), axis=-1)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    active = (jax.random.uniform(jax.random.PRNGKey(2), (n,)) >= inactive_frac).astype(
        jnp.float32
    )
    out = gossip_mix(mix, w, active)
    ref = gossip_mix_ref(mix, w, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # inactive rows are exact copies
    for i in np.where(np.asarray(active) == 0)[0]:
        np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(w)[i])


def test_gossip_mix_identity():
    n, d = 8, 256
    w = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    out = gossip_mix(jnp.eye(n), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w), atol=1e-6)


# ---------------------------------------------------------------------------
# gossip_mix_dp (fused noise-broadcast + mix + clean-self-restore)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(5, 64), (12, 130), (25, 700)])
@pytest.mark.parametrize("inactive_frac", [0.0, 0.4])
def test_gossip_mix_dp_sweep(n, d, inactive_frac):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n * 100 + d), 4)
    mix = jax.nn.softmax(jax.random.normal(k1, (n, n)), axis=-1)
    w = jax.random.normal(k2, (n, d))
    noise = 0.1 * jax.random.normal(k3, (n, d))
    active = (jax.random.uniform(k4, (n,)) >= inactive_frac).astype(jnp.float32)
    out = gossip_mix_dp(mix, w, noise, active)
    ref = gossip_mix_dp_ref(mix, w, noise, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # inactive rows bypass both noise and mix: bit-exact copies
    for i in np.where(np.asarray(active) == 0)[0]:
        np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(w)[i])


def test_gossip_mix_dp_zero_noise_equals_plain():
    """sigma=0 collapses the fused kernel to the vanilla contraction."""
    n, d = 9, 300
    mix = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3), (n, n)), axis=-1)
    w = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    out = gossip_mix_dp(mix, w, jnp.zeros_like(w))
    ref = gossip_mix(mix, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_gossip_mix_dp_self_contribution_clean():
    """With an identity mix every node keeps EXACTLY its clean params —
    the noise it broadcast never contaminates itself."""
    n, d = 8, 128
    w = jax.random.normal(jax.random.PRNGKey(5), (n, d))
    noise = jax.random.normal(jax.random.PRNGKey(6), (n, d))
    out = gossip_mix_dp(jnp.eye(n), w, noise)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w), atol=1e-5)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz", [1, 50, 128, 200])
@pytest.mark.parametrize("hidden", [128, 256, 512])
def test_lstm_cell_sweep(bsz, hidden):
    ks = jax.random.split(jax.random.PRNGKey(bsz + hidden), 6)
    x = jax.random.normal(ks[0], (bsz, 1))
    h = jax.random.normal(ks[1], (bsz, hidden))
    c = jax.random.normal(ks[2], (bsz, hidden))
    wx = jax.random.normal(ks[3], (1, 4 * hidden))
    wh = jax.random.normal(ks[4], (hidden, 4 * hidden)) * hidden**-0.5
    b = jax.random.normal(ks[5], (4 * hidden,))
    hn, cn = lstm_cell(x, h, c, wx, wh, b)
    hr, cr = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cr), atol=1e-5)


def test_lstm_cell_nonaligned_hidden_falls_back():
    bsz, hidden = 8, 100  # 100 % 128 != 0 -> reference path
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (bsz, 1))
    h = jax.random.normal(ks[1], (bsz, hidden))
    c = jax.random.normal(ks[2], (bsz, hidden))
    wx = jax.random.normal(ks[3], (1, 4 * hidden))
    wh = jax.random.normal(ks[4], (hidden, 4 * hidden)) * 0.1
    b = jnp.zeros((4 * hidden,))
    hn, cn = lstm_cell(x, h, c, wx, wh, b)
    hr, cr = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hr), atol=1e-5)


def test_lstm_model_with_kernel_matches_ref_path():
    from repro.models import LSTMModel

    m_ref = LSTMModel(hidden=128, use_kernel=False)
    m_ker = LSTMModel(hidden=128, use_kernel=True)
    params = m_ref.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 12))
    np.testing.assert_allclose(
        np.asarray(m_ref.apply(params, x)),
        np.asarray(m_ker.apply(params, x)),
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [128, 256, 1024])
@pytest.mark.parametrize("window", [64, 128, 300, 1024])
@pytest.mark.parametrize("hd", [64, 128])
def test_swa_attention_sweep(s, window, hd):
    b, h = 2, 2
    ks = jax.random.split(jax.random.PRNGKey(s + window), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    out = swa_attention(q, k, v, window=window)
    ref = swa_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_dtypes(dtype):
    b, s, h, hd = 1, 256, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, hd)).astype(dtype)
    out = swa_attention(q, k, v, window=100)
    ref = swa_attention_ref(q, k, v, window=100)
    atol = 3e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_swa_attention_matches_jax_banded_path():
    """Kernel vs the framework's pure-JAX banded flash implementation."""
    from repro.nn.attention import banded_flash_attention

    b, s, h, hd = 1, 512, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    out_kernel = swa_attention(q, k, v, window=128)
    out_jax = banded_flash_attention(q, k, v, window=128, block=128)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_jax), atol=3e-5)


# ---------------------------------------------------------------------------
# sparse (neighbor-table) gossip kernels
# ---------------------------------------------------------------------------


def _table(n, B, key, inactive_frac=0.0):
    from repro.core.topology import neighbor_table, random_adjacency

    k1, k2 = jax.random.split(key)
    adj = random_adjacency(k1, n, B)
    active = (jax.random.uniform(k2, (n,)) >= inactive_frac).astype(jnp.float32)
    if inactive_frac > 0:
        active = active.at[0].set(1.0)
    idx, wgt = neighbor_table(adj, active, B)
    return idx, wgt, active


@pytest.mark.parametrize("n,d", [(5, 64), (12, 700), (25, 1537), (226, 300)])
@pytest.mark.parametrize("inactive_frac", [0.0, 0.4])
def test_gossip_mix_sparse_sweep(n, d, inactive_frac):
    idx, wgt, active = _table(n, 3, jax.random.PRNGKey(n), inactive_frac)
    w = jax.random.normal(jax.random.PRNGKey(n + 1), (n, d))
    out = gossip_mix_sparse(idx, wgt, w, active)
    ref = gossip_mix_sparse_ref(idx, wgt, w, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    for i in np.where(np.asarray(active) == 0)[0]:
        np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(w)[i])


def test_gossip_mix_sparse_matches_dense_kernel():
    """Sparse Pallas body == dense Pallas body on the densified table —
    the two kernels are alternative layouts of one mixing operator."""
    from repro.core.topology import densify_neighbor_table

    n, d = 30, 513
    idx, wgt, active = _table(n, 5, jax.random.PRNGKey(7), 0.3)
    w = jax.random.normal(jax.random.PRNGKey(8), (n, d))
    sparse = gossip_mix_sparse(idx, wgt, w, active)
    dense = gossip_mix(densify_neighbor_table(idx, wgt), w, active)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense), atol=1e-5)


@pytest.mark.parametrize("n,d", [(5, 64), (12, 700), (226, 300)])
@pytest.mark.parametrize("inactive_frac", [0.0, 0.4])
def test_gossip_mix_sparse_dp_sweep(n, d, inactive_frac):
    """Fused DP variant: out[n] = Σ_b wgt[n,b]·(w+z)[idx[n,b]] −
    wgt_self[n]·z[n] — vs the densified oracle, bit-exact inactive."""
    idx, wgt, active = _table(n, 3, jax.random.PRNGKey(n + 50), inactive_frac)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    noise = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (n, d))
    out = gossip_mix_sparse_dp(idx, wgt, w, noise, active)
    ref = gossip_mix_sparse_dp_ref(idx, wgt, w, noise, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    for i in np.where(np.asarray(active) == 0)[0]:
        np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(w)[i])


def test_gossip_mix_sparse_dp_zero_noise_equals_plain():
    n, d = 16, 256
    idx, wgt, active = _table(n, 3, jax.random.PRNGKey(3), 0.2)
    w = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    out = gossip_mix_sparse_dp(idx, wgt, w, jnp.zeros_like(w), active)
    plain = gossip_mix_sparse(idx, wgt, w, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(plain), atol=1e-6)


def test_gossip_mix_sparse_dp_self_contribution_clean():
    """Each node's OWN noise never contaminates its mixed params: with
    only node i's noise nonzero, out[i] must equal the noiseless mix at
    row i (the kernel subtracts wgt_self·z_self)."""
    n, d = 12, 128
    idx, wgt, active = _table(n, 3, jax.random.PRNGKey(5))
    w = jax.random.normal(jax.random.PRNGKey(6), (n, d))
    i = 4
    noise = jnp.zeros((n, d)).at[i].set(
        jax.random.normal(jax.random.PRNGKey(7), (d,))
    )
    out = gossip_mix_sparse_dp(idx, wgt, w, noise, active)
    plain = gossip_mix_sparse(idx, wgt, w, active)
    np.testing.assert_allclose(np.asarray(out)[i], np.asarray(plain)[i], atol=1e-5)
