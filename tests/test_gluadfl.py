"""GluADFL algorithm tests (Algorithm 1) + FedAvg + gossip equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL, FedAvg, gossip_mix_tree, mixing_matrix, ring_adjacency
from repro.core.gossip import gossip_mix_kernel
from repro.models import LSTMModel, NBeatsModel
from repro.optim import adam, sgd
from repro.utils.pytree import tree_l2_norm, tree_mean, tree_sub, tree_weighted_mix


def _toy_fed(n=6, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, m)).astype(np.float32)
    counts = np.full((n,), m, np.int32)
    return x, y, counts


def test_gossip_mix_matches_manual():
    n = 5
    stacked = {"w": jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)}
    mix = mixing_matrix(ring_adjacency(n), jnp.ones((n,)), 7)
    out = gossip_mix_tree(stacked, mix)
    manual = np.asarray(mix) @ np.asarray(stacked["w"])
    np.testing.assert_allclose(np.asarray(out["w"]), manual, atol=1e-6)


def test_gossip_kernel_equals_tree():
    n, d = 7, 130
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, d)),
               "b": jax.random.normal(jax.random.PRNGKey(1), (n, 5, 2))}
    mix = mixing_matrix(ring_adjacency(n), jnp.ones((n,)), 7)
    a = gossip_mix_tree(stacked, mix)
    b = gossip_mix_kernel(stacked, mix)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]), atol=1e-5)


def test_gluadfl_loss_decreases():
    # 80 rounds: enough signal that the 20%-drop bar holds with margin
    # (40 rounds sat right at the threshold), and > DEFAULT_CHUNK so the
    # scan engine crosses chunk boundaries
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=16).as_model()
    cfg = FLConfig(topology="random", num_nodes=6, rounds=80, comm_batch=3)
    tr = GluADFL(m, adam(5e-3), cfg)
    pop, hist, _ = tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=16)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.8, (first, last)


def test_gluadfl_population_is_node_mean():
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=3)
    tr = GluADFL(m, sgd(1e-2), cfg)
    pop, _, state = tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8)
    manual = tree_mean(state.params)
    assert float(tree_l2_norm(tree_sub(pop, manual))) < 1e-6


@pytest.mark.parametrize("topology", ["ring", "cluster", "random", "full"])
def test_gluadfl_all_topologies_run(topology):
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology=topology, num_nodes=6, rounds=4, comm_batch=3)
    tr = GluADFL(m, sgd(1e-2), cfg)
    pop, hist, _ = tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8)
    assert np.isfinite(hist[-1]["loss"])


def test_gluadfl_inactive_nodes_frozen():
    """With inactive_ratio=1 forced via mask, params must not change.
    We emulate by 0 learning rate + full inactivity robustness check."""
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="random", num_nodes=6, rounds=6, inactive_ratio=0.95)
    tr = GluADFL(m, sgd(1e-2), cfg)
    pop, hist, state = tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8)
    # staleness grows for nodes that sat out
    assert float(state.staleness.max()) > 0
    assert np.isfinite(hist[-1]["loss"])


def test_gluadfl_premix_vs_mixed_gradients_differ():
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=5)
    p1, _, _ = GluADFL(m, sgd(1e-2), cfg, grad_at="premix").train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8
    )
    p2, _, _ = GluADFL(m, sgd(1e-2), cfg, grad_at="mixed").train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8
    )
    assert float(tree_l2_norm(tree_sub(p1, p2))) > 0


def test_fedavg_loss_decreases():
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=16).as_model()
    cfg = FLConfig(num_nodes=6, rounds=30, local_steps=2)
    fa = FedAvg(m, adam(5e-3), cfg)
    params, hist = fa.train(jax.random.PRNGKey(0), x, y, counts, batch_size=16)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


def test_single_node_gluadfl_matches_local_sgd_shape():
    """Degenerate federation (N=1) must still train and return params of
    the right structure."""
    x, y, counts = _toy_fed(n=1)
    m = NBeatsModel(hidden=16).as_model()
    cfg = FLConfig(topology="ring", num_nodes=1, rounds=3)
    tr = GluADFL(m, sgd(1e-2), cfg)
    pop, hist, _ = tr.train(jax.random.PRNGKey(0), x, y, counts, batch_size=8)
    ref = m.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(pop) == jax.tree.structure(ref)


def test_dp_noise_broadcast_only():
    """Local-DP gossip (beyond-paper): neighbours see noised params, each
    node's own contribution stays clean; sigma=0 reduces to vanilla."""
    x, y, counts = _toy_fed()
    m = LSTMModel(hidden=8).as_model()
    cfg = FLConfig(topology="ring", num_nodes=6, rounds=5)
    p_clean, _, _ = GluADFL(m, sgd(1e-2), cfg, dp_noise_sigma=0.0).train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8
    )
    p_zero, _, _ = GluADFL(m, sgd(1e-2), cfg).train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8
    )
    assert float(tree_l2_norm(tree_sub(p_clean, p_zero))) < 1e-6
    p_dp, hist, _ = GluADFL(m, sgd(1e-2), cfg, dp_noise_sigma=0.05).train(
        jax.random.PRNGKey(0), x, y, counts, batch_size=8
    )
    # noised run differs but still trains (finite loss)
    assert float(tree_l2_norm(tree_sub(p_clean, p_dp))) > 0
    assert np.isfinite(hist[-1]["loss"])
