"""Synthetic-twin calibration: at FULL day counts the generator must
reproduce the Table-1 population moments it is calibrated against
(per-dataset mean/SD of per-patient means and SDs), stay deterministic
per (dataset, patient, seed), keep the NaN missing-rate inside its
envelope, and respect the CGM value range.  replace-bg is moment-checked
on a 32-patient cap (226 patients x 251 days is generator-minutes of
work; sampling 32 widens the across-patient-SD tolerances below)."""
import numpy as np
import pytest

from repro.data.synth import (
    DATASET_SPECS,
    SAMPLES_PER_DAY,
    generate_dataset,
    generate_patient_series,
    node_skew_offsets,
)

# dataset -> patient cap for the full-day moment checks
_CAPS = {"ohiot1dm": None, "abc4d": None, "ctr3": None, "replace-bg": 32}

_TRACE_CACHE: dict = {}


def _full_traces(name):
    if name not in _TRACE_CACHE:
        _TRACE_CACHE[name] = generate_dataset(name, max_patients=_CAPS[name])
    return _TRACE_CACHE[name]


@pytest.mark.parametrize("name", list(_CAPS))
def test_population_moments_match_table1(name):
    """Per-patient mean/SD moments vs the paper's Table 1 targets.

    Tolerances are set from the calibration itself (measured 2026-08 on
    the full generator): means land within ~3% of target, per-patient
    SDs within ~12% (the [40, 400] clip shaves dispersion), and the
    ACROSS-patient SDs — second moments of 12..32 samples — within
    ~30%; each bound below carries margin over the measured worst case
    but fails on a real calibration regression (2x drift trips every
    row)."""
    spec = DATASET_SPECS[name]
    traces = _full_traces(name)
    if _CAPS[name] is None:
        assert len(traces) == spec.num_patients
        assert all(len(t) == spec.num_days * SAMPLES_PER_DAY for t in traces)
    means = np.array([np.nanmean(t) for t in traces])
    sds = np.array([np.nanstd(t) for t in traces])

    assert abs(means.mean() - spec.mean_bg) / spec.mean_bg < 0.05, means.mean()
    assert abs(sds.mean() - spec.sd_bg) / spec.sd_bg < 0.15, sds.mean()
    assert abs(means.std(ddof=1) - spec.mean_bg_sd) / spec.mean_bg_sd < 0.35
    assert abs(sds.std(ddof=1) - spec.sd_bg_sd) / spec.sd_bg_sd < 0.50
    # ABC4D (pen therapy) must stay the most heterogeneous federation
    if name == "abc4d":
        assert sds.std(ddof=1) > 10.0


@pytest.mark.parametrize("name", list(_CAPS))
def test_missing_rate_envelope_and_value_range(name):
    """NaN dropout stays near the dataset's calibrated rate — population
    mean within +-35%, every patient within [0.5x, 2x] — and all real
    samples stay inside the CGM range [40, 400] mg/dL."""
    spec = DATASET_SPECS[name]
    traces = _full_traces(name)
    miss = np.array([np.isnan(t).mean() for t in traces])
    assert abs(miss.mean() - spec.missing_rate) / spec.missing_rate < 0.35
    assert miss.min() > 0.5 * spec.missing_rate
    assert miss.max() < 2.0 * spec.missing_rate
    for t in traces:
        vals = t[~np.isnan(t)]
        assert vals.min() >= 40.0 and vals.max() <= 400.0


def test_generator_determinism():
    """Same (dataset, patient, seed) -> bitwise-identical trace
    (including the NaN pattern); a different seed or patient id is a
    different trace; ``mean_shift=0.0`` is bitwise-free (the skew axis'
    serial-twin contract: the shift lands AFTER all RNG draws)."""
    spec = DATASET_SPECS["ohiot1dm"]
    a = generate_patient_series(spec, 3, days=4, seed=5)
    b = generate_patient_series(spec, 3, days=4, seed=5)
    np.testing.assert_array_equal(a, b)
    c = generate_patient_series(spec, 3, days=4, seed=6)
    d = generate_patient_series(spec, 4, days=4, seed=5)
    assert not np.array_equal(a, c) and not np.array_equal(a, d)
    e = generate_patient_series(spec, 3, days=4, seed=5, mean_shift=0.0)
    np.testing.assert_array_equal(a, e)
    # dataset-level: two identical calls agree trace-for-trace
    f1 = generate_dataset("ctr3", fast=True, max_patients=3)
    f2 = generate_dataset("ctr3", fast=True, max_patients=3)
    for t1, t2 in zip(f1, f2):
        np.testing.assert_array_equal(t1, t2)


def test_dataset_skew_shifts_patient_means():
    """``generate_dataset(skew=s)`` moves patient p's level by
    ``s * mean_bg_sd * node_skew_offsets(n)[p]`` (up to the [40, 400]
    clip): the first/last patients separate by about the full span and
    ``skew=0`` stays bitwise-identical to the unskewed dataset."""
    name, n, skew = "ohiot1dm", 6, 1.0
    spec = DATASET_SPECS[name]
    base = generate_dataset(name, fast=True, max_patients=n)
    skewed = generate_dataset(name, fast=True, max_patients=n, skew=skew)
    zero = generate_dataset(name, fast=True, max_patients=n, skew=0.0)
    for t0, tz in zip(base, zero):
        np.testing.assert_array_equal(t0, tz)
    offsets = node_skew_offsets(n)
    shifts = np.array(
        [np.nanmean(s) - np.nanmean(b) for s, b in zip(skewed, base)]
    )
    expected = skew * spec.mean_bg_sd * offsets
    # the clip and NaN masks blur individual shifts; the SPAN must show
    span = shifts[-1] - shifts[0]
    expected_span = expected[-1] - expected[0]
    assert span > 0.5 * expected_span, (shifts, expected)
    # and the ordering of patient means must follow the offsets
    assert np.all(np.diff(shifts) > -5.0)


def test_node_skew_offsets_contract():
    """Offsets are centered (zero-sum), span exactly [-1, 1], monotone,
    and degenerate federations (n <= 1) get all-zeros."""
    for n in (2, 5, 12):
        off = node_skew_offsets(n)
        assert off.shape == (n,) and off.dtype == np.float32
        assert off[0] == -1.0 and off[-1] == 1.0
        assert abs(off.sum()) < 1e-5
        assert np.all(np.diff(off) > 0)
    np.testing.assert_array_equal(node_skew_offsets(1), np.zeros((1,), np.float32))
    np.testing.assert_array_equal(node_skew_offsets(0), np.zeros((0,), np.float32))
