"""Sparse (neighbor-table) gossip parity — the ``gossip_repr="sparse"``
representation must match the dense ``mixing_matrix`` contraction to
float tolerance (bitwise for inactive rows) at every layer: raw
contraction, trainer rounds at the paper's N=226 across all five
topologies (with active masks and DP noise), the sweep grid, and the
sharded mixer on a forced-8-device mesh (``multidevice`` marker)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL
from repro.core.gossip import gossip_mix_sparse_tree, gossip_mix_tree
from repro.core.topology import (
    densify_neighbor_table,
    neighbor_table,
    random_adjacency,
)
from repro.models import LSTMModel
from repro.optim import sgd
from repro.utils.pytree import tree_l2_norm, tree_sub

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# contraction-level parity
# ---------------------------------------------------------------------------


def test_sparse_tree_matches_dense_tree():
    n, d = 30, 130
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    adj = random_adjacency(k[0], n, 5)
    active = (jax.random.uniform(k[1], (n,)) > 0.4).astype(jnp.float32)
    idx, wgt = neighbor_table(adj, active, 5)
    w = {"a": jax.random.normal(k[2], (n, d)), "b": jnp.ones((n, 3, 7))}
    sparse = gossip_mix_sparse_tree(w, idx, wgt, active)
    dense = gossip_mix_tree(w, densify_neighbor_table(idx, wgt))
    for kk in w:
        np.testing.assert_allclose(
            np.asarray(sparse[kk]), np.asarray(dense[kk]), atol=1e-5
        )
        for i in np.where(np.asarray(active) == 0)[0]:
            np.testing.assert_array_equal(
                np.asarray(sparse[kk])[i], np.asarray(w[kk])[i]
            )


# ---------------------------------------------------------------------------
# trainer-level parity at the paper's scale (N=226, REPLACE-BG)
# ---------------------------------------------------------------------------

N226 = 226


def _federation(n, windows=16, hist=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, windows, hist)).astype(np.float32)
    y = (x @ rng.normal(size=(hist,)).astype(np.float32)).astype(np.float32)
    return x, y, np.full((n,), windows, np.int32)


def _chunk_losses(cfg, x, y, counts, *, gossip_repr, mixer="tree", sigma=0.0,
                  rounds=2):
    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg, mixer=mixer,
                 dp_noise_sigma=sigma, gossip_repr=gossip_repr)
    state = tr.init(jax.random.PRNGKey(0))
    state, losses = tr.train_chunk(state, x, y, counts, batch_size=4,
                                   chunk=rounds)
    return state, np.asarray(losses)


@pytest.mark.parametrize("topology", ["ring", "cluster", "star", "full", "random"])
def test_trainer_sparse_matches_dense_n226(topology):
    """Paper-scale parity: 2 rounds at N=226 with a 30% inactive mask and
    DP broadcast noise — sparse and dense trainers consume the identical
    key stream, so losses match to float tolerance and the final params
    differ only by contraction reassociation."""
    x, y, counts = _federation(N226)
    cfg = FLConfig(topology=topology, num_nodes=N226, rounds=2, comm_batch=7,
                   inactive_ratio=0.3)
    sd, ld = _chunk_losses(cfg, x, y, counts, gossip_repr="dense", sigma=0.05)
    ss, ls = _chunk_losses(cfg, x, y, counts, gossip_repr="sparse", sigma=0.05)
    np.testing.assert_allclose(ld, ls, atol=1e-5)
    assert float(tree_l2_norm(tree_sub(sd.params, ss.params))) < 1e-4


def test_trainer_sparse_kernel_matches_dense_kernel_n226():
    """The fused sparse DP kernel path against the fused dense DP kernel
    at N=226 (mixer="kernel" exercises ops.py padding + Pallas body)."""
    x, y, counts = _federation(N226)
    cfg = FLConfig(topology="random", num_nodes=N226, rounds=2, comm_batch=7,
                   inactive_ratio=0.3)
    sd, ld = _chunk_losses(cfg, x, y, counts, gossip_repr="dense",
                           mixer="kernel", sigma=0.05)
    ss, ls = _chunk_losses(cfg, x, y, counts, gossip_repr="sparse",
                           mixer="kernel", sigma=0.05)
    np.testing.assert_allclose(ld, ls, atol=1e-5)
    assert float(tree_l2_norm(tree_sub(sd.params, ss.params))) < 1e-4


def test_trainer_inactive_rows_bitwise_frozen_sparse():
    """Inactive nodes' params are BITWISE identical between sparse and
    dense runs (both freeze them with a where-select)."""
    n = 32
    x, y, counts = _federation(n)
    cfg = FLConfig(topology="ring", num_nodes=n, rounds=3, comm_batch=7,
                   inactive_ratio=0.6)
    sd, _ = _chunk_losses(cfg, x, y, counts, gossip_repr="dense", rounds=3)
    ss, _ = _chunk_losses(cfg, x, y, counts, gossip_repr="sparse", rounds=3)
    # staleness > 0 marks nodes inactive in the LAST round: their rows
    # were frozen that round, so both reprs carry the same bits forward
    stale = np.asarray(sd.staleness) > 0
    np.testing.assert_array_equal(np.asarray(sd.staleness),
                                  np.asarray(ss.staleness))
    assert stale.any(), "want at least one inactive node in the last round"
    for a, b in zip(jax.tree.leaves(sd.params), jax.tree.leaves(ss.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sweep_grid_sparse_matches_dense_n226():
    """The sweep engine under ``gossip_repr="sparse"``: all five
    topologies as one vmapped grid at N=226 match the dense sweep's
    losses scenario-for-scenario."""
    from repro.core import SweepGrid

    x, y, counts = _federation(N226, windows=8)
    topos = ["ring", "cluster", "star", "full", "random"]
    grid = SweepGrid.build(topos, [0.4], [0], num_nodes=N226)

    def sweep(repr_):
        cfg = FLConfig(topology="ring", num_nodes=N226, rounds=2, comm_batch=7)
        tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                     gossip_repr=repr_)
        return tr.train_sweep(x, y, counts, grid=grid, batch_size=4, chunk=2)

    pops_d, h_dense, _ = sweep("dense")
    pops_s, h_sparse, _ = sweep("sparse")
    assert float(tree_l2_norm(tree_sub(pops_d, pops_s))) < 1e-4
    for g, label in enumerate(grid.labels):
        for rd, rs in zip(h_dense[g], h_sparse[g]):
            assert abs(rd["loss"] - rs["loss"]) < 1e-5, (label, rd, rs)


def test_sparse_ring_scales_without_dense_matrix():
    """Population-scale smoke: a 1 000-node ring federation trains a
    round through the candidate-list path (the trainer holds a (N, 3)
    table; no (N, N) array exists in the round program)."""
    n = 1000
    x, y, counts = _federation(n, windows=2, hist=6)
    cfg = FLConfig(topology="ring", num_nodes=n, rounds=1, comm_batch=7,
                   inactive_ratio=0.2)
    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                 gossip_repr="sparse")
    assert tr._neighbor_cand is not None
    assert tr._neighbor_cand[0].shape == (n, 2)  # ring: 2 candidates/node
    state = tr.init(jax.random.PRNGKey(0))
    state, loss = tr.train_chunk(state, x, y, counts, batch_size=2, chunk=1)
    assert np.isfinite(np.asarray(loss)).all()


# ---------------------------------------------------------------------------
# knob resolution
# ---------------------------------------------------------------------------


def test_auto_gossip_repr_resolution():
    from repro.launch.mesh import choose_gossip_repr

    assert choose_gossip_repr(226, 7) == "sparse"   # paper scale
    assert choose_gossip_repr(16, 7) == "dense"     # smoke scale
    assert choose_gossip_repr(32, 7) == "sparse"    # boundary: 4*(7+1)
    assert choose_gossip_repr(31, 7) == "dense"

    cfg = FLConfig(topology="ring", num_nodes=226, rounds=1, comm_batch=7)
    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                 gossip_repr="auto")
    assert tr.gossip_repr == "sparse"
    cfg16 = FLConfig(topology="ring", num_nodes=16, rounds=1, comm_batch=7)
    tr16 = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg16,
                   gossip_repr="auto")
    assert tr16.gossip_repr == "dense"


def test_bad_gossip_repr_rejected():
    with pytest.raises(ValueError, match="gossip_repr"):
        GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2),
                FLConfig(num_nodes=4, rounds=1), gossip_repr="csr")


# ---------------------------------------------------------------------------
# sharded mixer (multidevice)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_sharded_sparse_matches_dense_and_tree():
    """``sharded_gossip_mix_sparse`` on 8 forced devices == the dense
    sharded mix == the single-device tree reference, with bit-exact
    inactive rows."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_gossip_mix, sharded_gossip_mix_sparse
        from repro.core.gossip import gossip_mix_tree
        from repro.core.topology import mixing_matrix, neighbor_table, random_adjacency
        N, D = 8, 96
        k = jax.random.split(jax.random.PRNGKey(0), 4)
        w = {"a": jax.random.normal(k[0], (N, D)),
             "b": jax.random.normal(k[1], (N, 3, 7))}
        active = (jax.random.uniform(k[2], (N,)) > 0.4).astype(jnp.float32)
        adj = random_adjacency(jax.random.PRNGKey(7), N, 3)
        mix = mixing_matrix(adj, active, 3)
        idx, wgt = neighbor_table(adj, active, 3)
        sp = jax.jit(lambda ww, ii, gg, aa: sharded_gossip_mix_sparse(ww, ii, gg, aa))(w, idx, wgt, active)
        dn = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(ww, mm, aa))(w, mix, active)
        ref = gossip_mix_tree(w, mix)
        for kk in w:
            np.testing.assert_allclose(np.asarray(sp[kk]), np.asarray(dn[kk]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(sp[kk]), np.asarray(ref[kk]), atol=1e-5)
            bad = np.where(np.asarray(active) == 0)[0]
            np.testing.assert_array_equal(np.asarray(sp[kk])[bad], np.asarray(w[kk])[bad])
        print("SHARDED_SPARSE_OK")
    """))


@pytest.mark.multidevice
def test_sharded_sparse_grid_stacked():
    """Grid-stacked (G, N, B+1) tables on a 2-D ("grid", "node") mesh:
    the sparse shard body batches under the grid axis exactly like the
    dense one (scenario-for-scenario parity)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import sharded_gossip_mix, sharded_gossip_mix_sparse
        from repro.core.topology import (mixing_matrix_stacked, random_adjacency,
                                         stacked_neighbor_table)
        G, N, D = 4, 8, 64
        mesh = jax.make_mesh((2, 4), ("grid", "node"))
        adjs = jnp.stack([random_adjacency(jax.random.PRNGKey(i), N, 3) for i in range(G)])
        acts = (jax.random.uniform(jax.random.PRNGKey(9), (G, N)) > 0.3).astype(jnp.float32)
        si, sw = stacked_neighbor_table(adjs, acts, 3)
        ms = mixing_matrix_stacked(adjs, acts, 3)
        w = {"a": jax.random.normal(jax.random.PRNGKey(1), (G, N, D))}
        sp = jax.jit(lambda ww, ii, gg, aa: sharded_gossip_mix_sparse(ww, ii, gg, aa, mesh=mesh))(w, si, sw, acts)
        dn = jax.jit(lambda ww, mm, aa: sharded_gossip_mix(ww, mm, aa, mesh=mesh))(w, ms, acts)
        np.testing.assert_allclose(np.asarray(sp["a"]), np.asarray(dn["a"]), atol=1e-5)
        print("GRID_SPARSE_OK")
    """))


@pytest.mark.multidevice
def test_trainer_sharded_sparse_trains_like_dense():
    """GluADFL end-to-end with mixer="sharded": gossip_repr="sparse"
    matches the dense sharded run's losses and final params."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_l2_norm, tree_sub
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8, 20, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((8,), 20, np.int32)
        cfg = FLConfig(topology="random", num_nodes=8, rounds=4,
                       comm_batch=3, inactive_ratio=0.25)
        def train(repr_):
            tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                         mixer="sharded", gossip_repr=repr_, dp_noise_sigma=0.02)
            return tr.train(jax.random.PRNGKey(0), x, y, counts,
                            batch_size=8, chunk=4)
        p_d, h_d, _ = train("dense")
        p_s, h_s, _ = train("sparse")
        assert len(h_d) == len(h_s) == 4
        assert float(tree_l2_norm(tree_sub(p_d, p_s))) < 1e-4
        for a, b in zip(h_d, h_s):
            assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)
        print("SHARDED_SPARSE_TRAIN_OK")
    """))


@pytest.mark.multidevice
def test_swept_sharded_sparse_matches_dense():
    """The swept-sharded engine (vmap with spmd_axis_name over the 2-D
    sweep mesh) under gossip_repr="sparse": per-scenario losses match
    the dense swept-sharded run."""
    print(_run("""
        import jax, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL, SweepGrid
        from repro.launch.mesh import make_sweep_mesh
        from repro.models import LSTMModel
        from repro.optim import sgd
        rng = np.random.default_rng(0)
        N = 8
        x = rng.normal(size=(N, 10, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((N,), 10, np.int32)
        grid = SweepGrid.build(["ring", "random"], [0.0, 0.5], [0], num_nodes=N)
        mesh = make_sweep_mesh(grid.size, N)
        def sweep(repr_):
            cfg = FLConfig(topology="ring", num_nodes=N, rounds=2, comm_batch=3)
            tr = GluADFL(LSTMModel(hidden=8).as_model(), sgd(1e-2), cfg,
                         mixer="sharded", gossip_repr=repr_, mesh=mesh)
            return tr.train_sweep(x, y, counts, grid=grid, batch_size=4, chunk=2)
        _, h_d, _ = sweep("dense")
        _, h_s, _ = sweep("sparse")
        for g in range(grid.size):
            for rd, rs in zip(h_d[g], h_s[g]):
                assert abs(rd["loss"] - rs["loss"]) < 1e-5, (g, rd, rs)
        print("SWEPT_SHARDED_SPARSE_OK")
    """))
