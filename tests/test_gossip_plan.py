"""GossipPlan resolution — totality over the full knob product, the
``use_kernel`` deprecation shim, the mesh-aware auto-repr policy, the
gather-table backend's refusals and its parity against the sparse
allgather schedule (single device and forced-8-device ``multidevice``
runs at the paper's N=226 and at N=10,000)."""
import importlib.util
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import GluADFL, GossipPlanError
from repro.core.distributed import GOSSIP_IMPLS, GOSSIP_REPRS
from repro.core.gossip import gossip_mix_sparse_tree
from repro.core.gossip_plan import (
    MIXERS,
    choose_gossip_impl,
    choose_gossip_repr,
    mix_backends,
    resolve_gossip_plan,
    supported_cells,
)
from repro.core.topology import neighbor_table, random_adjacency
from repro.models import LSTMModel
from repro.optim import sgd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _resolve(**kw):
    kw.setdefault("num_nodes", 8)
    kw.setdefault("comm_batch", 2)
    return resolve_gossip_plan(**kw)


# ---------------------------------------------------------------------------
# totality: every cell of the knob product resolves or refuses loudly
# ---------------------------------------------------------------------------


def test_plan_totality_full_knob_product():
    """Every (mixer, gossip_impl, gossip_repr) triple either resolves to
    a registered backend or raises a knob-naming error — no silent
    fallthrough.  The supported set is exactly: every dense-wire impl on
    every mixer, plus gather on (sharded, sparse) only."""
    cells = {
        (c["mixer"], c["gossip_impl"], c["gossip_repr"])
        for c in supported_cells()
    }
    expected = set()
    for mixer in MIXERS:
        for impl in GOSSIP_IMPLS:
            for repr_ in GOSSIP_REPRS:
                if impl == "gather":
                    if mixer == "sharded" and repr_ == "sparse":
                        expected.add((mixer, impl, repr_))
                else:
                    expected.add((mixer, impl, repr_))
    assert cells == expected

    registered = set(mix_backends())
    for mixer in MIXERS:
        for impl in GOSSIP_IMPLS:
            for repr_ in GOSSIP_REPRS:
                if (mixer, impl, repr_) in cells:
                    plan = _resolve(mixer=mixer, gossip_impl=impl,
                                    gossip_repr=repr_)
                    assert plan.backend in registered
                    assert plan.mixer == mixer
                    assert plan.gossip_repr == repr_
                    assert plan.masked == (impl == "masked")
                else:
                    with pytest.raises(ValueError) as e:
                        _resolve(mixer=mixer, gossip_impl=impl,
                                 gossip_repr=repr_)
                    # refusals are GossipPlanError (a ValueError) and
                    # name the offending knob value
                    assert isinstance(e.value, GossipPlanError)
                    assert "gather" in str(e.value)


def test_plan_totality_matches_knob_matrix_generator():
    """The doc generator and the totality test read the same registry:
    every supported cell's backend shows up in the generated matrix and
    the gather row carries its memory class."""
    spec = importlib.util.spec_from_file_location(
        "gen_knob_matrix", os.path.join(ROOT, "tools", "gen_knob_matrix.py")
    )
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    block = gen.generate()
    for mixer in MIXERS:
        assert f'`mixer="{mixer}"`' in block
    gather = mix_backends()["sharded_gather_tables"]
    assert gather.name in block
    assert gather.caps.memory_class in block
    # refused sweep cells document the refusal in the same table
    assert "raises" in block


def test_unknown_knob_values_name_the_registry():
    with pytest.raises(ValueError, match=r"mixer 'fft' not in"):
        _resolve(mixer="fft")
    with pytest.raises(ValueError, match=r"gossip_impl 'rdma' not in"):
        _resolve(gossip_impl="rdma")
    with pytest.raises(ValueError, match=r"gossip_repr 'csr' not in"):
        _resolve(gossip_repr="csr")


def test_bad_gossip_repr_message_lists_reprs_and_auto():
    """The satellite fix: the refusal prints the actual GOSSIP_REPRS
    tuple (not a mangled concatenation) and explains 'auto'."""
    with pytest.raises(ValueError) as e:
        _resolve(gossip_repr="csr")
    msg = str(e.value)
    assert str(GOSSIP_REPRS) in msg
    assert "auto" in msg
    # same message through the trainer constructor
    with pytest.raises(ValueError, match="auto"):
        GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2),
                FLConfig(num_nodes=4, rounds=1), gossip_repr="csr")


# ---------------------------------------------------------------------------
# the use_kernel deprecation shim
# ---------------------------------------------------------------------------


def test_use_kernel_flag_warns_and_maps():
    with pytest.warns(DeprecationWarning, match="use_kernel is deprecated"):
        plan = _resolve(use_kernel=True)
    assert plan.mixer == "kernel"
    assert plan.use_kernel  # the fused-DP capability mirrors the mixer


def test_plain_kernel_mixer_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plan = _resolve(mixer="kernel")
    assert plan.mixer == "kernel"
    assert plan.use_kernel


def test_use_kernel_conflicting_mixer_rejected():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="contradicts"):
            _resolve(use_kernel=True, mixer="tree")


def test_trainer_use_kernel_warns_and_maps():
    cfg = FLConfig(num_nodes=4, rounds=1)
    with pytest.warns(DeprecationWarning, match="use_kernel is deprecated"):
        tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                     use_kernel=True)
    assert tr.mixer == "kernel"
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tr2 = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                      mixer="kernel")
    assert tr2.mixer == "kernel"


def test_launcher_use_kernel_shim():
    """The --use-kernel launcher path: the flag warns (visible under
    -W error) and a contradicting --mixer exits before any training."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    warn = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning:__main__", "-m",
         "repro.launch.train", "--use-kernel"],
        capture_output=True, text=True, env=env, timeout=120,
    )
    assert warn.returncode != 0
    assert "--use-kernel is deprecated" in warn.stderr
    conflict = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--use-kernel",
         "--mixer", "tree"],
        capture_output=True, text=True, env=env, timeout=120,
    )
    assert conflict.returncode != 0
    assert "contradicts --mixer tree" in conflict.stderr


# ---------------------------------------------------------------------------
# plan-resolution policies
# ---------------------------------------------------------------------------


def test_choose_gossip_repr_mesh_aware():
    # plain flop heuristic, mesh-free: boundary at factor * (B+1)
    assert choose_gossip_repr(31, 7) == "dense"
    assert choose_gossip_repr(32, 7) == "sparse"
    # mesh path: same (N, B) flips to sparse once the per-device
    # (N/shards, N) row block outgrows the budget
    mesh = jax.make_mesh((1,), ("node",))
    assert choose_gossip_repr(31, 7, mesh=mesh) == "dense"
    assert choose_gossip_repr(31, 7, mesh=mesh, budget_bytes=31 * 31) == "sparse"
    # grid/model axes don't count toward the node width
    gm = jax.make_mesh((1, 1), ("grid", "node"))
    assert choose_gossip_repr(31, 7, mesh=gm, budget_bytes=31 * 31) == "sparse"


def test_choose_gossip_impl_secure_past_budget_refuses():
    assert choose_gossip_impl(8, 4, shards=2, secure=True) == "masked"
    with pytest.raises(GossipPlanError, match="masked"):
        choose_gossip_impl(1000, 1 << 20, shards=2, budget_bytes=1 << 10,
                           secure=True)


def test_auto_repr_through_trainer_uses_plan_policy():
    cfg = FLConfig(topology="ring", num_nodes=226, rounds=1, comm_batch=7)
    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                 gossip_repr="auto")
    assert tr.gossip_repr == "sparse"
    assert tr.plan.gossip_repr == "sparse"


# ---------------------------------------------------------------------------
# the gather-tables backend: refusals + single-device parity
# ---------------------------------------------------------------------------


def test_gather_refuses_non_sharded_mixer_and_dense_repr():
    with pytest.raises(GossipPlanError, match="needs mixer"):
        _resolve(mixer="tree", gossip_impl="gather", gossip_repr="sparse")
    with pytest.raises(GossipPlanError, match="needs gossip_repr='sparse'"):
        _resolve(mixer="sharded", gossip_impl="gather", gossip_repr="dense")
    with pytest.raises(ValueError, match="gossip_impl"):
        GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2),
                FLConfig(num_nodes=8, rounds=1), mixer="kernel",
                gossip_impl="gather", gossip_repr="sparse")


def test_gather_plan_refuses_sweep_but_offers_multihost():
    plan = _resolve(mixer="sharded", gossip_impl="gather",
                    gossip_repr="sparse")
    with pytest.raises(NotImplementedError, match="gather"):
        plan.require_sweep()
    plan.require_multihost()  # the scale-out schedule spans processes
    with pytest.raises(ValueError, match="sharded"):
        _resolve(mixer="tree").require_multihost()


def test_trainer_gather_sweep_refused():
    cfg = FLConfig(topology="ring", num_nodes=8, rounds=1, comm_batch=2)
    tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                 mixer="sharded", gossip_impl="gather", gossip_repr="sparse")
    from repro.core import SweepGrid

    grid = SweepGrid.build(["ring"], [0.0], [0], num_nodes=8)
    x = np.zeros((8, 4, 12), np.float32)
    y = np.zeros((8, 4), np.float32)
    counts = np.full((8,), 4, np.int32)
    with pytest.raises(NotImplementedError, match="gather"):
        tr.train_sweep(x, y, counts, grid=grid, batch_size=4, chunk=1)


def test_gather_matches_sparse_tree_single_device():
    """n_shards=1 degenerates to the local contraction: the gather mix
    equals the sparse tree reference, inactive rows bitwise."""
    from repro.core.distributed import sharded_gossip_mix_gather

    n, d = 24, 60
    k = jax.random.split(jax.random.PRNGKey(3), 3)
    adj = random_adjacency(k[0], n, 4)
    active = (jax.random.uniform(k[1], (n,)) > 0.4).astype(jnp.float32)
    idx, wgt = neighbor_table(adj, active, 4)
    w = {"a": jax.random.normal(k[2], (n, d)), "b": jnp.ones((n, 3, 5))}
    got = sharded_gossip_mix_gather(w, idx, wgt, active)
    ref = gossip_mix_sparse_tree(w, idx, wgt, active)
    for kk in w:
        np.testing.assert_allclose(np.asarray(got[kk]), np.asarray(ref[kk]),
                                   atol=1e-5)
        for i in np.where(np.asarray(active) == 0)[0]:
            np.testing.assert_array_equal(np.asarray(got[kk])[i],
                                          np.asarray(w[kk])[i])


def test_trainer_gather_trains_single_device():
    n = 8
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 8, 12)).astype(np.float32)
    y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
    counts = np.full((n,), 8, np.int32)
    cfg = FLConfig(topology="ring", num_nodes=n, rounds=2, comm_batch=3,
                   inactive_ratio=0.25)

    def train(impl):
        tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                     mixer="sharded", gossip_impl=impl, gossip_repr="sparse")
        st = tr.init(jax.random.PRNGKey(0))
        st, losses = tr.train_chunk(st, x, y, counts, batch_size=4, chunk=2)
        return st, np.asarray(losses)

    sg, lg = train("gather")
    sa, la = train("allgather")
    np.testing.assert_allclose(lg, la, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sg.staleness),
                                  np.asarray(sa.staleness))


# ---------------------------------------------------------------------------
# gather vs sparse allgather on 8 forced devices (multidevice)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_gather_matches_sparse_allgather_10k_nodes():
    """Contraction-level parity at N=10,000 over 8 shards: the
    ring-rotating gather-table schedule equals the sparse allgather mix
    to 1e-5 (different summation order), inactive rows bitwise — and no
    gathered (N, D) federation is needed to check it."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import (sharded_gossip_mix_gather,
                                            sharded_gossip_mix_sparse)
        N, B, D = 10_000, 3, 48
        rng = np.random.default_rng(0)
        idx = rng.integers(0, N, size=(N, B + 1)).astype(np.int32)
        idx[:, 0] = np.arange(N)
        wgt = rng.uniform(0.1, 1.0, size=(N, B + 1)).astype(np.float32)
        wgt /= wgt.sum(1, keepdims=True)
        active = (rng.uniform(size=N) > 0.3).astype(np.float32)
        inact = active == 0
        wgt[inact] = 0.0
        wgt[inact, 0] = 1.0
        idx[inact, 1:] = idx[inact, :1]
        w = {"a": rng.normal(size=(N, D)).astype(np.float32),
             "b": rng.normal(size=(N, 3, 5)).astype(np.float32)}
        w = jax.tree.map(jnp.asarray, w)
        ga = jax.jit(lambda ww, ii, gg, aa: sharded_gossip_mix_gather(ww, ii, gg, aa))(
            w, idx, wgt, active)
        sp = jax.jit(lambda ww, ii, gg, aa: sharded_gossip_mix_sparse(ww, ii, gg, aa))(
            w, idx, wgt, active)
        bad = np.where(inact)[0]
        for kk in w:
            np.testing.assert_allclose(np.asarray(ga[kk]), np.asarray(sp[kk]),
                                       atol=1e-5)
            np.testing.assert_array_equal(np.asarray(ga[kk])[bad],
                                          np.asarray(w[kk])[bad])
        print("GATHER_10K_OK")
    """))


@pytest.mark.multidevice
def test_trainer_gather_matches_sparse_allgather_n226():
    """GluADFL end-to-end at the paper's N=226 (2 node shards on the
    8-device box): gossip_impl="gather" matches the sparse allgather
    run's losses to 1e-5 with identical staleness (inactive-row bitwise
    parity is pinned at the contraction level above)."""
    print(_run("""
        import numpy as np, jax
        from repro.config import FLConfig
        from repro.core import GluADFL
        from repro.models import LSTMModel
        from repro.optim import sgd
        N = 226
        rng = np.random.default_rng(0)
        x = rng.normal(size=(N, 8, 12)).astype(np.float32)
        y = (x @ rng.normal(size=(12,)).astype(np.float32)).astype(np.float32)
        counts = np.full((N,), 8, np.int32)
        cfg = FLConfig(topology="random", num_nodes=N, rounds=3,
                       comm_batch=7, inactive_ratio=0.3)
        def train(impl):
            tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg,
                         mixer="sharded", gossip_impl=impl,
                         gossip_repr="sparse")
            st = tr.init(jax.random.PRNGKey(0))
            st, losses = tr.train_chunk(st, x, y, counts, batch_size=4,
                                        chunk=3)
            return st, np.asarray(losses)
        sg, lg = train("gather")
        sa, la = train("allgather")
        np.testing.assert_allclose(lg, la, atol=1e-5)
        st_g = np.asarray(sg.staleness)
        np.testing.assert_array_equal(st_g, np.asarray(sa.staleness))
        assert (st_g > 0).any(), "want inactive nodes in the last round"
        for a, b in zip(jax.tree.leaves(sg.params), jax.tree.leaves(sa.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
        print("GATHER_N226_OK")
    """))
