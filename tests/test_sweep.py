"""Scenario-sweep engine tests: the vmapped (topology x inactive-ratio
x seed) grid must NUMERICALLY MATCH per-config serial train() runs —
params, losses, streaming-eval records — including DP-noise and
inactive-mask cases, plus the batched topology/scheduling builders the
engine is made of.  An 8-forced-device subprocess case pins the same
parity on the multi-device path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, SweepConfig
from repro.core import GluADFL, SweepGrid, sweep_active_masks
from repro.core.async_sched import bernoulli_active
from repro.core.topology import (
    cluster_adjacency,
    mixing_matrix,
    mixing_matrix_stacked,
    ring_adjacency,
    stacked_adjacency,
)
from repro.models import LSTMModel
from repro.optim import adam, sgd
from repro.utils.pytree import tree_index, tree_l2_norm, tree_sub

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _toy_fed(n=6, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, m)).astype(np.float32)
    counts = np.full((n,), m, np.int32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)


def _val_set(m=24, L=12, seed=7):
    rng = np.random.default_rng(seed)
    vx = rng.normal(size=(m, L)).astype(np.float32)
    vy = (vx @ rng.normal(size=(L,)).astype(np.float32)).astype(np.float32)
    return jnp.asarray(vx), jnp.asarray(vy)


# ----------------------------------------------------------------------
# batched builders
# ----------------------------------------------------------------------

def test_stacked_adjacency_matches_static_builders():
    n = 8
    adj, resample = stacked_adjacency(["ring", "cluster", "random"], n)
    assert adj.shape == (3, n, n) and resample.shape == (3,)
    np.testing.assert_array_equal(np.asarray(adj[0]), np.asarray(ring_adjacency(n)))
    np.testing.assert_array_equal(
        np.asarray(adj[1]), np.asarray(cluster_adjacency(n, 4))
    )
    # "random" scenarios: zero placeholder + resample flag
    np.testing.assert_array_equal(np.asarray(adj[2]), np.zeros((n, n)))
    np.testing.assert_array_equal(np.asarray(resample), [0.0, 0.0, 1.0])


def test_stacked_adjacency_unknown_topology_raises():
    with pytest.raises(KeyError):
        stacked_adjacency(["ring", "moebius"], 8)


def test_mixing_matrix_stacked_matches_single():
    n = 8
    adj, _ = stacked_adjacency(["ring", "cluster"], n)
    key = jax.random.PRNGKey(0)
    active = (jax.random.uniform(key, (2, n)) > 0.3).astype(jnp.float32)
    stacked = mixing_matrix_stacked(adj, active, 3)
    for g in range(2):
        np.testing.assert_array_equal(
            np.asarray(stacked[g]),
            np.asarray(mixing_matrix(adj[g], active[g], 3)),
        )


def test_sweep_active_masks_per_scenario_keys():
    """(G, N) masks: scenario g bitwise-matches bernoulli_active on the
    g-th split key; ratio 0 activates everyone, high ratio >= 1 active."""
    key = jax.random.PRNGKey(3)
    ratios = jnp.asarray([0.0, 0.4, 0.99])
    masks = sweep_active_masks(key, 16, ratios)
    assert masks.shape == (3, 16)
    keys = jax.random.split(key, 3)
    for g, r in enumerate([0.0, 0.4, 0.99]):
        np.testing.assert_array_equal(
            np.asarray(masks[g]),
            np.asarray(bernoulli_active(keys[g], 16, jnp.float32(r))),
        )
    np.testing.assert_array_equal(np.asarray(masks[0]), np.ones(16))
    assert np.asarray(masks).sum(axis=1).min() >= 1


def test_bernoulli_active_traced_ratio_matches_concrete_shortcut():
    """The sweep engine feeds the ratio as a TRACED scalar; ratio 0 must
    still mean 'everyone active', matching the python-float shortcut."""
    key = jax.random.PRNGKey(11)
    concrete = bernoulli_active(key, 12, 0.0)
    traced = jax.jit(lambda r: bernoulli_active(key, 12, r))(jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(concrete), np.asarray(traced))


def test_sweep_grid_build_layout():
    grid = SweepGrid.build(("ring", "random"), (0.0, 0.5), (0, 1), num_nodes=6)
    assert grid.size == 8
    # topology-major, then ratio, then seed — the documented order
    assert grid.labels[0] == ("ring", 0.0, 0)
    assert grid.labels[1] == ("ring", 0.0, 1)
    assert grid.labels[4] == ("random", 0.0, 0)
    assert grid.adjacency.shape == (8, 6, 6)
    np.testing.assert_array_equal(
        np.asarray(grid.resample), [0, 0, 0, 0, 1, 1, 1, 1]
    )
    np.testing.assert_array_equal(
        np.asarray(grid.init_keys[1]), np.asarray(jax.random.PRNGKey(1))
    )
    cfg = SweepConfig()
    fig5 = SweepGrid.build(cfg.topologies, cfg.inactive_ratios,
                           cfg.seed_list(), num_nodes=6)
    assert fig5.size == 15  # the paper's Fig-5 grid


# ----------------------------------------------------------------------
# engine parity
# ----------------------------------------------------------------------

def _serial_histories(model, grid, x, y, counts, *, rounds, chunk,
                      dp_sigma=0.0, optimizer=None, eval_every=0, val=None):
    """Per-config serial train() runs — the oracle the sweep must match."""
    pops, hists, states = [], [], []
    for topo, ratio, seed in grid.labels:
        cfg = FLConfig(topology=topo, num_nodes=x.shape[0], comm_batch=3,
                       rounds=rounds, inactive_ratio=ratio)
        tr = GluADFL(model, optimizer or sgd(1e-2), cfg, dp_noise_sigma=dp_sigma)
        pop, hist, st = tr.train(
            jax.random.PRNGKey(seed), x, y, counts, batch_size=8, chunk=chunk,
            eval_every=eval_every, val_data=val,
        )
        pops.append(pop)
        hists.append(hist)
        states.append(st)
    return pops, hists, states


@pytest.mark.parametrize("dp_sigma", [0.0, 0.05])
def test_train_sweep_matches_serial_runs(dp_sigma):
    """The whole vmapped grid == per-config serial train(): losses,
    streaming-eval records, population params and final state — incl.
    the DP-noise path and non-zero inactive ratios, across a chunk
    remainder (rounds % chunk != 0)."""
    rounds, chunk, eval_every = 6, 4, 2
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    val = _val_set()
    if dp_sigma:
        grid = SweepGrid.build(("cluster", "random"), (0.3,), (0,),
                               num_nodes=6)
    else:
        grid = SweepGrid.build(("ring", "random"), (0.0, 0.4), (0, 1),
                               num_nodes=6)

    cfg = FLConfig(topology="ring", num_nodes=6, comm_batch=3, rounds=rounds)
    tr = GluADFL(model, sgd(1e-2), cfg, dp_noise_sigma=dp_sigma)
    pops, hists, states = tr.train_sweep(
        x, y, counts, grid=grid, batch_size=8, chunk=chunk,
        eval_every=eval_every, val_data=val,
    )

    s_pops, s_hists, s_states = _serial_histories(
        model, grid, x, y, counts, rounds=rounds, chunk=chunk,
        dp_sigma=dp_sigma, eval_every=eval_every, val=val,
    )
    for g in range(grid.size):
        assert len(hists[g]) == rounds
        assert [h["round"] for h in hists[g]] == list(range(rounds))
        for hs, hl in zip(hists[g], s_hists[g]):
            assert abs(hs["loss"] - hl["loss"]) < 1e-5
            assert ("val_rmse" in hs) == ("val_rmse" in hl)
            if "val_rmse" in hs:
                assert abs(hs["val_rmse"] - hl["val_rmse"]) < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), s_pops[g]))
        ) < 1e-5
        # final state: round counter, staleness, key chain all line up
        assert int(jax.tree.leaves(states.round)[0][g]) == rounds
        np.testing.assert_array_equal(
            np.asarray(states.key[g]), np.asarray(s_states[g].key)
        )
        np.testing.assert_allclose(
            np.asarray(states.staleness[g]),
            np.asarray(s_states[g].staleness),
        )


def test_train_sweep_adam_population_matches_serial():
    """Parity also holds with a stateful optimizer (Adam moments ride
    the vmapped scan carry)."""
    rounds = 5
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    grid = SweepGrid.build(("cluster",), (0.2,), (0, 1), num_nodes=6)
    cfg = FLConfig(topology="cluster", num_nodes=6, comm_batch=3, rounds=rounds)
    tr = GluADFL(model, adam(5e-3), cfg)
    pops, hists, _ = tr.train_sweep(x, y, counts, grid=grid, batch_size=8)
    s_pops, s_hists, _ = _serial_histories(
        model, grid, x, y, counts, rounds=rounds, chunk=None,
        optimizer=adam(5e-3),
    )
    for g in range(grid.size):
        for hs, hl in zip(hists[g], s_hists[g]):
            assert abs(hs["loss"] - hl["loss"]) < 1e-5
        assert float(
            tree_l2_norm(tree_sub(tree_index(pops, g), s_pops[g]))
        ) < 1e-5


def test_train_sweep_compiled_execution_budget():
    """The Fig-5 grid (3 topologies x 5 ratios) must run in <= 3
    compiled sweep executions — one batched program per chunk shape,
    never per scenario."""
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    cfg = SweepConfig()
    grid = SweepGrid.build(cfg.topologies, cfg.inactive_ratios,
                           cfg.seed_list(), num_nodes=6)
    assert grid.size == 15
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6, comm_batch=3))
    calls = []
    real = tr._sweep_chunk_jit

    def counting(*a, **kw):
        calls.append(kw.get("chunk"))
        return real(*a, **kw)

    tr._sweep_chunk_jit = counting
    _, hists, _ = tr.train_sweep(x, y, counts, grid=grid, batch_size=8,
                                 rounds=10, chunk=8)
    assert len(calls) <= 3, calls          # 8 + 2 -> two executions
    assert len({c for c in calls}) == len(calls)  # distinct chunk shapes
    assert all(len(h) == 10 for h in hists)


def test_train_sweep_guards():
    """Wrong-N grids, the per-scenario Pallas mixer, and non-sweep
    meshes must refuse loudly."""
    model = LSTMModel(hidden=8).as_model()
    grid = SweepGrid.build(("ring",), (0.0,), (0,), num_nodes=4)
    tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6))
    with pytest.raises(ValueError, match="num_nodes"):
        tr.train_sweep(*_toy_fed(), grid=grid)
    grid6 = SweepGrid.build(("ring",), (0.0,), (0,), num_nodes=6)
    tr_kernel = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6),
                        mixer="kernel")
    with pytest.raises(NotImplementedError, match="kernel"):
        tr_kernel.train_sweep(*_toy_fed(), grid=grid6)
    # the swept-sharded engine needs the 2-D (grid, node) mesh — a 1-D
    # federation mesh is the serial train() layout, not the sweep's
    tr_1d = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=6),
                    mixer="sharded", mesh=jax.make_mesh((1,), ("node",)))
    with pytest.raises(ValueError, match="2-D"):
        tr_1d.train_sweep(*_toy_fed(), grid=grid6)
    with pytest.raises(ValueError, match="empty"):
        SweepGrid.build((), (0.0,), (0,), num_nodes=6)


def test_train_sweep_sharded_matches_tree_in_process():
    """The swept-sharded engine must match the swept tree mixer exactly
    — same key streams, same losses, same populations — on whatever
    sweep mesh the test process's devices give (a degenerate (1, 1)
    local mesh on one device; a real multi-device mesh when another
    test module forced an XLA device count).  This keeps the 2-D
    dispatch path covered by tier-1; the pinned-layout multi-device
    parity lives in the ``multidevice`` test below."""
    rounds = 4
    x, y, counts = _toy_fed()
    model = LSTMModel(hidden=8).as_model()
    grid = SweepGrid.build(("ring", "random"), (0.0, 0.4), (0,), num_nodes=6)
    cfg = FLConfig(num_nodes=6, comm_batch=3, rounds=rounds)
    pops_t, hists_t, _ = GluADFL(model, sgd(1e-2), cfg).train_sweep(
        x, y, counts, grid=grid, batch_size=8
    )
    for impl in ("allgather", "psum"):
        tr = GluADFL(model, sgd(1e-2), cfg, mixer="sharded", gossip_impl=impl)
        pops_s, hists_s, _ = tr.train_sweep(x, y, counts, grid=grid, batch_size=8)
        for g in range(grid.size):
            for a, b in zip(hists_s[g], hists_t[g]):
                assert abs(a["loss"] - b["loss"]) < 1e-5, (impl, g, a, b)
            assert float(
                tree_l2_norm(tree_sub(tree_index(pops_s, g), tree_index(pops_t, g)))
            ) < 1e-5, (impl, g)


@pytest.mark.multidevice
def test_train_sweep_parity_on_forced_8_devices():
    """The sweep/serial parity must survive a real multi-device topology
    (the vmapped program and the serial scans both run on the forced
    8-device CPU platform CI uses for collective tests)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    src = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL, SweepGrid
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_index, tree_l2_norm, tree_sub

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(0)
        n = 8
        x = jnp.asarray(rng.normal(size=(n, 24, 12)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, 24)).astype(np.float32))
        counts = jnp.asarray(np.full((n,), 24, np.int32))
        model = LSTMModel(hidden=8).as_model()
        grid = SweepGrid.build(("ring", "random"), (0.0, 0.5), (0,), num_nodes=n)
        tr = GluADFL(model, sgd(1e-2), FLConfig(num_nodes=n, comm_batch=3, rounds=4))
        pops, hists, _ = tr.train_sweep(x, y, counts, grid=grid, batch_size=8)
        for g, (topo, ratio, seed) in enumerate(grid.labels):
            cfg = FLConfig(topology=topo, num_nodes=n, comm_batch=3,
                           rounds=4, inactive_ratio=ratio)
            s_tr = GluADFL(model, sgd(1e-2), cfg)
            pop, hist, _ = s_tr.train(jax.random.PRNGKey(seed), x, y, counts,
                                      batch_size=8)
            assert all(abs(a["loss"] - b["loss"]) < 1e-5
                       for a, b in zip(hists[g], hist))
            assert float(tree_l2_norm(tree_sub(tree_index(pops, g), pop))) < 1e-5
        print("SWEEP_8DEV_OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SWEEP_8DEV_OK" in out.stdout


@pytest.mark.multidevice
def test_train_sweep_sharded_parity_on_forced_8_devices():
    """The swept-SHARDED engine on a real 2-D (2 grid x 4 node) mesh:
    scenario g of ``train_sweep(mixer="sharded")`` must match a serial
    ``train(mixer="sharded", key=PRNGKey(seed_g))`` run — params,
    losses, AND streaming-eval records — for BOTH collective schedules
    (allgather and psum), plus the final-state key chain/staleness.
    The serial runs use the 1-D federation mesh, so this also pins that
    the (grid, node) lowering changes the schedule, not the numbers."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    src = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import FLConfig
        from repro.core import GluADFL, SweepGrid
        from repro.launch.mesh import make_sweep_mesh
        from repro.models import LSTMModel
        from repro.optim import sgd
        from repro.utils.pytree import tree_index, tree_l2_norm, tree_sub

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(0)
        n, rounds, chunk, eval_every = 8, 5, 4, 2
        x = jnp.asarray(rng.normal(size=(n, 24, 12)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, 24)).astype(np.float32))
        counts = jnp.asarray(np.full((n,), 24, np.int32))
        vx = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
        vy = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
        model = LSTMModel(hidden=8).as_model()
        grid = SweepGrid.build(("ring", "random"), (0.0, 0.5), (0,), num_nodes=n)
        mesh = make_sweep_mesh(grid.size, n, grid_width=2, node_width=4)
        assert dict(mesh.shape) == {"grid": 2, "node": 4}
        for impl in ("allgather", "psum"):
            tr = GluADFL(model, sgd(1e-2),
                         FLConfig(num_nodes=n, comm_batch=3, rounds=rounds),
                         mixer="sharded", gossip_impl=impl, mesh=mesh)
            pops, hists, states = tr.train_sweep(
                x, y, counts, grid=grid, batch_size=8, chunk=chunk,
                eval_every=eval_every, val_data=(vx, vy))
            for g, (topo, ratio, seed) in enumerate(grid.labels):
                cfg = FLConfig(topology=topo, num_nodes=n, comm_batch=3,
                               rounds=rounds, inactive_ratio=ratio)
                s_tr = GluADFL(model, sgd(1e-2), cfg,
                               mixer="sharded", gossip_impl=impl)
                pop, hist, st = s_tr.train(
                    jax.random.PRNGKey(seed), x, y, counts, batch_size=8,
                    chunk=chunk, eval_every=eval_every, val_data=(vx, vy))
                assert len(hists[g]) == rounds
                for a, b in zip(hists[g], hist):
                    assert abs(a["loss"] - b["loss"]) < 1e-4, (impl, g, a, b)
                    assert ("val_rmse" in a) == ("val_rmse" in b)
                    if "val_rmse" in a:
                        assert abs(a["val_rmse"] - b["val_rmse"]) < 1e-4
                assert sum("val_rmse" in h for h in hists[g]) == 2
                assert float(tree_l2_norm(tree_sub(
                    tree_index(pops, g), pop))) < 1e-4, (impl, g)
                np.testing.assert_array_equal(
                    np.asarray(states.key[g]), np.asarray(st.key))
                np.testing.assert_allclose(
                    np.asarray(states.staleness[g]),
                    np.asarray(st.staleness), atol=0)
            print(f"SWEEP_SHARDED_{impl.upper()}_OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SWEEP_SHARDED_ALLGATHER_OK" in out.stdout
    assert "SWEEP_SHARDED_PSUM_OK" in out.stdout
