"""Unit tests for the CI bench-regression gate
(``benchmarks/check_bench_regression.py``) — the gate guards every PR's
engine-speed claim, so its own edge cases (missing rows, exact-threshold
ratios, the scan-eval floor) must be pinned down too."""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def gate():
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression", ROOT / "benchmarks" / "check_bench_regression.py"
    )
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("check_bench_regression", mod)
    spec.loader.exec_module(mod)
    return mod


def _report(rps: dict, eval_rel: float | None = None) -> dict:
    out = {"rounds_per_sec": dict(rps)}
    if eval_rel is not None:
        out["scan_eval_relative_throughput"] = eval_rel
    return out


def _run(gate, tmp_path, baseline, fresh, *extra) -> int:
    b = tmp_path / "baseline.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    return gate.main(["--baseline", str(b), "--fresh", str(f), *extra])


BASE = {"loop": 10.0, "scan": 100.0, "sharded-scan": 50.0}


def test_green_when_ratios_hold(gate, tmp_path):
    assert _run(gate, tmp_path, _report(BASE), _report(BASE)) == 0


def test_missing_baseline_row_in_fresh_fails(gate, tmp_path):
    fresh = {k: v for k, v in BASE.items() if k != "sharded-scan"}
    assert _run(gate, tmp_path, _report(BASE), _report(fresh)) == 1


def test_extra_fresh_row_is_ignored(gate, tmp_path):
    """New engines (e.g. an optional multihost row) may appear in the
    fresh run without a baseline — only baseline rows are gated."""
    fresh = dict(BASE, **{"multihost-psum-scan": 1.0})
    assert _run(gate, tmp_path, _report(BASE), _report(fresh)) == 0


def test_wall_clock_rows_excluded_from_ratio_rule(gate, tmp_path):
    """The compile-inclusive wall-clock rows (serial-sweep, sweep-scan,
    sweep-sharded-psum) are not machine-portable ratios: halving them
    must NOT trip the loop-ratio gate as long as they stay present."""
    base = dict(BASE, **{"serial-sweep": 20.0, "sweep-scan": 60.0,
                         "sweep-sharded-psum": 30.0})
    fresh = dict(base, **{"serial-sweep": 10.0, "sweep-scan": 30.0,
                          "sweep-sharded-psum": 1.0})
    fresh_report = _report(fresh)
    fresh_report["sweep_scan_speedup_vs_serial"] = 3.0  # same-run floor holds
    assert _run(gate, tmp_path, _report(base), fresh_report) == 0


def test_missing_wall_clock_row_fails(gate, tmp_path):
    """A baseline wall-clock row vanishing from the fresh run means the
    engine path silently stopped being measured — that must fail."""
    base = dict(BASE, **{"sweep-sharded-psum": 30.0})
    fresh = {k: v for k, v in base.items() if k != "sweep-sharded-psum"}
    assert _run(gate, tmp_path, _report(base), _report(fresh)) == 1
    # ...but a baseline without the row doesn't demand one (old baselines)
    assert _run(gate, tmp_path, _report(BASE), _report(base)) == 0


def test_exactly_at_threshold_ratio_passes(gate, tmp_path):
    """The floor is inclusive: a speedup ratio at exactly
    baseline * (1 - threshold) must NOT fail (f < floor, not <=)."""
    # baseline scan ratio 10x, threshold 0.2 -> floor 8x exactly
    fresh = {"loop": 10.0, "scan": 80.0, "sharded-scan": 40.0}
    assert _run(gate, tmp_path, _report(BASE), _report(fresh)) == 0


def test_just_below_threshold_ratio_fails(gate, tmp_path):
    fresh = {"loop": 10.0, "scan": 79.9, "sharded-scan": 40.0}
    assert _run(gate, tmp_path, _report(BASE), _report(fresh)) == 1


def test_scan_eval_floor_gate(gate, tmp_path):
    ok = _run(gate, tmp_path, _report(BASE), _report(BASE, eval_rel=0.95))
    at = _run(gate, tmp_path, _report(BASE), _report(BASE, eval_rel=0.9))
    below = _run(gate, tmp_path, _report(BASE), _report(BASE, eval_rel=0.89))
    assert (ok, at, below) == (0, 0, 1)
    # the floor is adjustable for noisy runner classes
    assert _run(gate, tmp_path, _report(BASE), _report(BASE, eval_rel=0.85),
                "--eval-floor", "0.8") == 0


def test_missing_eval_ratio_is_not_gated(gate, tmp_path):
    """Runs without the scan-eval row (``--eval-every 0``) skip the
    floor check instead of crashing."""
    assert _run(gate, tmp_path, _report(BASE, eval_rel=0.95), _report(BASE)) == 0


def test_no_loop_row_is_a_hard_error(gate, tmp_path):
    with pytest.raises(SystemExit, match="loop"):
        _run(gate, tmp_path, _report({"scan": 5.0}), _report(BASE))


def test_absolute_mode_gates_raw_rps(gate, tmp_path):
    """Ratios identical but every engine 2x slower: relative gate passes,
    --absolute fails."""
    halved = {k: v / 2 for k, v in BASE.items()}
    assert _run(gate, tmp_path, _report(BASE), _report(halved)) == 0
    assert _run(gate, tmp_path, _report(BASE), _report(halved), "--absolute") == 1


def test_update_rewrites_baseline(gate, tmp_path):
    fresh = _report({"loop": 1.0, "scan": 7.0})
    rc = _run(gate, tmp_path, _report(BASE), fresh, "--update")
    assert rc == 0
    rewritten = json.loads((tmp_path / "baseline.json").read_text())
    assert rewritten == fresh


# ------------------------------------------------------- sparse-gossip rows


SPARSE_BASE = dict(BASE, **{"dense-gossip-n226": 17.0,
                            "sparse-gossip-n226": 20.0,
                            "sparse-gossip-10k": 0.4})


def _sparse_report(rps, ratio=None, **kw):
    out = _report(rps, **kw)
    if ratio is not None:
        out["sparse_gossip_speedup_vs_dense"] = ratio
    return out


def test_sparse_floor_gate(gate, tmp_path):
    """sparse/dense >= --sparse-floor (default 0.9, inclusive)."""
    base = _sparse_report(SPARSE_BASE, 1.2)
    ok = _run(gate, tmp_path, base, _sparse_report(SPARSE_BASE, 1.1))
    at = _run(gate, tmp_path, base, _sparse_report(SPARSE_BASE, 0.9))
    below = _run(gate, tmp_path, base, _sparse_report(SPARSE_BASE, 0.89))
    assert (ok, at, below) == (0, 0, 1)
    # the floor is adjustable, same as the eval/sweep floors
    assert _run(gate, tmp_path, base, _sparse_report(SPARSE_BASE, 0.85),
                "--sparse-floor", "0.8") == 0


def test_sparse_rows_excluded_from_ratio_rule(gate, tmp_path):
    """The representation pair runs a wider model than the engine rows —
    its loop ratio is apples-to-oranges, so tanking the raw rows must
    NOT trip the loop-ratio gate while the same-run floor holds."""
    fresh = dict(SPARSE_BASE, **{"dense-gossip-n226": 1.0,
                                 "sparse-gossip-n226": 1.0,
                                 "sparse-gossip-10k": 0.01})
    assert _run(gate, tmp_path, _sparse_report(SPARSE_BASE, 1.2),
                _sparse_report(fresh, 1.0)) == 0


def test_missing_sparse_row_fails(gate, tmp_path):
    """The 10k row silently vanishing = the population-scale path
    stopped being measured; same for the N=226 pair."""
    for gone in ("sparse-gossip-10k", "sparse-gossip-n226"):
        fresh = {k: v for k, v in SPARSE_BASE.items() if k != gone}
        assert _run(gate, tmp_path, _sparse_report(SPARSE_BASE, 1.2),
                    _sparse_report(fresh, 1.2)) == 1, gone
    # old baselines without the rows demand nothing
    assert _run(gate, tmp_path, _report(BASE),
                _sparse_report(SPARSE_BASE, 1.2)) == 0


def test_baseline_sparse_row_requires_fresh_ratio(gate, tmp_path):
    """A baseline with the N=226 pair but a fresh run reporting no
    sparse_gossip_speedup_vs_dense must fail (mirrors the sweep rule)."""
    assert _run(gate, tmp_path, _sparse_report(SPARSE_BASE, 1.2),
                _sparse_report(SPARSE_BASE)) == 1


GATHER_BASE = dict(SPARSE_BASE, **{"sparse-gossip-100k": 0.15})
GATHER_MEM = {"num_nodes": 100000, "node_shards": 8,
              "param_bytes_per_node": 404,
              "allgather_gathered_bytes_per_device": 40400000,
              "gather_table_bytes_per_device": 10100000}


def test_gather_100k_row_presence_and_memory_record(gate, tmp_path):
    """The 100k gather-table row is presence-gated like the other scale
    rows, and a baseline carrying it also demands the fresh run's
    per-device gather_table_memory_bytes record."""
    base = _sparse_report(GATHER_BASE, 1.2)
    base["gather_table_memory_bytes"] = GATHER_MEM
    ok = _sparse_report(GATHER_BASE, 1.2)
    ok["gather_table_memory_bytes"] = GATHER_MEM
    assert _run(gate, tmp_path, base, ok) == 0
    # the row vanished -> fail
    gone = {k: v for k, v in GATHER_BASE.items() if k != "sparse-gossip-100k"}
    gone_report = _sparse_report(gone, 1.2)
    gone_report["gather_table_memory_bytes"] = GATHER_MEM
    assert _run(gate, tmp_path, base, gone_report) == 1
    # the memory record vanished (or lost a key) -> fail
    no_mem = _sparse_report(GATHER_BASE, 1.2)
    assert _run(gate, tmp_path, base, no_mem) == 1
    partial = _sparse_report(GATHER_BASE, 1.2)
    partial["gather_table_memory_bytes"] = {
        k: v for k, v in GATHER_MEM.items()
        if k != "gather_table_bytes_per_device"}
    assert _run(gate, tmp_path, base, partial) == 1
    # old baselines without the row demand neither
    assert _run(gate, tmp_path, _sparse_report(SPARSE_BASE, 1.2), no_mem) == 0


# ------------------------------------------------- masked-gossip overhead row


MASKED_BASE = dict(BASE, **{"masked-sharded-scan": 40.0})


def _masked_report(rps, overhead=None, **kw):
    out = _report(rps, **kw)
    if overhead is not None:
        out["masked_gossip_overhead_vs_allgather"] = overhead
    return out


def test_masked_ceiling_gate(gate, tmp_path):
    """masked overhead <= --masked-ceiling (default 4.0, inclusive)."""
    base = _masked_report(MASKED_BASE, 3.0)
    ok = _run(gate, tmp_path, base, _masked_report(MASKED_BASE, 3.0))
    at = _run(gate, tmp_path, base, _masked_report(MASKED_BASE, 4.0))
    above = _run(gate, tmp_path, base, _masked_report(MASKED_BASE, 4.01))
    assert (ok, at, above) == (0, 0, 1)
    # the ceiling is adjustable like every other floor
    assert _run(gate, tmp_path, base, _masked_report(MASKED_BASE, 4.5),
                "--masked-ceiling", "5.0") == 0


def test_masked_row_excluded_from_ratio_rule(gate, tmp_path):
    """The masked row's cost is owned by the same-run ceiling; tanking
    its raw rps must NOT also trip the loop-ratio gate."""
    fresh = dict(MASKED_BASE, **{"masked-sharded-scan": 1.0})
    assert _run(gate, tmp_path, _masked_report(MASKED_BASE, 1.2),
                _masked_report(fresh, 1.5)) == 0


def test_missing_masked_row_fails(gate, tmp_path):
    """The secure-aggregation row silently vanishing = masking stopped
    being priced; old baselines without it demand nothing."""
    fresh = {k: v for k, v in MASKED_BASE.items() if k != "masked-sharded-scan"}
    assert _run(gate, tmp_path, _masked_report(MASKED_BASE, 1.2),
                _masked_report(fresh, 1.2)) == 1
    assert _run(gate, tmp_path, _report(BASE),
                _masked_report(MASKED_BASE, 1.2)) == 0


def test_baseline_masked_row_requires_fresh_ratio(gate, tmp_path):
    """A baseline with the masked row but a fresh run reporting no
    overhead ratio must fail (mirrors the sweep/sparse rule)."""
    assert _run(gate, tmp_path, _masked_report(MASKED_BASE, 1.2),
                _masked_report(MASKED_BASE)) == 1


# ------------------------------------------------- table4 baseline-grid rows


TABLE4_BASE = dict(BASE, **{"table4-serial-loops": 30.0,
                            "table4-batched": 60.0})


def _table4_report(rps, ratio=None, **kw):
    out = _report(rps, **kw)
    if ratio is not None:
        out["table4_batched_speedup_vs_serial"] = ratio
    return out


def test_table4_floor_gate(gate, tmp_path):
    """batched/serial >= --table4-floor (default 1.5, inclusive)."""
    base = _table4_report(TABLE4_BASE, 2.0)
    ok = _run(gate, tmp_path, base, _table4_report(TABLE4_BASE, 1.8))
    at = _run(gate, tmp_path, base, _table4_report(TABLE4_BASE, 1.5))
    below = _run(gate, tmp_path, base, _table4_report(TABLE4_BASE, 1.49))
    assert (ok, at, below) == (0, 0, 1)
    # the floor is adjustable like the sweep/sparse floors
    assert _run(gate, tmp_path, base, _table4_report(TABLE4_BASE, 1.2),
                "--table4-floor", "1.1") == 0


def test_table4_rows_excluded_from_ratio_rule(gate, tmp_path):
    """The baseline-grid pair runs a different workload (four method
    trainers, not the GluADFL engine federation) — its loop ratio is
    apples-to-oranges, so tanking the raw rows must NOT trip the
    loop-ratio gate while the same-run floor holds."""
    fresh = dict(TABLE4_BASE, **{"table4-serial-loops": 1.0,
                                 "table4-batched": 2.0})
    assert _run(gate, tmp_path, _table4_report(TABLE4_BASE, 2.0),
                _table4_report(fresh, 2.0)) == 0


def test_missing_table4_row_fails(gate, tmp_path):
    """Either grid row silently vanishing = the batched-baseline claim
    stopped being measured; old baselines without them demand nothing."""
    for gone in ("table4-batched", "table4-serial-loops"):
        fresh = {k: v for k, v in TABLE4_BASE.items() if k != gone}
        assert _run(gate, tmp_path, _table4_report(TABLE4_BASE, 2.0),
                    _table4_report(fresh, 2.0)) == 1, gone
    assert _run(gate, tmp_path, _report(BASE),
                _table4_report(TABLE4_BASE, 2.0)) == 0


def test_baseline_table4_row_requires_fresh_ratio(gate, tmp_path):
    """A baseline with the table4-batched row but a fresh run reporting
    no table4_batched_speedup_vs_serial must fail (mirrors the
    sweep/sparse/masked rule)."""
    assert _run(gate, tmp_path, _table4_report(TABLE4_BASE, 2.0),
                _table4_report(TABLE4_BASE)) == 1


# ------------------------------------------------------- serve gate rows


def _serve_report(buckets=("1", "4", "16"), speedup=5.0, gain=10.0) -> dict:
    row = {"p50_latency_ms": 1.0, "p99_latency_ms": 2.0,
           "forecasts_per_sec": 100.0}
    out = {"buckets": {b: dict(row) for b in buckets}}
    if speedup is not None:
        out["personalize_batch_speedup_vs_serial"] = speedup
    if gain is not None:
        out["bucket_batching_gain"] = gain
    return out


def _run_serve(gate, tmp_path, baseline, fresh, *extra) -> int:
    b = tmp_path / "serve_baseline.json"
    f = tmp_path / "serve_fresh.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    return gate.main(["--serve-only", "--serve-baseline", str(b),
                      "--serve-fresh", str(f), *extra])


def test_serve_gate_green(gate, tmp_path):
    assert _run_serve(gate, tmp_path, _serve_report(), _serve_report()) == 0


def test_serve_gate_latency_values_not_compared(gate, tmp_path):
    """Latencies are wall clock: a 100x slower fresh run must still pass
    as long as rows are present and the same-run floors hold."""
    fresh = _serve_report()
    for row in fresh["buckets"].values():
        row["p50_latency_ms"] *= 100
        row["forecasts_per_sec"] /= 100
    assert _run_serve(gate, tmp_path, _serve_report(), fresh) == 0


def test_serve_gate_missing_bucket_row_fails(gate, tmp_path):
    fresh = _serve_report(buckets=("1", "4"))  # 16 vanished
    assert _run_serve(gate, tmp_path, _serve_report(), fresh) == 1
    # extra fresh buckets (a new config) are fine without a baseline row
    wide = _serve_report(buckets=("1", "4", "16", "64"))
    assert _run_serve(gate, tmp_path, _serve_report(), wide) == 0


def test_serve_gate_personalize_floor(gate, tmp_path):
    """Floor inclusive at the default 2.0; adjustable like the others."""
    base = _serve_report()
    at = _run_serve(gate, tmp_path, base, _serve_report(speedup=2.0))
    below = _run_serve(gate, tmp_path, base, _serve_report(speedup=1.99))
    missing = _run_serve(gate, tmp_path, base, _serve_report(speedup=None))
    assert (at, below, missing) == (0, 1, 1)
    assert _run_serve(gate, tmp_path, base, _serve_report(speedup=1.5),
                      "--personalize-floor", "1.4") == 0


def test_serve_gate_batching_gain_floor(gate, tmp_path):
    base = _serve_report()
    at = _run_serve(gate, tmp_path, base, _serve_report(gain=1.0))
    below = _run_serve(gate, tmp_path, base, _serve_report(gain=0.9))
    missing = _run_serve(gate, tmp_path, base, _serve_report(gain=None))
    assert (at, below, missing) == (0, 1, 1)


def test_serve_gate_update_rewrites_serve_baseline_only(gate, tmp_path):
    """--serve-only --update rewrites BENCH_serve, not the training
    baseline."""
    rounds_baseline = tmp_path / "baseline.json"
    rounds_baseline.write_text(json.dumps(_report(BASE)))
    fresh = _serve_report(speedup=9.0)
    rc = _run_serve(gate, tmp_path, _serve_report(), fresh,
                    "--baseline", str(rounds_baseline), "--update")
    assert rc == 0
    rewritten = json.loads((tmp_path / "serve_baseline.json").read_text())
    assert rewritten == fresh
    assert json.loads(rounds_baseline.read_text()) == _report(BASE)
