"""Loop-vs-scan parity for the baseline trainers (FedAvg, MAML/MetaSGD,
pooled supervised): the chunked scan engines must BITWISE-match the
original per-round jit loops — losses, eval records, final params — with
the loop kept as ``engine="loop"``; plus the early-stopping semantics
and the Table-4 compiled-execution budget (<= 4 executions for the whole
trainable-baseline grid, counted through the ``chunked.dispatch_chunk``
chokepoint)."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.chunked as chunked
from repro.config import FLConfig
from repro.core.fedavg import FedAvg
from repro.core.meta import MAML, MetaSGD
from repro.core.supervised import train_supervised
from repro.models import LSTMModel
from repro.optim import adam, sgd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _toy_fed(n=5, m=40, L=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    w_true = rng.normal(size=(L,)).astype(np.float32)
    y = (x @ w_true)[..., None].astype(np.float32)
    counts = np.full((n,), m, np.int64)
    return x, y, counts


def _val_set(m=16, L=12, seed=7):
    rng = np.random.default_rng(seed)
    vx = rng.normal(size=(m, L)).astype(np.float32)
    vy = rng.normal(size=(m, 1)).astype(np.float32)
    return vx, vy


def _model(L=12):
    return LSTMModel(history_len=L, hidden=8).as_model()


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _hist_arrays(hist, key="round"):
    losses = np.asarray([r["loss"] for r in hist])
    vals = [(r[key], r["val_loss"]) for r in hist if "val_loss" in r]
    return losses, vals


# ----------------------------------------------------------------------
# per-trainer bitwise parity (the pin that lets scan be the default)
# ----------------------------------------------------------------------

def test_fedavg_scan_matches_loop_bitwise():
    """engine="scan" (chunked, one sync per chunk, incl. a remainder
    chunk) == engine="loop" bitwise: losses, val records, params."""
    x, y, counts = _toy_fed()
    vx, vy = _val_set()
    cfg = FLConfig(num_nodes=5, rounds=9, inactive_ratio=0.3)

    def run(engine):
        fa = FedAvg(_model(), sgd(1e-2), cfg)
        return fa.train(
            jax.random.PRNGKey(7), x, y, counts, batch_size=8,
            engine=engine, chunk=4, val_data=(vx, vy), eval_every=3,
        )

    p_loop, h_loop = run("loop")
    p_scan, h_scan = run("scan")
    assert len(h_loop) == len(h_scan) == 9
    l_loop, v_loop = _hist_arrays(h_loop)
    l_scan, v_scan = _hist_arrays(h_scan)
    np.testing.assert_array_equal(l_loop, l_scan)
    assert v_loop == v_scan and len(v_loop) == 3
    _assert_trees_equal(p_loop, p_scan)


@pytest.mark.parametrize("cls", [MAML, MetaSGD])
def test_meta_scan_matches_loop_bitwise(cls):
    """MAML/MetaSGD scan engine == loop engine bitwise: losses, val
    records, meta-params AND learned inner lrs ride the donated carry."""
    x, y, counts = _toy_fed(n=4, m=30)
    vx, vy = _val_set()

    def run(engine):
        meta = cls(_model(), adam(1e-3), inner_lr=1e-2, inner_steps=2)
        return meta.train(
            jax.random.PRNGKey(3), x, y, counts, batch_size=8, steps=7,
            engine=engine, chunk=3, val_data=(vx, vy), eval_every=2,
        )

    p_loop, lr_loop, h_loop = run("loop")
    p_scan, lr_scan, h_scan = run("scan")
    l_loop, v_loop = _hist_arrays(h_loop)
    l_scan, v_scan = _hist_arrays(h_scan)
    np.testing.assert_array_equal(l_loop, l_scan)
    assert v_loop == v_scan and len(v_loop) == 3
    _assert_trees_equal(p_loop, p_scan)
    _assert_trees_equal(lr_loop, lr_scan)


def test_supervised_scan_matches_loop_bitwise():
    """Pooled-supervised scan engine == loop engine bitwise, including
    the best-val checkpoint selection (jnp.where tree-selects in the
    carry vs the loop's host-side snapshot)."""
    x, y, _ = _toy_fed(n=1, m=120)
    x, y = x[0], y[0]
    vx, vy = _val_set()

    def run(engine, **kw):
        return train_supervised(
            _model(), sgd(1e-2), jax.random.PRNGKey(5), x, y, batch_size=8,
            steps=23, val=(vx, vy), eval_every=5, engine=engine, **kw,
        )

    p_loop, h_loop = run("loop")
    p_scan, h_scan = run("scan", chunk=7)
    l_loop, v_loop = _hist_arrays(h_loop, key="step")
    l_scan, v_scan = _hist_arrays(h_scan, key="step")
    np.testing.assert_array_equal(l_loop, l_scan)
    assert v_loop == v_scan and len(v_loop) == 4
    _assert_trees_equal(p_loop, p_scan)

    # no-val path: both engines return the FINAL params
    pa, ha = train_supervised(_model(), sgd(1e-2), jax.random.PRNGKey(5),
                              x, y, batch_size=8, steps=9, engine="scan")
    pb, hb = train_supervised(_model(), sgd(1e-2), jax.random.PRNGKey(5),
                              x, y, batch_size=8, steps=9, engine="loop")
    _assert_trees_equal(pa, pb)
    assert len(ha) == len(hb) == 9


# ----------------------------------------------------------------------
# engine guards + early stopping
# ----------------------------------------------------------------------

def test_engine_guards():
    x, y, counts = _toy_fed()
    fa = FedAvg(_model(), sgd(1e-2), FLConfig(num_nodes=5, rounds=2))
    with pytest.raises(ValueError, match="engine"):
        fa.train(jax.random.PRNGKey(0), x, y, counts, engine="while")
    with pytest.raises(ValueError, match="early_stop_patience"):
        fa.train(jax.random.PRNGKey(0), x, y, counts,
                 early_stop_patience=2)
    meta = MAML(_model(), adam(1e-3))
    with pytest.raises(ValueError, match="engine"):
        meta.train(jax.random.PRNGKey(0), x, y, counts, engine="while")
    with pytest.raises(ValueError, match="early_stop_patience"):
        meta.train(jax.random.PRNGKey(0), x, y, counts,
                   early_stop_patience=1)
    with pytest.raises(ValueError, match="engine"):
        train_supervised(_model(), sgd(1e-2), jax.random.PRNGKey(0),
                         x[0], y[0], engine="while")
    with pytest.raises(ValueError, match="early_stop_patience"):
        train_supervised(_model(), sgd(1e-2), jax.random.PRNGKey(0),
                         x[0], y[0], early_stop_patience=1)


def test_early_stop_truncates_and_is_chunk_invariant():
    """The cond-guarded done-flag: the run stops after `patience`
    non-improving evals, the history ends exactly at the tripping round,
    and the result is IDENTICAL whether the stop lands mid-chunk or the
    whole budget is one chunk (frozen rounds are inert)."""
    x, y, counts = _toy_fed()
    vx, vy = _val_set()
    cfg = FLConfig(num_nodes=5, rounds=30, inactive_ratio=0.0)

    def run(chunk):
        fa = FedAvg(_model(), sgd(1e-2), cfg)
        return fa.train(
            jax.random.PRNGKey(7), x, y, counts, batch_size=8,
            engine="scan", chunk=chunk, val_data=(vx, vy), eval_every=2,
            early_stop_patience=1,
        )

    p_one, h_one = run(30)
    p_mid, h_mid = run(7)
    assert len(h_one) < 30  # it actually stopped
    assert "val_loss" in h_one[-1]  # stopped ON an eval boundary
    assert [r["round"] for r in h_one] == list(range(len(h_one)))
    assert len(h_one) == len(h_mid)
    np.testing.assert_array_equal(
        np.asarray([r["loss"] for r in h_one]),
        np.asarray([r["loss"] for r in h_mid]),
    )
    _assert_trees_equal(p_one, p_mid)

    # the stopped prefix must match the no-early-stop run's prefix
    fa = FedAvg(_model(), sgd(1e-2), cfg)
    _, h_full = fa.train(
        jax.random.PRNGKey(7), x, y, counts, batch_size=8, engine="scan",
        chunk=30, val_data=(vx, vy), eval_every=2,
    )
    np.testing.assert_array_equal(
        np.asarray([r["loss"] for r in h_one]),
        np.asarray([r["loss"] for r in h_full[: len(h_one)]]),
    )


# ----------------------------------------------------------------------
# Table-4 compiled-execution budget
# ----------------------------------------------------------------------

def test_table4_grid_runs_in_four_compiled_executions(monkeypatch):
    """The whole trainable-baseline grid (fedavg, maml, metasgd, lstm)
    dispatches <= 4 compiled chunk executions through the
    ``chunked.dispatch_chunk`` chokepoint — one per method."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.common import Scale
        from benchmarks.table4_baselines import run_baseline_grid
    finally:
        sys.path.remove(ROOT)

    calls = []
    orig = chunked.dispatch_chunk

    def counting(fn, *a, **k):
        calls.append(fn)
        return orig(fn, *a, **k)

    monkeypatch.setattr(chunked, "dispatch_chunk", counting)
    scale = Scale(fast=True, rounds=5, sup_steps=5, max_patients=4,
                  hidden=8, batch_size=8)
    out = run_baseline_grid("ohiot1dm", scale)
    assert set(out) == {"fedavg", "maml", "metasgd", "lstm"}
    assert len(calls) <= 4, f"{len(calls)} compiled executions"
    for method, d in out.items():
        assert len(d["history"]) == 5, method
        assert np.isfinite(d["history"][-1]["loss"]), method
