"""Serving-subsystem tests: checkpoint -> personalize -> forecast end to
end, the bitwise padding/batching contract, compile-once-per-bucket, the
MicroBatcher policy under a fake clock, and the launcher's --selfcheck.
CI re-runs these in the dedicated ``serve`` job (`pytest -m serve`);
they also run in tier-1 (single-device, fast)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import personalize
from repro.models import LSTMModel
from repro.optim import adam
from repro.serve import (
    GlucoseServable,
    MicroBatcher,
    Request,
    bucket_for,
    load_population,
    replay,
)
from repro.utils.pytree import tree_to_vector

pytestmark = pytest.mark.serve

ROOT = Path(__file__).resolve().parents[1]
CKPT = ROOT / "experiments" / "checkpoints" / "gluadfl_ohiot1dm_ring.npz"
L = 12


@pytest.fixture(scope="module")
def servable():
    model, pop = load_population(CKPT)
    sv = GlucoseServable(model, pop, buckets=(1, 2, 4),
                         personalize_steps=4, personalize_batch_size=8)
    rng = np.random.default_rng(0)
    k = 3
    sv.personalize(
        ["patient-a", "patient-b", "patient-c"],
        jax.random.split(jax.random.PRNGKey(0), k),
        rng.normal(size=(k, 6, L)).astype(np.float32),
        rng.normal(size=(k, 6)).astype(np.float32),
        np.array([6, 3, 1], np.int32),
    )
    return sv


# ------------------------------------------------------------ checkpoint


def test_load_population_infers_hidden_width():
    model, pop = load_population(CKPT)
    like = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(pop) == jax.tree.structure(like)
    vec = np.load(CKPT)["vec"]
    assert (np.asarray(tree_to_vector(pop)) == vec).all()


def test_load_population_rejects_wrong_hidden_and_unknown_count(tmp_path):
    with pytest.raises(ValueError, match="hidden=64"):
        load_population(CKPT, hidden=64)
    bogus = tmp_path / "bogus.npz"
    np.savez(bogus, vec=np.zeros(17, np.float32), meta="{}")
    with pytest.raises(ValueError, match="no LSTM width"):
        load_population(bogus)


# ---------------------------------------------- personalize -> forecast e2e


def test_checkpoint_personalize_forecast_roundtrip(servable):
    """The full serving lifecycle: every personalized row is bitwise the
    serial personalize() of that patient's history, and its served
    forecast is bitwise the direct model.apply under those params."""
    model = servable.model
    rng = np.random.default_rng(0)
    k = 3
    x = rng.normal(size=(k, 6, L)).astype(np.float32)
    y = rng.normal(size=(k, 6)).astype(np.float32)
    counts = np.array([6, 3, 1], np.int32)
    keys = jax.random.split(jax.random.PRNGKey(0), k)

    windows = rng.normal(size=(k, L)).astype(np.float32)
    rows = [servable.row_of(n) for n in ("patient-a", "patient-b", "patient-c")]
    served = np.asarray(servable.forecast_rows(rows, windows))
    for i, name in enumerate(("patient-a", "patient-b", "patient-c")):
        expect = personalize(model, servable.optimizer, servable.population,
                             keys[i], x[i], y[i], steps=4, batch_size=8,
                             count=counts[i])
        stored = servable.params_rows([servable.row_of(name)])
        assert all(
            (np.asarray(u) == np.asarray(v[0])).all()
            for u, v in zip(jax.tree.leaves(expect), jax.tree.leaves(stored))
        ), name
        direct = float(model.apply(expect, windows[i][None, :])[0])
        assert served[i] == direct, name


def test_unknown_patient_falls_back_to_population(servable):
    assert servable.row_of_or_population("never-seen") == 0
    with pytest.raises(KeyError):
        servable.row_of("never-seen")


# ------------------------------------------------------- padding/bucketing


def test_bucket_padding_never_changes_real_forecasts(servable):
    """A request's forecast must not depend on who shares its batch:
    every batch size n <= the largest bucket returns bitwise the n=1
    forecasts, pad rows and all."""
    rng = np.random.default_rng(1)
    windows = rng.normal(size=(4, L)).astype(np.float32)
    rows = [0, 1, 2, 3]
    singles = np.asarray(
        [servable.forecast_rows([r], w[None, :])[0]
         for r, w in zip(rows, windows)]
    )
    for n in (1, 2, 3, 4):
        batched = np.asarray(servable.forecast_rows(rows[:n], windows[:n]))
        assert (batched == singles[:n]).all(), f"batch of {n}"


def test_oversized_batch_splits_on_largest_bucket(servable):
    rng = np.random.default_rng(2)
    n = 4 * 2 + 3  # two full largest buckets + a padded tail
    windows = rng.normal(size=(n, L)).astype(np.float32)
    rows = [i % servable.num_rows for i in range(n)]
    out = np.asarray(servable.forecast_rows(rows, windows))
    singles = np.asarray(
        [servable.forecast_rows([r], w[None, :])[0]
         for r, w in zip(rows, windows)]
    )
    assert (out == singles).all()


def test_forecast_compiles_once_per_bucket(servable):
    """One jit cache, len(buckets) entries: after warmup every batch
    size <= the cap reuses a bucket executable (no new shapes)."""
    servable.warmup(history_len=L)
    assert servable.compiled_buckets == set(servable.buckets)
    sizes = servable._forecast_jit._cache_size()
    rng = np.random.default_rng(3)
    for n in (1, 2, 3, 4, 7):
        windows = rng.normal(size=(n, L)).astype(np.float32)
        servable.forecast_rows([0] * n, windows)
    assert servable._forecast_jit._cache_size() == sizes == len(servable.buckets)


def test_vmap_mode_is_close_but_not_the_contract(servable):
    """batch_mode='vmap' exists for throughput: allclose to the bitwise
    path (it is the same math, differently lowered)."""
    sv = GlucoseServable(servable.model, servable.population,
                         buckets=(1, 2, 4), batch_mode="vmap")
    rng = np.random.default_rng(4)
    windows = rng.normal(size=(3, L)).astype(np.float32)
    a = np.asarray(sv.forecast_rows([0, 0, 0], windows))
    b = np.asarray(servable.forecast_rows([0, 0, 0], windows))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_bucket_for():
    assert bucket_for(1, (1, 4, 16)) == 1
    assert bucket_for(2, (1, 4, 16)) == 4
    assert bucket_for(16, (1, 4, 16)) == 16
    assert bucket_for(99, (1, 4, 16)) == 16  # overflow -> caller splits
    with pytest.raises(ValueError):
        bucket_for(0, (1, 4))


# --------------------------------------------------- batcher (fake clock)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(rid):
    return Request(rid=rid, patient=0, window=np.zeros(L, np.float32))


def test_full_bucket_forms_immediately():
    clock = FakeClock()
    mb = MicroBatcher((1, 4), flush_timeout=1.0, clock=clock)
    for i in range(5):
        mb.submit(_req(i))
    batch = mb.ready()
    assert [r.rid for r in batch] == [0, 1, 2, 3]  # largest bucket, FIFO
    assert mb.pending == 1
    mb.complete(batch)


def test_partial_batch_waits_out_the_timeout():
    clock = FakeClock()
    mb = MicroBatcher((1, 4), flush_timeout=0.010, clock=clock)
    mb.submit(_req(0))
    clock.t = 0.005
    mb.submit(_req(1))
    assert mb.ready() is None  # oldest has waited only 5ms
    clock.t = 0.010
    batch = mb.ready()
    assert [r.rid for r in batch] == [0, 1]  # timeout ships the queue
    mb.complete(batch)


def test_admission_blocks_at_max_live_batches():
    clock = FakeClock()
    mb = MicroBatcher((1, 2), max_live_batches=1, flush_timeout=0.0,
                      clock=clock)
    for i in range(4):
        mb.submit(_req(i))
    first = mb.ready()
    assert first is not None
    assert mb.ready() is None and mb.flush() is None  # saturated
    assert mb.live_batches == 1
    mb.complete(first)
    assert mb.ready() is not None  # slot freed


def test_latency_accounting_with_fake_clock():
    clock = FakeClock()
    mb = MicroBatcher((1, 2), flush_timeout=0.050, clock=clock)
    mb.submit(_req(0))
    clock.t = 0.010
    mb.submit(_req(1))
    batch = mb.ready()  # full bucket of 2 at t=10ms
    clock.t = 0.030
    mb.complete(batch)
    stats = mb.stats()
    assert stats["completed"] == 2
    # rid 0: submitted t=0, done t=30ms; rid 1: submitted t=10ms
    assert stats["p99_latency_ms"] == pytest.approx(30.0, rel=0.02)
    assert stats["mean_queue_wait_ms"] == pytest.approx(5.0)  # (10 + 0) / 2


def test_fail_frees_the_admission_slot():
    # FAILS PRE-FIX (no fail() existed): an exception between formation
    # and complete() leaked _live forever and ready() saturated for the
    # rest of the process
    clock = FakeClock()
    mb = MicroBatcher((1, 2), max_live_batches=1, flush_timeout=0.0,
                      clock=clock)
    for i in range(4):
        mb.submit(_req(i))
    batch = mb.ready()
    assert mb.ready() is None  # saturated while the batch is in flight
    mb.fail(batch)  # the model raised: drop the batch, free the slot
    assert mb.live_batches == 0
    nxt = mb.ready()
    assert [r.rid for r in nxt] == [2, 3]  # admission recovered
    mb.complete(nxt)
    stats = mb.stats()
    assert stats["failed_batches"] == 1 and stats["dropped"] == 2
    assert stats["completed"] == 2


def test_fail_requeue_preserves_order_and_latency():
    clock = FakeClock()
    mb = MicroBatcher((1, 2), flush_timeout=0.0, clock=clock)
    mb.submit(_req(0))
    mb.submit(_req(1))
    clock.t = 0.010
    batch = mb.ready()
    mb.fail(batch, requeue=True)  # transient failure: retry them
    assert mb.live_batches == 0 and mb.pending == 2
    clock.t = 0.020
    retry = mb.ready()
    assert [r.rid for r in retry] == [0, 1]  # original order, front of queue
    clock.t = 0.030
    mb.complete(retry)
    stats = mb.stats()
    # latency spans the ORIGINAL submit (t=0), not the retry formation
    assert stats["completed"] == 2
    assert stats["p99_latency_ms"] == pytest.approx(30.0, rel=0.02)
    assert stats["failed_batches"] == 1 and stats["dropped"] == 0
    assert np.isfinite(stats["mean_queue_wait_ms"])


def test_stats_robust_to_never_completed_requests():
    # a request that never ran to completion (e.g. mixed into _finished
    # by a buggy caller, or inspected mid-flight) carries NaN stamps —
    # stats() must exclude it instead of NaN-ing the percentiles
    clock = FakeClock()
    mb = MicroBatcher((1, 2), flush_timeout=0.0, clock=clock)
    mb.submit(_req(0))
    batch = mb.ready()
    clock.t = 0.005
    mb.complete(batch)
    mb._finished.append(_req(99))  # never submitted/completed: all-NaN
    stats = mb.stats()
    assert stats["completed"] == 1
    for k in ("p50_latency_ms", "p99_latency_ms", "mean_queue_wait_ms",
              "forecasts_per_sec"):
        assert np.isfinite(stats[k]), (k, stats)


def test_flush_drains_the_tail_regardless_of_timeout():
    clock = FakeClock()
    mb = MicroBatcher((1, 4), flush_timeout=100.0, clock=clock)
    for i in range(3):
        mb.submit(_req(i))
    assert mb.ready() is None  # timeout far away, bucket not full
    batch = mb.flush()
    assert [r.rid for r in batch] == [0, 1, 2]
    mb.complete(batch)
    assert mb.flush() is None  # empty queue


def test_replay_routes_every_request(servable):
    rng = np.random.default_rng(5)
    reqs = [
        Request(rid=i, patient=int(rng.integers(0, servable.num_rows)),
                window=rng.normal(size=(L,)).astype(np.float32))
        for i in range(11)
    ]
    preds = replay(servable, MicroBatcher((1, 2, 4)), reqs)
    assert sorted(preds) == list(range(11))
    for r in reqs:
        params = servable.params_rows([r.patient])
        one = jax.tree.map(lambda l: l[0], params)
        direct = float(servable.model.apply(one, jnp.asarray(r.window)[None, :])[0])
        assert preds[r.rid] == direct, r.rid


# -------------------------------------------------------------- selfcheck


def test_launcher_selfcheck_passes():
    """The CLI selfcheck (the CI serve job's smoke) replays a stream and
    asserts bitwise parity with direct model.apply — returncode 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--requests", "12", "--steps", "2", "--personalize", "2",
         "--history-windows", "8", "--buckets", "1,4", "--selfcheck"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "bitwise-match" in out.stdout
