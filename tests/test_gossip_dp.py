"""Gossip data-parallelism correctness sweep (``core/gossip_dp.py``).

Pins the module against the single-device dense oracle: on a forced
8-device mesh, ``gossip_mix_params`` under both collectives
(allgather / psum-scatter) must equal ``mixing_matrix(...) @ w`` row for
row, and ``ring_mix_params`` must equal
``mixing_matrix(ring_adjacency(N), ones, 2) @ w`` for N ∈ {2, 4, 8} —
the N=2 case is the regression test for the double-peer bug (fwd and
bwd permutes deliver the SAME node, so the three-way average weighted
the single peer 2/3 instead of 1/2).  Node-varying parameters are
manufactured INSIDE one jit via a shard_map scatter (params are
logically replicated over the node axes, so divergence can't be fed in
from the host).  Tier-1 half: ``GossipDPSchedule`` key-stream
determinism and the ``ring_mix_params`` specs-leaf-count guard."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.gossip_dp import GossipDPSchedule, ring_mix_params

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# tier-1: host-side schedule + input validation
# ---------------------------------------------------------------------------


def test_schedule_key_stream_deterministic():
    """Same seed -> bitwise-identical mixing-matrix sequence; a
    different seed diverges.  The schedule is the only stateful object
    in gossip-DP, so replaying a run hinges on exactly this."""
    def draw(seed, k=4):
        s = GossipDPSchedule("random", 8, comm_batch=3, mix_every=2,
                             inactive_ratio=0.3, seed=seed)
        return [np.asarray(s.next_mix()) for _ in range(k)]

    a, b = draw(0), draw(0)
    for ma, mb in zip(a, b):
        np.testing.assert_array_equal(ma, mb)
    c = draw(1)
    assert any(not np.array_equal(ma, mc) for ma, mc in zip(a, c))
    # each matrix is row-stochastic (a sanity floor under the oracle tests)
    for m in a:
        np.testing.assert_allclose(m.sum(axis=1), np.ones(8), atol=1e-6)


def test_schedule_cadence():
    s = GossipDPSchedule("ring", 4, mix_every=3)
    assert [s.should_mix(t) for t in range(6)] == [
        False, False, True, False, False, True
    ]


def test_ring_mix_specs_leaf_mismatch_raises():
    """A specs tree with the wrong leaf count must refuse loudly — the
    old ``zip`` silently truncated and mixed the tail as replicated."""
    mesh = jax.make_mesh((1, 1), ("node", "model"))
    params = {"a": np.ones((4,)), "b": np.ones((4,))}
    with pytest.raises(ValueError, match="leaves"):
        ring_mix_params(params, mesh, ("node",), specs={"a": P(None)})


# ---------------------------------------------------------------------------
# multidevice: dense-oracle parity on a forced 8-device mesh
# ---------------------------------------------------------------------------

_SCATTER_GATHER = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.utils.compat import shard_map as _shard_map

    def node_varying(mesh):
        # params in gossip-DP are replicated over the node axes (P()),
        # so per-node divergence must be built INSIDE the program:
        # scatter hands node i row i of a host (N, D) base, gather
        # reads the per-node values back out as (N, D)
        scatter = _shard_map(lambda b: b[jax.lax.axis_index('node')],
                             mesh=mesh, in_specs=(P(),), out_specs=P(),
                             check_vma=False)
        gather = _shard_map(lambda w: jax.lax.all_gather(w, 'node'),
                            mesh=mesh, in_specs=(P(),), out_specs=P(),
                            check_vma=False)
        return scatter, gather
"""


@pytest.mark.multidevice
def test_gossip_mix_params_matches_dense_oracle():
    """allgather == psum-scatter == ``mix @ w`` for every node row."""
    print(_run(_SCATTER_GATHER + """
    from repro.core.gossip_dp import gossip_mix_params
    from repro.core.topology import mixing_matrix, random_adjacency

    N, D = 4, 96
    mesh = jax.make_mesh((N, 2), ('node', 'model'))
    base = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    adj = random_adjacency(jax.random.PRNGKey(3), N, 2)
    active = jnp.array([1.0, 0.0, 1.0, 1.0])
    mix = mixing_matrix(adj, active, 2)
    scatter, gather = node_varying(mesh)
    oracle = np.asarray(mix @ base)

    for impl in ("allgather", "psum"):
        @jax.jit
        def run(b):
            out = gossip_mix_params({'w': scatter(b)}, mix, mesh,
                                    ('node',), impl=impl)
            return gather(out['w'])
        got = np.asarray(run(base))
        np.testing.assert_allclose(got, oracle, atol=1e-5, err_msg=impl)
        # inactive node 1 has the identity row: bitwise-unchanged params
        np.testing.assert_array_equal(got[1], np.asarray(base)[1])
    print("GOSSIP_MIX_ORACLE_OK")
    """))


@pytest.mark.multidevice
def test_ring_mix_matches_mixing_matrix_oracle():
    """``ring_mix_params`` == the paper's ring mixing matrix for
    N ∈ {2, 4, 8}.  N=2 is the double-peer regression: pre-fix the
    permute average gave (w0 + 2·w1)/3 instead of (w0 + w1)/2."""
    print(_run(_SCATTER_GATHER + """
    from repro.core.gossip_dp import ring_mix_params
    from repro.core.topology import mixing_matrix, ring_adjacency

    D = 64
    for N in (2, 4, 8):
        mesh = jax.make_mesh((N, 8 // N), ('node', 'model'))
        base = jax.random.normal(jax.random.PRNGKey(N), (N, D))
        scatter, gather = node_varying(mesh)

        @jax.jit
        def run(b):
            out = ring_mix_params({'w': scatter(b)}, mesh, ('node',))
            return gather(out['w'])

        oracle = mixing_matrix(
            ring_adjacency(N), jnp.ones((N,)), 2
        ) @ base
        np.testing.assert_allclose(
            np.asarray(run(base)), np.asarray(oracle), atol=1e-5,
            err_msg=f"N={N}",
        )
    print("RING_MIX_ORACLE_OK")
    """))
