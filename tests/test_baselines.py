"""Tests for the dormant baseline trainers (paper §4.4 comparisons):
FedAvg's counts-weighted aggregation + its two bugfixes (inactive clients
must be inert; epochs are not steps), MetaSGD's learned inner lr actually
diverging from MAML, and ``train_supervised`` returning the best-val —
not last — params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial

from repro.config import FLConfig
from repro.core import FedAvg
from repro.core.async_sched import bernoulli_active
from repro.core.meta import MAML, MetaSGD
from repro.core.supervised import train_supervised
from repro.models import LSTMModel
from repro.optim import adam, sgd


def _fed(n=4, m=24, L=6, seed=0, counts=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, L)).astype(np.float32)
    y = rng.normal(size=(n, m)).astype(np.float32)
    counts = np.asarray(counts if counts is not None else [m] * n, np.int32)
    return x, y, counts


# ------------------------------------------------------------- FedAvg
def test_fedavg_aggregation_is_counts_weighted_mean():
    # run the round's own client updates, then check the server step is
    # EXACTLY the counts-weighted mean of the client models it produced
    x, y, counts = _fed(counts=[10, 20, 40, 10])
    model = LSTMModel(hidden=4).as_model()
    cfg = FLConfig(num_nodes=4, inactive_ratio=0.0, local_steps=2)
    fa = FedAvg(model, sgd(1e-2), cfg)
    params = model.init(jax.random.PRNGKey(1))

    key = jax.random.PRNGKey(3)
    _, new_params, _ = fa._round_jit(
        key, params, x, y, counts, batch_size=8, local_steps=2
    )

    # oracle: replicate the round's key chain, collect the per-client
    # models, and weight them by counts in float64 numpy
    _, _, k_cli = jax.random.split(key, 3)
    client_keys = jax.random.split(k_cli, 4)
    bcast = jax.tree.map(lambda l: jnp.broadcast_to(l, (4,) + l.shape), params)
    cp, _ = jax.vmap(
        partial(fa._client_update, batch_size=8, local_steps=2)
    )(client_keys, bcast, x, y, counts, jnp.ones((4,)))
    w = counts / counts.sum()

    def oracle(leaf):
        arr = np.asarray(leaf, np.float64)
        return (w.reshape((4,) + (1,) * (arr.ndim - 1)) * arr).sum(axis=0)

    for got, ref in zip(jax.tree.leaves(new_params), jax.tree.leaves(cp)):
        np.testing.assert_allclose(
            np.asarray(got), oracle(ref), rtol=1e-5, atol=1e-6
        )


def test_fedavg_inactive_clients_are_inert():
    # FAILS PRE-FIX: inactive clients used to train on their shard anyway
    # and reach aggregation through 0 * NaN = NaN.  Poison an inactive
    # client's data and the round must still produce finite params/loss.
    x, y, counts = _fed(n=6)
    model = LSTMModel(hidden=4).as_model()
    cfg = FLConfig(num_nodes=6, inactive_ratio=0.5, local_steps=2)
    fa = FedAvg(model, sgd(1e-2), cfg)
    params = model.init(jax.random.PRNGKey(1))

    key = jax.random.PRNGKey(0)
    _, k_act, _ = jax.random.split(key, 3)  # the round's own key chain
    active = np.asarray(bernoulli_active(k_act, 6, cfg.inactive_ratio))
    assert 0 < active.sum() < 6, "seed must give a mixed active set"
    poisoned = x.copy()
    poisoned[active == 0] = np.nan

    _, new_params, loss = fa._round_jit(
        key, params, poisoned, y, counts, batch_size=8, local_steps=2
    )
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(new_params):
        assert np.all(np.isfinite(np.asarray(leaf)))

    # and the gate is inert for ACTIVE clients: same round on clean data,
    # with vs without the poison, agrees bitwise
    _, clean_params, clean_loss = fa._round_jit(
        key, params, x, y, counts, batch_size=8, local_steps=2
    )
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(clean_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert float(loss) == float(clean_loss)


def test_fedavg_epochs_resolve_to_data_coverage_steps():
    # FAILS PRE-FIX: local_epochs used to collapse into
    # max(cfg.local_steps, local_epochs) — 3 "epochs" meant 3 STEPS
    # regardless of how much data a client holds.
    model = LSTMModel(hidden=4).as_model()
    cfg = FLConfig(num_nodes=2, local_steps=1)
    fa = FedAvg(model, sgd(1e-2), cfg, local_epochs=3)
    # largest client: ceil(200 / 64) = 4 batches/epoch -> 12 steps
    assert fa.resolve_local_steps([200, 50], batch_size=64) == 12
    # no epochs requested: cfg.local_steps is the literal step count
    assert FedAvg(model, sgd(1e-2), cfg).resolve_local_steps([200], 64) == 1


def test_fedavg_epochs_match_equivalent_steps_bitwise():
    # FAILS PRE-FIX: 2 epochs over 100 windows at batch 64 is 4 steps;
    # the epoch-configured run must be bit-identical to the step-
    # configured one (same key stream, same scan length)
    x, y, counts = _fed(n=3, m=100, counts=[100, 100, 100])
    model = LSTMModel(hidden=4).as_model()
    by_steps = FedAvg(model, sgd(1e-2), FLConfig(num_nodes=3, local_steps=4))
    by_epochs = FedAvg(
        model, sgd(1e-2), FLConfig(num_nodes=3, local_steps=1), local_epochs=2
    )
    pa, ha = by_steps.train(jax.random.PRNGKey(5), x, y, counts,
                            batch_size=64, rounds=2)
    pb, hb = by_epochs.train(jax.random.PRNGKey(5), x, y, counts,
                             batch_size=64, rounds=2)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in ha] == [h["loss"] for h in hb]


# ------------------------------------------------------- MAML / MetaSGD
def test_metasgd_learns_inner_lrs_and_diverges_from_maml():
    x, y, counts = _fed(n=3, m=16)
    model = LSTMModel(hidden=4).as_model()
    maml = MAML(model, adam(1e-2), inner_lr=0.05, inner_steps=2)
    msgd = MetaSGD(model, adam(1e-2), inner_lr=0.05, inner_steps=2)
    p_a, lrs_a, _ = maml.train(jax.random.PRNGKey(2), x, y, counts,
                               batch_size=8, steps=3)
    p_b, lrs_b, _ = msgd.train(jax.random.PRNGKey(2), x, y, counts,
                               batch_size=8, steps=3)
    # MAML's inner lrs are frozen at the configured constant...
    for leaf in jax.tree.leaves(lrs_a):
        assert np.all(np.asarray(leaf) == np.float32(0.05))
    # ...MetaSGD's are parameters: after meta-updates they must have moved
    moved = any(
        not np.allclose(np.asarray(leaf), 0.05)
        for leaf in jax.tree.leaves(lrs_b)
    )
    assert moved, "MetaSGD inner lrs never updated"
    # and the learned-lr meta-gradient changes the initialization itself
    diff = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b))
    )
    assert diff, "MetaSGD trained the same init as MAML"


# ---------------------------------------------------------- supervised
def test_supervised_returns_best_val_params_not_last():
    # anti-correlated val set: as training fits y, val targets -y get
    # WORSE every eval — so best-val is the first boundary, never the last
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 6)).astype(np.float32)
    w = rng.normal(size=(6,)).astype(np.float32)
    y = (x @ w).astype(np.float32)
    model = LSTMModel(hidden=4).as_model()
    params, history = train_supervised(
        model, sgd(5e-2), jax.random.PRNGKey(0), x, y,
        batch_size=16, steps=40, val=(x, -y), eval_every=10,
    )
    vals = [h["val_loss"] for h in history if "val_loss" in h]
    assert len(vals) == 4
    pv = model.apply(params, jnp.asarray(x))
    returned_val = float(jnp.mean(jnp.square(pv - jnp.asarray(-y))))
    assert returned_val == pytest.approx(min(vals), rel=1e-5)
    assert returned_val < vals[-1], (returned_val, vals)
