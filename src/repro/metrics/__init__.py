"""Clinical blood-glucose prediction metrics (paper §4): RMSE, MARD,
MAE, glucose-specific RMSE (Clarke-grid-weighted) and time-lag —
``all_metrics`` bundles them for every table/figure."""
from repro.metrics.glucose import (
    rmse,
    mard,
    mae,
    grmse,
    time_lag_minutes,
    all_metrics,
)
