from repro.metrics.glucose import (
    rmse,
    mard,
    mae,
    grmse,
    time_lag_minutes,
    all_metrics,
)
