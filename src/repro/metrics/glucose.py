"""Clinical BGLP metrics (paper §4.3), in mg/dL unless noted.

RMSE, MARD(%), MAE, glucose-specific RMSE (gRMSE, Del Favero et al. 2012
penalty), and time lag via cross-correlation (Cohen 1995).
"""
from __future__ import annotations

import numpy as np


def rmse(y: np.ndarray, yhat: np.ndarray) -> float:
    return float(np.sqrt(np.mean(np.square(y - yhat))))


def mard(y: np.ndarray, yhat: np.ndarray) -> float:
    y_safe = np.maximum(np.abs(y), 1e-6)
    return float(np.mean(np.abs(y - yhat) / y_safe) * 100.0)


def mae(y: np.ndarray, yhat: np.ndarray) -> float:
    return float(np.mean(np.abs(y - yhat)))


def _grmse_penalty(y: np.ndarray, yhat: np.ndarray) -> np.ndarray:
    """Del Favero-style clinically asymmetric penalty P(y, yhat) >= 1.

    Penalizes overestimation in hypoglycemia (y < 70) and underestimation
    in hyperglycemia (y > 180).  Smooth sigmoid ramp, max penalty x2.5.
    """
    over = yhat > y
    under = ~over
    hypo = 1.0 / (1.0 + np.exp((y - 70.0) / 5.0))   # ~1 deep in hypo
    hyper = 1.0 / (1.0 + np.exp((180.0 - y) / 10.0))  # ~1 deep in hyper
    pen = 1.0 + 1.5 * (hypo * over + hyper * under)
    return pen


def grmse(y: np.ndarray, yhat: np.ndarray) -> float:
    pen = _grmse_penalty(y, yhat)
    return float(np.sqrt(np.mean(pen * np.square(y - yhat))))


def time_lag_minutes(
    y: np.ndarray, yhat: np.ndarray, sample_minutes: float = 5.0, max_shift: int = 12
) -> float:
    """Temporal lag between prediction and truth via cross-correlation.

    Finds the shift k >= 0 maximizing corr(y[t-k], yhat[t]); the reported
    lag is k * sample_minutes.  Series must be time-ordered.
    """
    y = np.asarray(y, np.float64)
    yhat = np.asarray(yhat, np.float64)
    n = min(len(y), len(yhat))
    if n < max_shift + 2:
        return 0.0
    y, yhat = y[:n], yhat[:n]
    best_k, best_c = 0, -np.inf
    for k in range(max_shift + 1):
        a = y[: n - k]
        b = yhat[k:]
        sa, sb = a.std(), b.std()
        c = -np.inf if sa < 1e-9 or sb < 1e-9 else float(
            np.mean((a - a.mean()) * (b - b.mean())) / (sa * sb)
        )
        if c > best_c:
            best_c, best_k = c, k
    return best_k * sample_minutes


def all_metrics(y_raw: np.ndarray, yhat_raw: np.ndarray) -> dict[str, float]:
    return {
        "rmse": rmse(y_raw, yhat_raw),
        "mard": mard(y_raw, yhat_raw),
        "mae": mae(y_raw, yhat_raw),
        "grmse": grmse(y_raw, yhat_raw),
        "time_lag": time_lag_minutes(y_raw, yhat_raw),
    }
