"""Uniform architecture API + assigned input shapes.

``build_arch(cfg)`` dispatches on ``cfg.family`` and returns an ``Arch``
with a uniform surface the launcher / dry-run / benchmarks consume:

    init_params(key) -> params
    loss_fn(params, batch) -> scalar                     (train shapes)
    prefill_fn(params, batch) -> (logits, cache/state)   (prefill shapes)
    decode_fn(params, state, batch) -> (logits, state)   (decode shapes)
    init_decode_state(params, batch_size, seq_len) -> state
    input_specs(shape_name) -> ShapeDtypeStruct batch (no allocation)

Input shapes (assigned):
    train_4k     seq 4096    global batch 256   train_step
    prefill_32k  seq 32768   global batch 32    prefill
    decode_32k   seq 32768   global batch 128   decode_step (1 token)
    long_500k    seq 524288  global batch 1     decode_step (sub-quadratic
                                                 archs only; see DESIGN.md)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.nn.layers import pad_vocab

PyTree = Any


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass
class Arch:
    cfg: ArchConfig
    init_params: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_decode_state: Callable
    supports_long: bool

    def supports(self, shape: str) -> bool:
        if shape == "long_500k":
            return self.supports_long
        return True

    # -- input specs -----------------------------------------------------
    def input_specs(self, shape_name: str, *, override_batch: int | None = None,
                    override_seq: int | None = None) -> PyTree:
        cfg = self.cfg
        sh = SHAPES[shape_name]
        b = override_batch or sh.global_batch
        s = override_seq or sh.seq_len
        i32 = jnp.int32
        act_dtype = jnp.dtype(cfg.dtype)
        tok = lambda *shape: jax.ShapeDtypeStruct(shape, i32)

        if sh.kind in ("train", "prefill"):
            if cfg.family == "vlm":
                tv = cfg.vision_tokens
                from repro.arch.lm import VISION_STUB_DIM

                spec = {
                    "patches": jax.ShapeDtypeStruct((b, tv, VISION_STUB_DIM), act_dtype),
                    "tokens": tok(b, s - tv),
                }
                if sh.kind == "train":
                    spec["labels"] = tok(b, s)
                return spec
            if cfg.family == "encdec":
                spec = {
                    "frames": jax.ShapeDtypeStruct(
                        (b, cfg.encoder_seq, cfg.d_model), act_dtype
                    ),
                    "tokens": tok(b, s),
                }
                if sh.kind == "train":
                    spec["labels"] = tok(b, s)
                return spec
            spec = {"tokens": tok(b, s)}
            if sh.kind == "train":
                spec["labels"] = tok(b, s)
            return spec

        # decode: one new token against a seq_len-deep state
        return {"token": tok(b, 1), "pos": jax.ShapeDtypeStruct((), i32)}

    def decode_state_specs(self, shape_name: str, *, override_batch: int | None = None,
                           override_seq: int | None = None) -> PyTree:
        sh = SHAPES[shape_name]
        b = override_batch or sh.global_batch
        s = override_seq or sh.seq_len
        params_spec = jax.eval_shape(self.init_params, jax.random.PRNGKey(0))
        return jax.eval_shape(
            lambda p: self.init_decode_state(p, b, s), params_spec
        )


# ---------------------------------------------------------------------------
# family dispatch
# ---------------------------------------------------------------------------


def build_arch(cfg: ArchConfig) -> Arch:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.arch import lm

        return Arch(
            cfg=cfg,
            init_params=lambda key: lm.init_params(key, cfg),
            loss_fn=lambda p, b: lm.loss_fn(p, cfg, b),
            prefill_fn=lambda p, b: lm.prefill(p, cfg, b),
            decode_fn=lambda p, st, b: lm.decode_step(p, cfg, st, b),
            init_decode_state=lambda p, bsz, s: lm.init_cache(cfg, bsz, s),
            supports_long=cfg.sliding_window > 0,
        )
    if cfg.family == "ssm":
        from repro.arch import ssm_lm

        return Arch(
            cfg=cfg,
            init_params=lambda key: ssm_lm.init_params(key, cfg),
            loss_fn=lambda p, b: ssm_lm.loss_fn(p, cfg, b),
            prefill_fn=lambda p, b: ssm_lm.prefill(p, cfg, b),
            decode_fn=lambda p, st, b: ssm_lm.decode_step(p, cfg, st, b),
            init_decode_state=lambda p, bsz, s: ssm_lm.init_state(cfg, bsz),
            supports_long=True,
        )
    if cfg.family == "hybrid":
        from repro.arch import hybrid_lm

        return Arch(
            cfg=cfg,
            init_params=lambda key: hybrid_lm.init_params(key, cfg),
            loss_fn=lambda p, b: hybrid_lm.loss_fn(p, cfg, b),
            prefill_fn=lambda p, b: (hybrid_lm.forward(p, cfg, b)[0][:, -1:], None),
            decode_fn=lambda p, st, b: hybrid_lm.decode_step(p, cfg, st, b),
            init_decode_state=lambda p, bsz, s: hybrid_lm.init_state(cfg, bsz, s),
            supports_long=True,
        )
    if cfg.family == "encdec":
        from repro.arch import encdec

        return Arch(
            cfg=cfg,
            init_params=lambda key: encdec.init_params(key, cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, cfg, b),
            prefill_fn=lambda p, b: (encdec.forward(p, cfg, b)[0][:, -1:], None),
            decode_fn=lambda p, st, b: encdec.decode_step(p, cfg, st, b),
            init_decode_state=lambda p, bsz, s: encdec.init_state(p, cfg, bsz, s),
            supports_long=False,
        )
    raise KeyError(f"unknown family {cfg.family!r}")


# re-exported for launchers
from repro.arch.common import TrainState, init_train_state, make_train_step  # noqa: E402,F401
