"""Partition rules: param-path -> PartitionSpec, divisibility-aware.

Policy (DESIGN.md §6):
  * weights shard their LARGEST model-parallel-friendly dim on "model"
    (d_ff, vocab, fused-QKV output, expert dim when divisible),
  * a dim is sharded only if evenly divisible by the axis size — else the
    next preference is tried, else replicated (this is what makes the
    8-kv-head / 16-way-axis case work: the fused kv projection output
    1024 shards, the head count would not),
  * stacked-layer leaves get a leading ``None`` for the scan dim,
  * batch dims of activations shard on "data" (+"pod" multi-pod).

Rules are keyed by the LAST path component (param names are chosen to be
globally unambiguous), with a small table of (dim-index preferences).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# name -> list of (dim, axis-kind) preferences; "model" only for now.
# dim indices are for the UNSTACKED param (no leading layer dim).
_RULES: dict[str, tuple[int, ...]] = {
    # embeddings / heads
    "embed": (0,),          # (V, d): shard vocab
    "lm_head": (1,),        # (d, V): shard vocab
    "pos_embed": (),
    # attention
    "wq": (1,), "wk": (1,), "wv": (1,), "wo": (0,),
    "bq": (0,), "bk": (0,), "bv": (0,),
    # mlp
    "w_gate": (1,), "w_up": (1,), "w_down": (0,),
    "w_in": (1,), "w_out": (0,), "b_in": (0,), "b_out": (),
    # moe (stacked (E, d, ff) / (E, ff, d)): prefer expert dim, then hidden
    "moe_w_gate": (0, 2), "moe_w_up": (0, 2), "moe_w_down": (0, 1),
    "router": (),
    # mamba2
    "in_proj": (1,), "out_proj": (0,), "conv_w": (1,), "conv_b": (0,),
    "a_log": (), "dt_bias": (), "d_skip": (), "norm_scale": (),
    # rglru / griffin
    "w_in_x": (1,), "w_in_gate": (1,), "w_a": (1,), "w_x": (1,),
    "b_a": (0,), "b_x": (0,), "lam": (0,),
    # norms / misc
    "scale": (), "bias": (), "b": (),
}


def _spec_for(name: str, shape: tuple[int, ...], model_axis: str, axis_size: int,
              stacked: bool, fsdp_axes: tuple[str, ...] = (), fsdp_size: int = 1) -> P:
    prefs = _RULES.get(name, None)
    ndim = len(shape)
    off = 1 if stacked else 0
    entries: list = [None] * ndim
    if prefs is None:
        # default: shard the largest divisible dim (skipping the layer dim)
        order = sorted(range(off, ndim), key=lambda i: -shape[i])
        prefs_abs = order
    else:
        prefs_abs = [p + off for p in prefs]
    for dim in prefs_abs:
        if dim < ndim and shape[dim] % axis_size == 0 and shape[dim] >= axis_size:
            entries[dim] = model_axis
            break
    if fsdp_axes and fsdp_size > 1:
        # serving/FSDP: additionally shard the largest remaining divisible
        # dim over the data axes (weights all-gather per layer on use)
        cands = sorted(
            (i for i in range(off, ndim) if entries[i] is None),
            key=lambda i: -shape[i],
        )
        for dim in cands:
            if shape[dim] % fsdp_size == 0 and shape[dim] >= fsdp_size:
                entries[dim] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return P(*entries)


def param_pspecs(params: PyTree, *, model_axis: str = "model", axis_size: int,
                 fsdp_axes: tuple[str, ...] = (), fsdp_size: int = 1,
                 stacked_subtrees: tuple[str, ...] = ("layers", "enc_layers", "dec_layers", "blocks")) -> PyTree:
    """PartitionSpec tree matching ``params``."""

    def fn(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        stacked = any(k in stacked_subtrees for k in keys[:-1])
        # disambiguate MoE expert weights from dense MLP weights
        if name in ("w_gate", "w_up", "w_down") and (len(leaf.shape) - (1 if stacked else 0)) == 3:
            name = "moe_" + name
        return _spec_for(name, leaf.shape, model_axis, axis_size, stacked,
                         fsdp_axes, fsdp_size)

    return jax.tree_util.tree_map_with_path(fn, params)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ("pod","data") when the pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_spec(mesh: Mesh, ndim: int, *, seq_axis: int | None = None) -> P:
    """(B, ...) activation spec: batch on data axes."""
    entries: list = [data_axes(mesh)] + [None] * (ndim - 1)
    return P(*entries)


def shardings_for(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Activation-sharding policy (residual-stream constraints)
#
# GSPMD propagation can otherwise let activations inherit FSDP *weight*
# shardings (batch replicated, d_model scattered over "data") which blows
# saved-activation memory by the data-axis size — found in the first
# 123B dry-run (§Perf iteration 0b).  The policy pins the residual
# stream to (batch -> data axes, seq -> optional "model" for sequence
# parallelism, d_model -> replicated) at layer boundaries.
# ---------------------------------------------------------------------------

from contextlib import contextmanager
from contextvars import ContextVar

_ACT_POLICY: ContextVar = ContextVar("act_policy", default=None)


@contextmanager
def activation_policy(batch_axes, *, seq_axis=None, seq_axis_size: int = 1,
                      attn_axis=None, attn_axis_size: int = 1,
                      attn_seq_fallback: bool = True):
    """Enable residual-stream constraints inside a lowering context.

    ``batch_axes``: mesh axis (or tuple) for the batch dim.
    ``seq_axis``: optional axis for the seq dim (sequence parallelism —
    the §Perf lever for saved-activation memory).
    ``attn_axis``: optional axis to pin attention internals ((B,S,H,hd)
    tensors and flash-scan carries): heads when divisible, else the q
    seq dim — kills GSPMD resharding churn inside blocked attention
    (§Perf hillclimb H1).
    """
    tok = _ACT_POLICY.set(
        {"batch": batch_axes, "seq": seq_axis, "seq_size": seq_axis_size,
         "attn": attn_axis, "attn_size": attn_axis_size,
         "attn_seq_fallback": attn_seq_fallback}
    )
    try:
        yield
    finally:
        _ACT_POLICY.reset(tok)


def _divisible(n: int, k: int) -> bool:
    return k > 1 and n % k == 0 and n >= k


def constrain_attn(t, layout: str, *, kv: bool = False):
    """Pin attention internals.  layout: 'bshd' for (B,S,H,hd) q/k/v,
    'bhsd' for (B,H,S,hd) scan accs, 'bhs' for (B,H,S) softmax stats.

    Prefers sharding H on the attn axis, falling back to the QUERY seq
    dim.  K/V tensors (``kv=True``) never shard their seq dim — blocked
    flash attention slices it dynamically, and an S-sharded KV turns
    every block slice into a reshard (measured 4x collective blow-up on
    mistral-large, §Perf H2 iteration 1) — they replicate heads instead.
    """
    pol = _ACT_POLICY.get()
    if pol is None or not pol.get("attn"):
        return t
    ax, size = pol["attn"], pol["attn_size"]
    batch = pol["batch"]
    dims = {c: i for i, c in enumerate(layout)}
    entries: list = [None] * t.ndim
    if "b" in dims:
        entries[dims["b"]] = batch
    h_i, s_i = dims.get("h"), dims.get("s")
    if h_i is not None and _divisible(t.shape[h_i], size):
        entries[h_i] = ax
    elif (not kv) and pol.get("attn_seq_fallback", True) and s_i is not None \
            and _divisible(t.shape[s_i], size):
        # query-seq fallback: a 22x collective win for 32k PREFILL when
        # heads don't divide, but a 2.2x REGRESSION for the training
        # backward (dq resharding) — enabled for serve paths only
        # (§Perf H1 it-3).
        entries[s_i] = ax
    elif not kv:
        # nothing shardable on the model axis: constraining batch alone
        # forces GSPMD to replicate the attention compute across "model"
        # (measured 2.5x compute blow-up, yi-34b train) — stay out of
        # propagation's way entirely.
        return t
    return jax.lax.with_sharding_constraint(t, P(*entries))


def constrain_act(x):
    """Pin a (B, S, d) activation to the policy (no-op without one)."""
    pol = _ACT_POLICY.get()
    if pol is None or x.ndim != 3:
        return x
    seq = (
        pol["seq"]
        if (pol["seq"] and x.shape[1] % max(pol["seq_size"], 1) == 0
            and x.shape[1] >= pol["seq_size"])
        else None
    )
    return jax.lax.with_sharding_constraint(x, P(pol["batch"], seq, None))
