"""Decoder-only LM assembly: dense GQA, MoE, and VLM families.

One parameter tree, three entry points:
  * ``forward``      — full-sequence logits (training / teacher forcing),
  * ``prefill``      — logits + per-layer KV caches (ring-truncated for
                       sliding-window archs),
  * ``decode_step``  — one token against the caches.

Layers are STACKED (leading L dim) and iterated with ``jax.lax.scan`` so
the 88-layer config lowers to a compact HLO, with ``jax.checkpoint`` on
the layer body (full per-layer remat — the §Perf baseline).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.arch.sharding import constrain_act, constrain_attn
from repro.nn.attention import KVCache, decode_attention, gqa_attention
from repro.nn.layers import dense, embed, pad_vocab, rms_norm, rope, swiglu_ffn
from repro.nn.moe import init_moe, moe_ffn

PyTree = Any

VISION_STUB_DIM = 1024  # stubbed vision-encoder embedding width (DESIGN.md)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig) -> PyTree:
    d, hd = cfg.d_model, cfg.head_dim
    h, k = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "ln1_scale": jnp.zeros((d,), jnp.float32),
        "ln2_scale": jnp.zeros((d,), jnp.float32),
        "wq": jax.random.normal(ks[0], (d, h * hd), jnp.float32) * d**-0.5,
        "wk": jax.random.normal(ks[1], (d, k * hd), jnp.float32) * d**-0.5,
        "wv": jax.random.normal(ks[2], (d, k * hd), jnp.float32) * d**-0.5,
        "wo": jax.random.normal(ks[3], (h * hd, d), jnp.float32) * (h * hd) ** -0.5,
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((k * hd,), jnp.float32)
        p["bv"] = jnp.zeros((k * hd,), jnp.float32)
    if cfg.num_experts:
        p["moe"] = init_moe(ks[4], d, cfg.d_ff, cfg.num_experts)
    else:
        from repro.nn.layers import init_swiglu

        p.update(init_swiglu(ks[4], d, cfg.d_ff))
    return p


def init_params(key, cfg: ArchConfig) -> PyTree:
    vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    keys = jax.random.split(key, cfg.num_layers + 3)
    layers = [init_layer(keys[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *layers)
    p = {
        "embed": jax.random.normal(keys[-1], (vp, d), jnp.float32) * 0.02,
        "layers": stacked,
        "final_scale": jnp.zeros((d,), jnp.float32),
        "lm_head": jax.random.normal(keys[-2], (d, vp), jnp.float32) * d**-0.5,
    }
    if cfg.family == "vlm":
        p["vision_proj"] = {
            "w_in": jax.random.normal(keys[-3], (VISION_STUB_DIM, d), jnp.float32)
            * VISION_STUB_DIM**-0.5,
        }
    return p


# ---------------------------------------------------------------------------
# layer body (shared by forward / prefill / decode)
# ---------------------------------------------------------------------------


def _qkv(x, lp, cfg: ArchConfig, positions):
    b, s, d = x.shape
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(x, lp["wq"], lp.get("bq")).reshape(b, s, h, hd)
    kk = dense(x, lp["wk"], lp.get("bk")).reshape(b, s, k, hd)
    v = dense(x, lp["wv"], lp.get("bv")).reshape(b, s, k, hd)
    q = constrain_attn(rope(q, positions, cfg.rope_theta), "bshd")
    kk = constrain_attn(rope(kk, positions, cfg.rope_theta), "bshd", kv=True)
    return q, kk, constrain_attn(v, "bshd", kv=True)


def layer_forward(x, lp, cfg: ArchConfig, positions):
    """Full-seq layer; returns (x, (k, v), aux).

    ``cfg.parallel_block``: PaLM-style parallel residual — attention and
    MLP both read norm(x) and their outputs are summed BEFORE the single
    residual all-reduce, halving per-layer activation collectives (§Perf
    H2 iteration; beyond-paper variant, changes the model's math).
    """
    h = rms_norm(x, lp["ln1_scale"], cfg.norm_eps)
    q, k, v = _qkv(h, lp, cfg, positions)
    attn = gqa_attention(q, k, v, causal=True, window=cfg.sliding_window)
    attn_out = dense(attn.reshape(x.shape[0], x.shape[1], -1), lp["wo"])
    if cfg.parallel_block:
        if cfg.num_experts:
            ff, aux = moe_ffn(
                h, lp["moe"], top_k=cfg.experts_per_token,
                capacity_factor=cfg.expert_capacity_factor,
            )
        else:
            ff, aux = swiglu_ffn(h, lp), {}
        return x + attn_out + ff, (k, v), aux
    x = x + attn_out
    h = rms_norm(x, lp["ln2_scale"], cfg.norm_eps)
    if cfg.num_experts:
        ff, aux = moe_ffn(
            h, lp["moe"], top_k=cfg.experts_per_token,
            capacity_factor=cfg.expert_capacity_factor,
        )
    else:
        ff, aux = swiglu_ffn(h, lp), {}
    return x + ff, (k, v), aux


def layer_decode(x, lp, cache: KVCache, cfg: ArchConfig, pos):
    """One-token layer. x (B,1,d); pos scalar absolute position."""
    h = rms_norm(x, lp["ln1_scale"], cfg.norm_eps)
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _qkv(h, lp, cfg, positions.reshape(1))
    cache = cache.append(k, v)
    attn = decode_attention(q, cache, window=cfg.sliding_window)
    x = x + dense(attn.reshape(x.shape[0], 1, -1), lp["wo"])
    h = rms_norm(x, lp["ln2_scale"], cfg.norm_eps)
    if cfg.num_experts:
        ff, _ = moe_ffn(
            h, lp["moe"], top_k=cfg.experts_per_token,
            capacity_factor=cfg.expert_capacity_factor,
        )
    else:
        ff = swiglu_ffn(h, lp)
    return x + ff, cache


# ---------------------------------------------------------------------------
# model-level entry points
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, batch, dtype):
    """Token (and VLM patch) embedding -> (B, S, d)."""
    x = embed(batch["tokens"], params["embed"], dtype)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dtype)  # (B, Tv, VISION_STUB_DIM)
        vis = dense(patches, params["vision_proj"]["w_in"])
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward(params, cfg: ArchConfig, batch, *, remat: bool = True) -> jnp.ndarray:
    """Teacher-forcing logits (B, S_total, Vp) plus MoE aux losses."""
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = _embed_inputs(params, cfg, batch, dtype)
    s = x.shape[1]
    positions = jnp.arange(s)

    def body(carry, lp):
        x = constrain_act(carry)
        x, _, aux = layer_forward(x, lp, cfg, positions)
        x = constrain_act(x)
        aux_vec = (
            jnp.stack([aux["load_balance"], aux["router_z"]])
            if aux
            else jnp.zeros((2,), jnp.float32)
        )
        return x, aux_vec

    body_fn = jax.checkpoint(body) if remat else body
    x = constrain_act(x)
    x, aux_stack = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    logits = dense(x, params["lm_head"])
    return logits, jnp.mean(aux_stack, axis=0)


def loss_fn(params, cfg: ArchConfig, batch, *, aux_weight: float = 0.01):
    from repro.arch.common import cross_entropy

    logits, aux = forward(params, cfg, batch)
    ce = cross_entropy(logits, batch["labels"])
    if cfg.num_experts:
        ce = ce + aux_weight * aux[0] + 1e-3 * aux[1]
    return ce


def cache_capacity(cfg: ArchConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> KVCache:
    """Stacked (L-leading) caches for decode."""
    cap = cache_capacity(cfg, seq_len)
    dtype = jnp.dtype(cfg.dtype)
    one = lambda: KVCache.init(batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype)
    caches = [one() for _ in range(cfg.num_layers)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *caches)


def prefill(params, cfg: ArchConfig, batch):
    """Prefill: returns (last-position logits, stacked KV caches)."""
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = _embed_inputs(params, cfg, batch, dtype)
    s = x.shape[1]
    positions = jnp.arange(s)
    cap = cache_capacity(cfg, s)

    def body(x, lp):
        x = constrain_act(x)
        x, (k, v), _ = layer_forward(x, lp, cfg, positions)
        # keep only the last `cap` positions (ring layout: contiguous here)
        return constrain_act(x), (k[:, -cap:], v[:, -cap:])

    x, kvs = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_scale"], cfg.norm_eps)
    logits = dense(x, params["lm_head"])
    b = x.shape[0]
    caches = KVCache(
        k=kvs[0], v=kvs[1],
        pos=jnp.full((cfg.num_layers,), s, jnp.int32),
    )
    return logits, caches


def decode_step(params, cfg: ArchConfig, caches: KVCache, batch):
    """One decode step.  batch = {"token": (B, 1) int32, "pos": scalar}.
    ``caches`` leaves have leading L.  Returns (logits (B,1,Vp), caches).
    """
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["token"], params["embed"], dtype)
    pos = batch["pos"]

    def body(x, scanned):
        lp, cache_l = scanned
        x, new_cache = layer_decode(x, lp, cache_l, cfg, pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    logits = dense(x, params["lm_head"])
    return logits, new_caches
