"""Mamba-2 language model assembly (attention-free SSM family)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.arch.sharding import constrain_act
from repro.nn.layers import dense, embed, pad_vocab, rms_norm
from repro.nn.ssm import (
    init_mamba2_block,
    init_mamba2_state,
    mamba2_block,
    mamba2_decode,
)

PyTree = Any


def _dims(cfg: ArchConfig):
    nheads = cfg.ssm_heads or (cfg.ssm_expand * cfg.d_model // 64)
    return dict(expand=cfg.ssm_expand, nheads=nheads, dstate=cfg.ssm_state)


def init_params(key, cfg: ArchConfig) -> PyTree:
    vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    dims = _dims(cfg)
    keys = jax.random.split(key, cfg.num_layers + 2)
    layers = []
    for i in range(cfg.num_layers):
        ks = jax.random.split(keys[i], 2)
        layers.append(
            {
                "ln_scale": jnp.zeros((d,), jnp.float32),
                "mamba": init_mamba2_block(ks[0], d, **dims),
            }
        )
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *layers)
    return {
        "embed": jax.random.normal(keys[-1], (vp, d), jnp.float32) * 0.02,
        "layers": stacked,
        "final_scale": jnp.zeros((d,), jnp.float32),
        "lm_head": jax.random.normal(keys[-2], (d, vp), jnp.float32) * d**-0.5,
    }


def forward(params, cfg: ArchConfig, batch, *, remat: bool = True):
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["tokens"], params["embed"], dtype)
    dims = _dims(cfg)

    def body(x, lp):
        x = constrain_act(x)
        h = rms_norm(x, lp["ln_scale"], cfg.norm_eps)
        x = x + mamba2_block(h, lp["mamba"], chunk=cfg.ssm_chunk, **dims)
        return constrain_act(x), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    return dense(x, params["lm_head"]), jnp.zeros((2,), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch):
    from repro.arch.common import cross_entropy

    logits, _ = forward(params, cfg, batch)
    return cross_entropy(logits, batch["labels"])


def init_state(cfg: ArchConfig, batch: int) -> PyTree:
    """Stacked per-layer (conv, ssm) decode states.  O(1) in context
    length — the reason this family runs long_500k."""
    dims = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    one = lambda: init_mamba2_state(batch, cfg.d_model, dtype=dtype, **dims)
    states = [one() for _ in range(cfg.num_layers)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *states)


def prefill(params, cfg: ArchConfig, batch):
    """Prefill returns last-token logits + final recurrent states."""
    from repro.arch.common import cast_params

    params = cast_params(params, cfg.dtype)
    dtype = jnp.dtype(cfg.dtype)
    x = embed(batch["tokens"], params["embed"], dtype)
    dims = _dims(cfg)

    # run block-by-block keeping final states: reuse decode-state shapes
    def body(x, lp):
        x = constrain_act(x)
        h = rms_norm(x, lp["ln_scale"], cfg.norm_eps)
        x = x + mamba2_block(h, lp["mamba"], chunk=cfg.ssm_chunk, **dims)
        # states are re-derivable; for serving we'd thread them out of
        # ssd_forward — kept simple here (decode starts from prefill text)
        return constrain_act(x), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_scale"], cfg.norm_eps)
    return dense(x, params["lm_head"]), init_state(cfg, batch["tokens"].shape[0])


def decode_step(params, cfg: ArchConfig, states, batch):
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["token"], params["embed"], dtype)[:, 0, :]  # (B, d)
    dims = _dims(cfg)

    def body(x, scanned):
        lp, st = scanned
        h = rms_norm(x, lp["ln_scale"], cfg.norm_eps)
        out, new_st = mamba2_decode(h, lp["mamba"], st, **dims)
        return x + out, new_st

    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    x = rms_norm(x[:, None, :], params["final_scale"], cfg.norm_eps)
    return dense(x, params["lm_head"]), new_states
