"""Shared pieces of the architecture assemblies: loss, train-state,
gradient-accumulated train step, and decode-loop scaffolding.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def cast_params(params: PyTree, dtype) -> PyTree:
    """Cast f32 master params to the compute dtype ONCE at forward entry.

    The cast runs on the *sharded* leaves, so FSDP all-gathers move bf16
    (half the bytes) instead of gathering f32 and converting after — the
    cast-then-gather ordering (§Perf).  Gradients flow through the cast
    (standard mixed precision: bf16 compute, f32 master/update).
    """
    dt = jnp.dtype(dtype)
    if dt == jnp.float32:
        return params
    return jax.tree.map(
        lambda l: l.astype(dt) if l.dtype == jnp.float32 else l, params
    )


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0.  logits (B,S,V) any dtype;
    computed in f32 without materializing one-hots (vocab may be sharded).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: PyTree
    m: PyTree            # adam first moment
    v: PyTree            # adam second moment
    step: jnp.ndarray


def init_train_state(params: PyTree) -> TrainState:
    return TrainState(
        params=params,
        m=jax.tree.map(jnp.zeros_like, params),
        v=jax.tree.map(jnp.zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )


def adam_apply(state: TrainState, grads: PyTree, *, lr: float = 3e-4,
               b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8) -> TrainState:
    step = state.step + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        state.params, m, v,
    )
    return TrainState(params=params, m=m, v=v, step=step)


def make_train_step(
    loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
    *,
    num_microbatches: int = 1,
    lr: float = 3e-4,
    data_axes: tuple[str, ...] = (),
):
    """Gradient-accumulated train step.

    ``loss_fn(params, microbatch) -> scalar``.  The global batch (leaves
    (B, ...)) is split into ``num_microbatches`` along dim 0 and gradients
    are accumulated in f32 via lax.scan — the standard way to fit large-
    model activations in HBM (the remat policy lives inside loss_fn).

    ``data_axes``: mesh axes carrying the batch dim.  The microbatch
    reshape (B,) -> (M, B/M) must KEEP the batch shard on dim 1 — without
    an explicit constraint GSPMD can replicate the microbatch and blow
    activation memory by the data-axis size (§Perf iteration 0).
    """
    from jax.sharding import PartitionSpec as P

    def _constrain_micro(mb: PyTree) -> PyTree:
        if not data_axes:
            return mb

        def leaf(l):
            if l.ndim >= 2:
                return jax.lax.with_sharding_constraint(
                    l, P(None, data_axes, *([None] * (l.ndim - 2)))
                )
            return l

        return jax.tree.map(leaf, mb)

    def train_step(state: TrainState, batch: PyTree):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda l: l.reshape((num_microbatches, l.shape[0] // num_microbatches)
                                    + l.shape[1:]),
                batch,
            )
            mb = _constrain_micro(mb)

            def acc(carry, micro):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(state.params, micro)
                return (
                    loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads),
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / num_microbatches
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
        new_state = adam_apply(state, grads, lr=lr)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        )
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    inv = jnp.exp(-jnp.arange(0, dim, 2).astype(jnp.float32) / dim * jnp.log(10000.0))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :dim]
