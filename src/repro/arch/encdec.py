"""Whisper-style encoder-decoder assembly (audio family).

The mel-spectrogram + conv frontend is a STUB per the harness carve-out:
``input_specs`` supplies precomputed frame embeddings (B, S_enc, d) and
this module implements the transformer encoder + decoder that consume
them.  Pre-LN layers with biases and learned/sinusoidal positions match
the Whisper architecture (arXiv:2212.04356); attention is MHA
(num_kv_heads == num_heads).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.arch.common import sinusoidal_positions
from repro.arch.sharding import constrain_act
from repro.nn.attention import KVCache, decode_attention, gqa_attention, plain_attention
from repro.nn.layers import dense, embed, gelu_ffn, init_gelu_ffn, layer_norm, pad_vocab

PyTree = Any

# Whisper's real decoder context is 448; the assigned decode/prefill
# shapes require 32k, so the learned position table is sized to match
# (noted in DESIGN.md — the architecture, not the checkpoint, is assigned).
MAX_DECODER_POS = 32_768


def _init_attn(key, d, h, hd, kh=None):
    kh = kh or h
    ks = jax.random.split(key, 4)
    return {
        "wq": jax.random.normal(ks[0], (d, h * hd)) * d**-0.5,
        "bq": jnp.zeros((h * hd,)),
        "wk": jax.random.normal(ks[1], (d, kh * hd)) * d**-0.5,
        "wv": jax.random.normal(ks[2], (d, kh * hd)) * d**-0.5,
        "bv": jnp.zeros((kh * hd,)),
        "wo": jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5,
        "bo": jnp.zeros((d,)),
    }


def _ln_init(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


def init_params(key, cfg: ArchConfig) -> PyTree:
    vp = pad_vocab(cfg.vocab_size)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _ln_init(d), "ln2": _ln_init(d),
            "attn": _init_attn(k1, d, h, hd),
            "mlp": init_gelu_ffn(k2, d, cfg.d_ff),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _ln_init(d), "ln2": _ln_init(d), "ln3": _ln_init(d),
            "self_attn": _init_attn(k1, d, h, hd),
            "cross_attn": _init_attn(k2, d, h, hd),
            "mlp": init_gelu_ffn(k3, d, cfg.d_ff),
        }

    nke = cfg.encoder_layers
    keys = jax.random.split(key, nke + cfg.num_layers + 3)
    enc = [enc_layer(keys[i]) for i in range(nke)]
    dec = [dec_layer(keys[nke + i]) for i in range(cfg.num_layers)]
    return {
        "enc_layers": jax.tree.map(lambda *ls: jnp.stack(ls), *enc),
        "enc_final_ln": _ln_init(d),
        "dec_layers": jax.tree.map(lambda *ls: jnp.stack(ls), *dec),
        "dec_final_ln": _ln_init(d),
        "embed": jax.random.normal(keys[-1], (vp, d)) * 0.02,
        "pos_embed": jax.random.normal(keys[-2], (MAX_DECODER_POS, d)) * 0.01,
    }


def _mha(x, ap, cfg, *, kv=None, causal, window=0):
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    src = x if kv is None else kv
    q = dense(x, ap["wq"], ap["bq"]).reshape(b, s, h, hd)
    k = dense(src, ap["wk"]).reshape(b, src.shape[1], h, hd)
    v = dense(src, ap["wv"], ap["bv"]).reshape(b, src.shape[1], h, hd)
    out = gqa_attention(q, k, v, causal=causal, window=window)
    return dense(out.reshape(b, s, -1), ap["wo"], ap["bo"])


def encode(params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: stubbed conv-frontend output (B, S_enc, d)."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]

    def body(x, lp):
        x = constrain_act(x)
        h = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        x = x + _mha(h, lp["attn"], cfg, causal=False)
        h = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + gelu_ffn(h, lp["mlp"])
        return constrain_act(x), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return layer_norm(x, params["enc_final_ln"]["scale"], params["enc_final_ln"]["bias"])


def decode_train(params, cfg: ArchConfig, tokens, enc_out):
    dtype = jnp.dtype(cfg.dtype)
    x = embed(tokens, params["embed"], dtype)
    s = x.shape[1]
    x = x + params["pos_embed"][:s].astype(dtype)[None]

    def body(x, lp):
        x = constrain_act(x)
        h = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        x = x + _mha(h, lp["self_attn"], cfg, causal=True)
        h = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + _mha(h, lp["cross_attn"], cfg, kv=enc_out, causal=False)
        h = layer_norm(x, lp["ln3"]["scale"], lp["ln3"]["bias"])
        x = x + gelu_ffn(h, lp["mlp"])
        return constrain_act(x), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    x = layer_norm(x, params["dec_final_ln"]["scale"], params["dec_final_ln"]["bias"])
    # tied output head (whisper ties the token embedding)
    return x @ params["embed"].T.astype(x.dtype)


def forward(params, cfg: ArchConfig, batch, *, remat: bool = True):
    from repro.arch.common import cast_params

    params = cast_params(params, cfg.dtype)
    enc_out = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], enc_out)
    return logits, jnp.zeros((2,), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch):
    from repro.arch.common import cross_entropy

    logits, _ = forward(params, cfg, batch)
    return cross_entropy(logits, batch["labels"])


# -- serving -----------------------------------------------------------------


def init_state(params, cfg: ArchConfig, batch: int, seq_len: int, frames=None) -> PyTree:
    """Decode state: per-layer self-attn cache + precomputed cross K/V."""
    dtype = jnp.dtype(cfg.dtype)
    h, hd = cfg.num_heads, cfg.head_dim
    self_caches = jax.tree.map(
        lambda *ls: jnp.stack(ls),
        *[KVCache.init(batch, seq_len, h, hd, dtype) for _ in range(cfg.num_layers)],
    )
    if frames is None:
        enc_out = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
    else:
        enc_out = encode(params, cfg, frames)

    def cross_kv(lp):
        k = dense(enc_out, lp["cross_attn"]["wk"]).reshape(batch, -1, h, hd)
        v = dense(enc_out, lp["cross_attn"]["wv"], lp["cross_attn"]["bv"]).reshape(
            batch, -1, h, hd
        )
        return {"k": k, "v": v}

    cross = jax.vmap(cross_kv)(params["dec_layers"])  # leading L
    return {"self": self_caches, "cross": cross}


def decode_step(params, cfg: ArchConfig, state, batch):
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["token"], params["embed"], dtype)
    pos = batch["pos"]
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos % MAX_DECODER_POS, 1, 0).astype(
        dtype
    )[None]
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim

    def body(x, scanned):
        lp, self_cache, cross = scanned
        hst = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q = dense(hst, lp["self_attn"]["wq"], lp["self_attn"]["bq"]).reshape(b, 1, h, hd)
        k = dense(hst, lp["self_attn"]["wk"]).reshape(b, 1, h, hd)
        v = dense(hst, lp["self_attn"]["wv"], lp["self_attn"]["bv"]).reshape(b, 1, h, hd)
        self_cache = self_cache.append(k, v)
        attn = decode_attention(q, self_cache)
        x = x + dense(attn.reshape(b, 1, -1), lp["self_attn"]["wo"], lp["self_attn"]["bo"])

        hst = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        qc = dense(hst, lp["cross_attn"]["wq"], lp["cross_attn"]["bq"]).reshape(b, 1, h, hd)
        cattn = plain_attention(qc, cross["k"], cross["v"], causal=False)
        x = x + dense(
            cattn.reshape(b, 1, -1), lp["cross_attn"]["wo"], lp["cross_attn"]["bo"]
        )

        hst = layer_norm(x, lp["ln3"]["scale"], lp["ln3"]["bias"])
        x = x + gelu_ffn(hst, lp["mlp"])
        return x, self_cache

    x, new_self = jax.lax.scan(body, x, (params["dec_layers"], state["self"], state["cross"]))
    x = layer_norm(x, params["dec_final_ln"]["scale"], params["dec_final_ln"]["bias"])
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, {"self": new_self, "cross": state["cross"]}
