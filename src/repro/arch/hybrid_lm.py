"""RecurrentGemma-style hybrid LM: RG-LRU recurrent blocks + local
(sliding-window) attention blocks in a repeating pattern (default 2:1),
each followed by a gated MLP, per Griffin (arXiv:2402.19427).

Layers are grouped into SUPER-BLOCKS of one pattern period so the mixed
block kinds scan with a uniform parameter structure.  38 configured
layers / pattern length 3 -> 13 super-blocks (39 effective layers; noted
in the config file).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.arch.sharding import constrain_act
from repro.nn.attention import KVCache, decode_attention, gqa_attention
from repro.nn.layers import dense, embed, init_swiglu, pad_vocab, rms_norm, rope, swiglu_ffn
from repro.nn.rglru import (
    init_recurrent_block,
    init_recurrent_state,
    recurrent_block,
    recurrent_block_decode,
)

PyTree = Any


def _pattern(cfg: ArchConfig) -> tuple:
    return cfg.block_pattern or ("rglru", "rglru", "attn")


def num_super_blocks(cfg: ArchConfig) -> int:
    return max(1, round(cfg.num_layers / len(_pattern(cfg))))


def _width(cfg: ArchConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_params(key, cfg: ArchConfig) -> PyTree:
    vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    pat = _pattern(cfg)
    nsb = num_super_blocks(cfg)
    keys = jax.random.split(key, nsb + 2)

    def init_super(k):
        ks = jax.random.split(k, 2 * len(pat))
        sub = []
        for i, kind in enumerate(pat):
            if kind == "rglru":
                mix = {"rec": init_recurrent_block(ks[2 * i], d, _width(cfg))}
            else:
                h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
                kk = jax.random.split(ks[2 * i], 4)
                mix = {
                    "wq": jax.random.normal(kk[0], (d, h * hd)) * d**-0.5,
                    "wk": jax.random.normal(kk[1], (d, kh * hd)) * d**-0.5,
                    "wv": jax.random.normal(kk[2], (d, kh * hd)) * d**-0.5,
                    "wo": jax.random.normal(kk[3], (h * hd, d)) * (h * hd) ** -0.5,
                }
            sub.append(
                {
                    "ln1_scale": jnp.zeros((d,)),
                    "ln2_scale": jnp.zeros((d,)),
                    "mix": mix,
                    "mlp": init_swiglu(ks[2 * i + 1], d, cfg.d_ff),
                }
            )
        return sub

    supers = [init_super(keys[i]) for i in range(nsb)]
    # sub-blocks have HETEROGENEOUS param structures (rec vs attn), so the
    # super-block is a tuple of per-kind dicts; stacking is across supers.
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *supers)
    return {
        "embed": jax.random.normal(keys[-1], (vp, d)) * 0.02,
        "blocks": stacked,
        "final_scale": jnp.zeros((d,)),
        "lm_head": jax.random.normal(keys[-2], (d, vp)) * d**-0.5,
    }


def _attn_mix(x, mp, cfg: ArchConfig, positions):
    b, s, d = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(x, mp["wq"]).reshape(b, s, h, hd)
    k = dense(x, mp["wk"]).reshape(b, s, kh, hd)
    v = dense(x, mp["wv"]).reshape(b, s, kh, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    attn = gqa_attention(q, k, v, causal=True, window=cfg.local_attn_window)
    return dense(attn.reshape(b, s, -1), mp["wo"]), (k, v)


def _super_forward(x, sp, cfg: ArchConfig, positions):
    pat = _pattern(cfg)
    kvs = []
    for i, kind in enumerate(pat):
        bp = sp[i]
        h = rms_norm(x, bp["ln1_scale"], cfg.norm_eps)
        if kind == "rglru":
            mix = recurrent_block(h, bp["mix"]["rec"])
        else:
            mix, kv = _attn_mix(h, bp["mix"], cfg, positions)
            kvs.append(kv)
        x = x + mix
        h = rms_norm(x, bp["ln2_scale"], cfg.norm_eps)
        x = x + swiglu_ffn(h, bp["mlp"])
    return x, kvs


def forward(params, cfg: ArchConfig, batch, *, remat: bool = True):
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["tokens"], params["embed"], dtype)
    positions = jnp.arange(x.shape[1])

    def body(x, sp):
        x = constrain_act(x)
        x, _ = _super_forward(x, sp, cfg, positions)
        return constrain_act(x), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    return dense(x, params["lm_head"]), jnp.zeros((2,), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch):
    from repro.arch.common import cross_entropy

    logits, _ = forward(params, cfg, batch)
    return cross_entropy(logits, batch["labels"])


# -- decode ------------------------------------------------------------------


def init_state(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    """Per-super-block state: recurrent states + a ring KV cache bounded
    by the local attention window (long_500k stays O(window))."""
    pat = _pattern(cfg)
    nsb = num_super_blocks(cfg)
    dtype = jnp.dtype(cfg.dtype)
    cap = min(seq_len, cfg.local_attn_window)

    def one():
        state = {}
        for i, kind in enumerate(pat):
            if kind == "rglru":
                state[f"rec{i}"] = init_recurrent_state(batch, _width(cfg), dtype)
            else:
                state[f"kv{i}"] = KVCache.init(
                    batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype
                )
        return state

    states = [one() for _ in range(nsb)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *states)


def decode_step(params, cfg: ArchConfig, states, batch):
    from repro.arch.common import cast_params

    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed(batch["token"], params["embed"], dtype)  # (B,1,d)
    pos = batch["pos"]
    pat = _pattern(cfg)

    def body(x, scanned):
        sp, st = scanned
        new_st = dict(st)
        for i, kind in enumerate(pat):
            bp = sp[i]
            h = rms_norm(x, bp["ln1_scale"], cfg.norm_eps)
            if kind == "rglru":
                out, new_st[f"rec{i}"] = recurrent_block_decode(
                    h[:, 0, :], bp["mix"]["rec"], st[f"rec{i}"]
                )
                mix = out[:, None, :]
            else:
                b = x.shape[0]
                hh, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
                q = dense(h, bp["mix"]["wq"]).reshape(b, 1, hh, hd)
                k = dense(h, bp["mix"]["wk"]).reshape(b, 1, kh, hd)
                v = dense(h, bp["mix"]["wv"]).reshape(b, 1, kh, hd)
                q = rope(q, pos.reshape(1), cfg.rope_theta)
                k = rope(k, pos.reshape(1), cfg.rope_theta)
                cache = st[f"kv{i}"].append(k, v)
                attn = decode_attention(q, cache, window=cfg.local_attn_window)
                new_st[f"kv{i}"] = cache
                mix = dense(attn.reshape(b, 1, -1), bp["mix"]["wo"])
            x = x + mix
            h = rms_norm(x, bp["ln2_scale"], cfg.norm_eps)
            x = x + swiglu_ffn(h, bp["mlp"])
        return x, new_st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    return dense(x, params["lm_head"]), new_states
