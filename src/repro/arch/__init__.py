"""Assigned architecture pool: ``build_arch(name)`` assembles any of the
ten registered transformer-family architectures (dense / MoE / SSM /
hybrid / enc-dec / VLM) from its :class:`repro.config.ArchConfig`, with
partition rules for the production meshes (see ``arch/sharding.py``)."""
from repro.arch.api import Arch, TrainState, build_arch
