from repro.arch.api import Arch, TrainState, build_arch
