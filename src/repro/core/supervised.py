"""Traditional supervised learning on MIXED data (paper Table 3 / §4.4):
all patients' training windows pooled on one "server".  The privacy-free
upper-bound baseline the paper compares FL against.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


def train_supervised(
    model: Model,
    optimizer: Optimizer,
    key,
    x: np.ndarray,
    y: np.ndarray,
    *,
    batch_size: int = 64,
    steps: int = 500,
    loss_fn: Callable | None = None,
    val: tuple[np.ndarray, np.ndarray] | None = None,
    eval_every: int = 50,
):
    """SGD on the pooled window set; returns (params, history)."""
    loss_fn = loss_fn or (lambda p, bx, by: jnp.mean(jnp.square(model.apply(p, bx) - by)))
    x = jnp.asarray(x)
    y = jnp.asarray(y)

    @jax.jit
    def step(p, st, k):
        idx = jax.random.randint(k, (batch_size,), 0, x.shape[0])
        loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, st = optimizer.update(grads, st, p)
        return p, st, loss

    key, k_init = jax.random.split(key)
    params = model.init(k_init)
    st = optimizer.init(params)
    history = []
    best_val, best_params = np.inf, params
    for t in range(steps):
        key, sub = jax.random.split(key)
        params, st, loss = step(params, st, sub)
        rec = {"step": t, "loss": float(loss)}
        if val is not None and (t + 1) % eval_every == 0:
            pv = model.apply(params, jnp.asarray(val[0]))
            vloss = float(jnp.mean(jnp.square(pv - jnp.asarray(val[1]))))
            rec["val_loss"] = vloss
            if vloss < best_val:
                best_val, best_params = vloss, params
        history.append(rec)
    return (best_params if val is not None and np.isfinite(best_val) else params), history
