"""Traditional supervised learning on MIXED data (paper Table 3 / §4.4):
all patients' training windows pooled on one "server".  The privacy-free
upper-bound baseline the paper compares FL against.

Engines: ``engine="scan"`` (default) runs chunks of SGD steps as one
donated ``lax.scan`` dispatched through ``chunked.dispatch_chunk`` —
best-checkpoint tracking moves into the carry as ``jnp.where``
tree-selects so the whole run needs one host sync per chunk —
with optional ``lax.cond``-guarded early stopping
(``early_stop_patience``).  ``engine="loop"`` keeps the original
per-step jit loop as the parity oracle
(``tests/test_baseline_engines.py`` pins the two bitwise-equal).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked
from repro.core.fedavg import DEFAULT_CHUNK
from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


@functools.lru_cache(maxsize=32)
def _build_engine(model: Model, optimizer: Optimizer,
                  loss_fn: Callable | None, batch_size: int):
    """Jitted step/val/chunk fns for a (model, optimizer, loss, batch) tuple.

    ``Model`` and ``Optimizer`` are frozen dataclasses, so the cache key is
    hashable; data arrays are jit *arguments* rather than closure captures,
    which lets repeat ``train_supervised`` calls (e.g. the Table-4 grid run
    back-to-back per engine) reuse the compiled executables instead of
    re-tracing per call.
    """
    if loss_fn is None:
        loss_fn = lambda p, bx, by: jnp.mean(jnp.square(model.apply(p, bx) - by))

    def step_core(p, st, k, x, y):
        idx = jax.random.randint(k, (batch_size,), 0, x.shape[0])
        loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, st = optimizer.update(grads, st, p)
        return p, st, loss

    def val_loss(p, val_x, val_y):
        return jnp.mean(jnp.square(model.apply(p, val_x) - val_y))

    def train_chunk(carry, stop, x, y, val_x, val_y, t0, *,
                    chunk, eval_every, patience):
        def body(c, t):
            key, p, st, best_v, best_p = c
            key, sub = jax.random.split(key)
            p, st, loss = step_core(p, st, sub, x, y)
            v = chunked.boundary_val(
                lambda q: val_loss(q, val_x, val_y), p, t, eval_every)
            # NaN val never improves (comparison is False), matching the
            # loop engine's host-side `vloss < best_val`
            improved = v < best_v
            best_v = jnp.where(improved, v, best_v)
            best_p = jax.tree.map(
                lambda a, b: jnp.where(improved, a, b), p, best_p
            )
            return (key, p, st, best_v, best_p), (loss, v)

        ts = t0 + jnp.arange(chunk, dtype=jnp.int32)
        return chunked.scan_rounds(body, carry, ts, stop, patience=patience)

    return (
        jax.jit(step_core),
        jax.jit(val_loss),
        jax.jit(train_chunk,
                static_argnames=("chunk", "eval_every", "patience"),
                donate_argnums=(0, 1)),
    )


def train_supervised(
    model: Model,
    optimizer: Optimizer,
    key,
    x: np.ndarray,
    y: np.ndarray,
    *,
    batch_size: int = 64,
    steps: int = 500,
    loss_fn: Callable | None = None,
    val: tuple[np.ndarray, np.ndarray] | None = None,
    eval_every: int = 50,
    engine: str = "scan",
    chunk: int | None = None,
    early_stop_patience: int = 0,
):
    """SGD on the pooled window set; returns (params, history).

    With ``val`` set, the returned params are the best-val checkpoint
    (falling back to the final params if no finite val loss was seen).
    """
    if engine not in ("scan", "loop"):
        raise ValueError(f"unknown engine {engine!r}")
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    val_x = val_y = None
    if val is not None:
        val_x, val_y = jnp.asarray(val[0]), jnp.asarray(val[1])
    do_eval = val is not None and bool(eval_every)
    if early_stop_patience and not do_eval:
        raise ValueError("early_stop_patience requires val and eval_every")

    step_jit, val_jit, chunk_jit = _build_engine(
        model, optimizer, loss_fn, batch_size)

    key, k_init = jax.random.split(key)
    params = model.init(k_init)
    st = optimizer.init(params)
    history = []

    if engine == "loop":
        best_val, best_params = np.inf, params
        for t in range(steps):
            key, sub = jax.random.split(key)
            params, st, loss = step_jit(params, st, sub, x, y)
            rec = {"step": t, "loss": float(loss)}
            if do_eval and (t + 1) % eval_every == 0:
                vloss = float(val_jit(params, val_x, val_y))
                rec["val_loss"] = vloss
                if vloss < best_val:
                    best_val, best_params = vloss, params
            history.append(rec)
        return (best_params if val is not None and np.isfinite(best_val) else params), history

    chunk = max(1, min(chunk or DEFAULT_CHUNK, steps))
    # best_params must be distinct buffers from params: the donated carry
    # may not alias the same buffer twice
    carry = (key, params, st, jnp.full((), jnp.inf, jnp.float32),
             jax.tree.map(jnp.copy, params))
    stop = chunked.init_stop() if early_stop_patience else None
    t = 0
    while t < steps:
        c = min(chunk, steps - t)
        carry, stop, (losses, vals) = chunked.dispatch_chunk(
            chunk_jit, carry, stop, x, y,
            val_x if do_eval else None, val_y if do_eval else None,
            jnp.int32(t), chunk=c,
            eval_every=eval_every if do_eval else 0,
            patience=early_stop_patience,
        )
        sr = int(np.asarray(stop.stop_round)) if stop is not None else -1
        stopped = chunked.drain_history(
            history, np.asarray(losses),
            np.asarray(vals) if do_eval else None, t,
            eval_every=eval_every if do_eval else 0, stop_round=sr,
            round_key="step",
        )
        t += c
        if stopped:
            break
    _, params, _, best_v, best_params = carry
    use_best = val is not None and bool(np.isfinite(np.asarray(best_v)))
    return (best_params if use_best else params), history
