"""The paper's primary contribution: asynchronous decentralized
federated learning (GluADFL) — topologies, gossip mixing, wait-free
scheduling, Algorithm 1, the batched scenario-sweep engine, and the
baselines it is compared against (FedAvg, MAML/MetaSGD, supervised)."""
from repro.core.topology import (
    stacked_adjacency,
    mixing_matrix_stacked,
    ring_adjacency,
    cluster_adjacency,
    star_adjacency,
    full_adjacency,
    random_adjacency,
    round_adjacency,
    mixing_matrix,
    spectral_gap,
)
from repro.core.async_sched import (
    bernoulli_active,
    markov_active,
    staleness_update,
    sweep_active_masks,
)
from repro.core.gossip import (
    gossip_mix_tree,
    gossip_mix_kernel,
    gossip_mix_dp_kernel,
    sharded_gossip_mix,
    sharded_gossip_mix_gather,
)
from repro.core.gossip_plan import (
    GossipPlan,
    GossipPlanError,
    MixBackend,
    choose_gossip_impl,
    choose_gossip_repr,
    mix_backends,
    register_mix_backend,
    resolve_gossip_plan,
)
from repro.core.gluadfl import GluADFL, FLState, SweepGrid
from repro.core.fedavg import FedAvg
from repro.core.meta import MAML, MetaSGD
from repro.core.supervised import train_supervised
from repro.core.personalize import (
    personalize,
    personalize_batch,
    personalize_batch_fn,
    personalize_loop,
)
