# The paper's primary contribution: asynchronous decentralized federated
# learning (GluADFL) — topologies, gossip mixing, wait-free scheduling,
# Algorithm 1, and the baselines it is compared against.
from repro.core.topology import (
    ring_adjacency,
    cluster_adjacency,
    star_adjacency,
    full_adjacency,
    random_adjacency,
    round_adjacency,
    mixing_matrix,
    spectral_gap,
)
from repro.core.async_sched import bernoulli_active, markov_active, staleness_update
from repro.core.gossip import (
    gossip_mix_tree,
    gossip_mix_kernel,
    gossip_mix_dp_kernel,
    sharded_gossip_mix,
)
from repro.core.gluadfl import GluADFL, FLState
from repro.core.fedavg import FedAvg
from repro.core.meta import MAML, MetaSGD
from repro.core.supervised import train_supervised
from repro.core.personalize import personalize
