"""FedAvg baseline (McMahan et al., AISTATS'17) — the paper's centralized
FL comparison (star topology, Figure 1b).

Round: server broadcasts w; each participating client runs its local SGD
steps on its own data; server averages client models weighted by their
sample counts.  Vectorized over clients exactly like GluADFL so the two
trainers differ only in communication structure.

Two long-standing bugs fixed here, both pinned by ``tests/test_baselines``:

  * **Inactive clients used to train anyway.**  The vmapped client update
    ran the full local scan for EVERY client and only discarded inactive
    ones at aggregation — wasted work (at the paper's 70%-inactive
    setting ~3.3x the useful FLOPs stayed in the program), and worse, a
    poisoned inactive shard (NaN/Inf data) reached aggregation through
    ``0 * NaN = NaN``.  The scan step is now where-gated on the client's
    activity: active clients keep the identical numerics (the same keys,
    batches and updates as before), inactive clients carry their params/
    opt-state through unchanged and report zero loss — their update is
    inert data flow XLA is free to simplify, and no value they compute
    can reach the aggregate.
  * **Epochs were silently treated as steps.**  ``local_epochs`` used to
    collapse into ``max(cfg.local_steps, local_epochs)``.  It now means
    what it says: ``local_epochs=k`` resolves to
    ``ceil(max(counts) / batch_size) * k`` SGD steps (uniform sampling
    has no epoch boundary, so the step count is the faithful translation
    and the scan length must be one static number for the vmap — the
    LARGEST client's epoch defines it).  ``local_epochs=None`` (default)
    keeps ``cfg.local_steps`` as the literal step count.

Engines: ``train(engine="scan")`` (default) runs whole CHUNKS of rounds
as one donated ``lax.scan`` program dispatched through
``chunked.dispatch_chunk`` — one host sync per chunk instead of one
``float(loss)`` per round — with optional streaming eval
(``val_data`` + ``eval_every``, NaN-sentinel off-boundary) and
``lax.cond``-guarded early stopping (``early_stop_patience``).
``engine="loop"`` keeps the original per-round jit loop; the two are
pinned bitwise-equal by ``tests/test_baseline_engines.py``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import chunked
from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any

# Default rounds-per-compiled-execution for engine="scan"; the driver
# clamps it to the requested round count, so short runs compile once.
DEFAULT_CHUNK = 128


class FedAvg:
    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        cfg: FLConfig,
        *,
        local_epochs: int | None = None,
        loss_fn: Callable | None = None,
    ):
        self.model = model
        self.optimizer = optimizer
        self.cfg = cfg
        if local_epochs is not None and local_epochs < 1:
            raise ValueError(f"local_epochs must be >= 1, got {local_epochs}")
        self.local_epochs = local_epochs
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        # local_steps is static: the scan length is program structure
        self._round_jit = jax.jit(
            self._round, static_argnames=("batch_size", "local_steps")
        )
        self._val_jit = jax.jit(self._val_loss)
        # carry (key, params) and stop state are donated: round t+1's
        # buffers reuse round t's in place across chunk dispatches
        self._chunk_jit = jax.jit(
            self._train_chunk,
            static_argnames=("batch_size", "local_steps", "chunk",
                             "eval_every", "patience"),
            donate_argnums=(0, 1),
        )

    def resolve_local_steps(self, counts, batch_size: int) -> int:
        """The per-round local scan length: ``cfg.local_steps`` verbatim,
        or — with ``local_epochs`` set — ``ceil(max(counts)/batch_size) *
        local_epochs`` (one "epoch" = enough uniform batches to cover the
        largest client's data once; the scan length is shared across the
        vmap, so the largest client defines it)."""
        if self.local_epochs is None:
            return max(1, int(self.cfg.local_steps))
        biggest = max(1, int(max(counts)))
        return math.ceil(biggest / batch_size) * self.local_epochs

    def _client_update(self, key, params, x, y, count, active, batch_size, local_steps):
        opt_state = self.optimizer.init(params)
        keep = active > 0

        def step(carry, k):
            p, st = carry
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(count, 1))
            loss, grads = jax.value_and_grad(self.loss_fn)(p, x[idx], y[idx])
            new_p, new_st = self.optimizer.update(grads, st, p)
            # inactive clients are inert: params/opt-state pass through
            # bitwise and the loss is clean zero — nothing they compute
            # (including NaN from a poisoned shard) escapes the gate
            p = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new_p, p)
            st = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new_st, st)
            return (p, st), jnp.where(keep, loss, 0.0)

        keys = jax.random.split(key, local_steps)
        (p, _), losses = jax.lax.scan(step, (params, opt_state), keys)
        return p, jnp.mean(losses)

    def _round(self, key, params, x, y, counts, *, batch_size: int, local_steps: int):
        n = self.cfg.num_nodes
        key, k_act, k_cli = jax.random.split(key, 3)
        from repro.core.async_sched import bernoulli_active

        active = bernoulli_active(k_act, n, self.cfg.inactive_ratio)
        client_keys = jax.random.split(k_cli, n)
        bcast = jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), params)
        client_params, losses = jax.vmap(
            partial(self._client_update, batch_size=batch_size, local_steps=local_steps)
        )(client_keys, bcast, x, y, counts, active)

        w = active * counts.astype(jnp.float32)
        w = w / jnp.maximum(jnp.sum(w), 1.0)

        def agg(cp, old):
            ws = w.reshape((n,) + (1,) * (cp.ndim - 1))
            return jnp.sum(ws * cp, axis=0) + (1.0 - jnp.sum(w)) * old

        new_params = jax.tree.map(agg, client_params, params)
        loss = jnp.sum(losses * active) / jnp.maximum(jnp.sum(active), 1.0)
        return key, new_params, loss

    def _val_loss(self, params, val_x, val_y):
        pred = self.model.apply(params, val_x)
        return jnp.mean(jnp.square(pred - val_y))

    def _train_chunk(self, carry, stop, x, y, counts, val_x, val_y, t0, *,
                     batch_size: int, local_steps: int, chunk: int,
                     eval_every: int, patience: int):
        """One compiled chunk: scan ``chunk`` rounds from global round
        ``t0`` (traced, so every chunk shares one executable)."""

        def body(c, t):
            key, params = c
            key, params, loss = self._round(
                key, params, x, y, counts,
                batch_size=batch_size, local_steps=local_steps,
            )
            val = chunked.boundary_val(
                lambda p: self._val_loss(p, val_x, val_y), params, t, eval_every
            )
            return (key, params), (loss, val)

        ts = t0 + jnp.arange(chunk, dtype=jnp.int32)
        return chunked.scan_rounds(body, carry, ts, stop, patience=patience)

    def train(self, key, x, y, counts, *, batch_size: int = 64,
              rounds: int | None = None, engine: str = "scan",
              chunk: int | None = None, val_data=None, eval_every: int = 0,
              early_stop_patience: int = 0):
        """Train the federation.  ``engine="scan"`` (default) dispatches
        compiled chunks through ``chunked.dispatch_chunk``;
        ``engine="loop"`` is the original per-round jit loop (kept as the
        parity oracle).  ``val_data=(vx, vy)`` + ``eval_every=k`` records
        ``val_loss`` every k rounds; ``early_stop_patience=p`` (scan
        engine) stops after p consecutive non-improving evals."""
        if engine not in ("scan", "loop"):
            raise ValueError(f"unknown engine {engine!r}")
        rounds = rounds if rounds is not None else self.cfg.rounds
        local_steps = self.resolve_local_steps(counts, batch_size)
        x, y, counts = jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)
        val_x = val_y = None
        if val_data is not None:
            val_x, val_y = (jnp.asarray(v) for v in val_data)
        do_eval = bool(eval_every) and val_data is not None
        if early_stop_patience and not do_eval:
            raise ValueError(
                "early_stop_patience requires val_data and eval_every"
            )
        key, k_init = jax.random.split(key)
        params = self.model.init(k_init)
        history = []
        if engine == "loop":
            for t in range(rounds):
                key, params, loss = self._round_jit(
                    key, params, x, y, counts,
                    batch_size=batch_size, local_steps=local_steps,
                )
                rec = {"round": t, "loss": float(loss)}
                if do_eval and (t + 1) % eval_every == 0:
                    rec["val_loss"] = float(self._val_jit(params, val_x, val_y))
                history.append(rec)
            return params, history
        chunk = max(1, min(chunk or DEFAULT_CHUNK, rounds))
        carry = (key, params)
        stop = chunked.init_stop() if early_stop_patience else None
        t = 0
        while t < rounds:
            c = min(chunk, rounds - t)
            carry, stop, (losses, vals) = chunked.dispatch_chunk(
                self._chunk_jit, carry, stop, x, y, counts, val_x, val_y,
                jnp.int32(t), batch_size=batch_size, local_steps=local_steps,
                chunk=c, eval_every=eval_every if do_eval else 0,
                patience=early_stop_patience,
            )
            sr = int(np.asarray(stop.stop_round)) if stop is not None else -1
            stopped = chunked.drain_history(
                history, np.asarray(losses),
                np.asarray(vals) if do_eval else None, t,
                eval_every=eval_every if do_eval else 0, stop_round=sr,
            )
            t += c
            if stopped:
                break
        _, params = carry
        return params, history
