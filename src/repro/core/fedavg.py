"""FedAvg baseline (McMahan et al., AISTATS'17) — the paper's centralized
FL comparison (star topology, Figure 1b).

Round: server broadcasts w; each participating client runs E local SGD
steps on its own data; server averages client models weighted by their
sample counts.  Vectorized over clients exactly like GluADFL so the two
trainers differ only in communication structure.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import FLConfig
from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


class FedAvg:
    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        cfg: FLConfig,
        *,
        local_epochs: int = 1,
        loss_fn: Callable | None = None,
    ):
        self.model = model
        self.optimizer = optimizer
        self.cfg = cfg
        self.local_steps = max(cfg.local_steps, local_epochs)
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        self._round_jit = jax.jit(self._round, static_argnames=("batch_size",))

    def _client_update(self, key, params, x, y, count, batch_size):
        opt_state = self.optimizer.init(params)

        def step(carry, k):
            p, st = carry
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(count, 1))
            loss, grads = jax.value_and_grad(self.loss_fn)(p, x[idx], y[idx])
            p, st = self.optimizer.update(grads, st, p)
            return (p, st), loss

        keys = jax.random.split(key, self.local_steps)
        (p, _), losses = jax.lax.scan(step, (params, opt_state), keys)
        return p, jnp.mean(losses)

    def _round(self, key, params, x, y, counts, *, batch_size: int):
        n = self.cfg.num_nodes
        key, k_act, k_cli = jax.random.split(key, 3)
        from repro.core.async_sched import bernoulli_active

        active = bernoulli_active(k_act, n, self.cfg.inactive_ratio)
        client_keys = jax.random.split(k_cli, n)
        bcast = jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), params)
        client_params, losses = jax.vmap(
            partial(self._client_update, batch_size=batch_size)
        )(client_keys, bcast, x, y, counts)

        w = active * counts.astype(jnp.float32)
        w = w / jnp.maximum(jnp.sum(w), 1.0)

        def agg(cp, old):
            ws = w.reshape((n,) + (1,) * (cp.ndim - 1))
            return jnp.sum(ws * cp, axis=0) + (1.0 - jnp.sum(w)) * old

        new_params = jax.tree.map(agg, client_params, params)
        loss = jnp.sum(losses * active) / jnp.maximum(jnp.sum(active), 1.0)
        return key, new_params, loss

    def train(self, key, x, y, counts, *, batch_size: int = 64, rounds: int | None = None):
        rounds = rounds if rounds is not None else self.cfg.rounds
        x, y, counts = jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)
        key, k_init = jax.random.split(key)
        params = self.model.init(k_init)
        history = []
        for t in range(rounds):
            key, params, loss = self._round_jit(key, params, x, y, counts, batch_size=batch_size)
            history.append({"round": t, "loss": float(loss)})
        return params, history
