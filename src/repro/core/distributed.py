"""Distributed gossip — the paper's communication step on a device mesh.

At fleet scale the federation axis N is sharded over the mesh's node axis
(single-pod: "data"; multi-pod: "pod"+"data").  The per-round mix
``W <- M_t @ W`` then needs real collectives.  Topology-aware lowering:

  * ring      — each node needs only neighbours i±1: TWO
                ``jax.lax.ppermute`` (collective-permute) hops, cost
                O(D) per link — the cheapest possible gossip;
  * cluster / random / star / full — general row-stochastic mix, with two
                interchangeable schedules behind ``impl=``:
      - ``"allgather"``  the node axis is all-gathered and contracted
                locally (MXU matmul).  For node counts in this paper's
                range (<= 256 shards) a single all-gather beats emulated
                point-to-point sends on TPU ICI (dense collectives are
                what the fabric is good at) — but every device
                materializes the full (N, D) federation, so per-device
                memory does NOT shrink as the mesh grows;
      - ``"psum"``       psum-of-local-contributions: each shard
                contracts its LOCAL rows against its column block of the
                mixing matrix and the partial products are summed with a
                reduce-scatter (``jax.lax.psum_scatter``), which hands
                each device only its own (N/shards, D) output rows.  No
                device ever holds the gathered node axis, so per-device
                working set scales O(N/shards · D) — the multi-host /
                big-model schedule.

All paths are ``shard_map``s so the collective schedule is explicit and
the dry-run can count its bytes.

Representation: the general paths above contract a dense (N, N) mixing
matrix.  ``gossip_repr="sparse"`` (:func:`sharded_gossip_mix_sparse`)
replaces it with ``core.topology.neighbor_table``'s (N, B+1) index/weight
table — same all-gather wire, but the local contraction gathers only the
B+1 referenced rows per output row, dropping per-device flops from
O(N/shards · N · D) to O(N/shards · B · D) and eliminating every (N, N)
operand.

Sweep batching: every shard body below is written dim-relative (ellipsis
einsums, gather/scatter on the second-to-last axis), so the SAME bodies
run under a 2-D ``("grid", "node")`` sweep mesh
(``launch.mesh.make_sweep_mesh``): the grid axis BATCHES — each shard
holds a ``(G/grid, N/node, D)`` block and no collective ever crosses
``"grid"`` — while the node axis keeps carrying the gossip collectives.
:func:`sharded_gossip_mix` accepts grid-stacked ``(G, N, ...)`` inputs
and issues one shard_map with ``P("grid", ...)`` in_specs; the trainer's
swept-sharded path reaches the identical lowering through
``jax.vmap(..., spmd_axis_name="grid")`` over the per-scenario call.

Multi-host: every shard body above indexes the node axis GLOBALLY — the
mixing-matrix row/column blocks are sliced by shard position on the mesh,
not by process — so the same programs lower unchanged when the federation
mesh spans ``jax.distributed`` processes (the all-gather / psum-scatter /
ppermute become cross-host transfers).  What IS per-process is data
residence: :func:`addressable_node_rows` names the contiguous global row
interval whose shards live on the calling process, which is the contract
``launch.multihost.place_federation`` fulfills when it materializes each
host's CGM windows.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map as _shard_map

PyTree = Any

# interchangeable schedules for the general (non-ring) sharded mix.
# "masked" is pairwise-masked secure aggregation (core.secure_agg): on
# the wire it rides the allgather schedule — the mask cancellation term
# is added OUTSIDE the collective by the trainer, so every shard body
# below stays schedule-only.  "gather" is the sparse-only gather-table
# schedule (:func:`sharded_gossip_mix_gather`): a ppermute halo rotation
# that never materializes the gathered (N, D) federation
GOSSIP_IMPLS = ("allgather", "psum", "masked", "gather")

# mixing-operator representations: dense (N, N) matrix vs (N, B+1)
# neighbor table (core.topology.neighbor_table)
GOSSIP_REPRS = ("dense", "sparse")


def ring_gossip_shard(w, active, *, axis: str, n_shards: int, self_w: float = 1.0 / 3.0):
    """shard_map body: ring mix via two collective-permutes.

    ``w``: local block of stacked params, node dim second-to-last with
    ``k = nodes-per-shard`` CONSECUTIVE global rows (1 when fully
    sharded; a leading grid-block dim batches through).  ``active``: the
    matching (..., k, 1) activity-flag block.  Inactive nodes keep their
    row; active nodes average self with *active* ring neighbours.
    ``n_shards`` is static (the ppermute source/target lists need a
    Python int — the caller reads it off the mesh).

    When ``k > 1`` a row's ring neighbours ``i±1`` mostly live INSIDE
    the same block — only the block-boundary rows talk to the adjacent
    shards.  The shifted views are therefore built by an intra-block
    roll stitched to a single-row boundary exchange (``k``'s worth of
    ppermute traffic would be wrong AND wasteful: permuting whole blocks
    would hand row ``i`` the params of row ``i±k``).
    """
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [((i + 1) % n_shards, i) for i in range(n_shards)]

    def ring_shift(v):
        """(v_prev, v_next): row i's view of global rows i-1 and i+1."""
        prev_last = jax.lax.ppermute(v[..., -1:, :], axis, fwd)
        next_first = jax.lax.ppermute(v[..., :1, :], axis, bwd)
        v_prev = jnp.concatenate([prev_last, v[..., :-1, :]], axis=-2)
        v_next = jnp.concatenate([v[..., 1:, :], next_first], axis=-2)
        return v_prev, v_next

    w_prev, w_next = ring_shift(w)
    a_prev, a_next = ring_shift(active)
    num = w + a_prev * w_prev + a_next * w_next
    den = 1.0 + a_prev + a_next
    mixed = num / den
    return jnp.where(active > 0, mixed, w)


def general_gossip_shard(w, mix_rows, *, axis: str):
    """shard_map body: general mix. ``mix_rows`` is this shard's
    (..., N/s, N) rows of the mixing matrix; the node axis of ``w`` is
    all-gathered and contracted against them.  The gather yields
    (..., N, D) with D FULL — the in_specs shard only the node axis, so
    the trailing parameter dim is never split.  Leading dims (the sweep
    mesh's local grid block) batch straight through the ellipsis."""
    w_all = jax.lax.all_gather(w, axis, tiled=True, axis=w.ndim - 2)
    return jnp.einsum(
        "...km,...md->...kd", mix_rows, w_all.astype(jnp.float32)
    ).astype(w.dtype)


def psum_gossip_shard(w, mix_cols, *, axis: str):
    """shard_map body: memory-scaled general mix.  ``mix_cols`` is this
    shard's (..., N, N/s) COLUMN block of the mixing matrix; ``w`` its
    local (..., N/s, D) rows.  The shard's contribution to EVERY output
    row is one local matmul, and the partial products are combined with
    a reduce-scatter that leaves each shard holding only its own rows —
    the node axis is never gathered on any device.  Leading dims (the
    sweep mesh's local grid block) batch straight through.

    fp32 accumulation matches ``general_gossip_shard`` so the two impls
    agree to float tolerance on the same mixing matrix.
    """
    contrib = jnp.einsum("...nm,...md->...nd", mix_cols, w.astype(jnp.float32))
    out = jax.lax.psum_scatter(
        contrib, axis, scatter_dimension=contrib.ndim - 2, tiled=True
    )
    return out.astype(w.dtype)


def sparse_gossip_shard(w, idx, wgt, *, axis: str):
    """shard_map body: neighbor-table (sparse) mix.  ``idx``/``wgt`` are
    this shard's (..., N/s, B+1) table rows; the node axis of ``w`` is
    all-gathered (same wire as ``general_gossip_shard``) but the local
    contraction gathers only the B+1 referenced rows per output row —
    O(N/s · B · D) flops instead of O(N/s · N · D).  Per-device MEMORY
    still holds the gathered (N, D) federation, like the allgather impl;
    the flop (and dense-matrix storage) saving is the point.  Leading
    dims (the sweep mesh's local grid block) batch straight through:
    every index below is dim-relative."""
    w_all = jax.lax.all_gather(w, axis, tiled=True, axis=w.ndim - 2)
    # (..., 1, N, D) gathered rows indexed by (..., k, B+1, 1) -> (..., k, B+1, D)
    rows = jnp.take_along_axis(
        w_all.astype(jnp.float32)[..., None, :, :], idx[..., None], axis=-2
    )
    return jnp.einsum("...kb,...kbd->...kd", wgt, rows).astype(w.dtype)


# wire-schedule registry for the dense sharded mix: impl knob value ->
# (shard body, which mixing-matrix block each shard holds).  "masked"
# deliberately aliases the allgather row entry — secure aggregation is a
# trainer-level wrapper (core.secure_agg adds the exact-zero mask
# cancellation after the mix) and its wire schedule IS the gathered-rows
# one, so both knob values lower to the identical program.  New dense
# schedules register here; sparse-only ones (gather tables) have their
# own entry points.
_DENSE_WIRE_SCHEDULES = {
    "allgather": (general_gossip_shard, "rows"),
    "masked": (general_gossip_shard, "rows"),
    "psum": (psum_gossip_shard, "cols"),
}


def gather_tables_gossip_shard(w, idx, wgt, *, axis: str, n_shards: int):
    """shard_map body: gather-table (sparse, fully sharded) mix.

    ``idx``/``wgt`` are this shard's (..., k, B+1) neighbor-table rows
    (``k = N / n_shards`` CONSECUTIVE global rows, matching the mesh's
    row-block placement) and ``w`` its local (..., k, D) parameter rows.
    Instead of all-gathering the node axis (the ``sparse_gossip_shard``
    wire, per-device O(N · D) memory), the LOCAL block ring-rotates
    through every shard via ``n_shards - 1`` collective-permutes: at step
    ``t`` this shard holds the rows of global shard ``(me + t) %
    n_shards`` and contracts exactly the table entries that reference
    that block — each (row, slot) pair lands in precisely one step, so
    the fp32 step-sums add up to the full B+1 contraction.  Per-device
    working set is two row blocks (resident + in-flight), O(N/shards ·
    D), with no gathered (N, D) spike anywhere — the schedule that takes
    the federation past the 10k-node wall.

    ``n_shards`` is static (ppermute needs Python-int source/target
    pairs); leading dims (the sweep mesh's local grid block) batch
    through — every index below is dim-relative.  One shard degenerates
    to the purely local contraction with zero collectives.
    """
    k = w.shape[-2]
    me = jax.lax.axis_index(axis)
    perm = [(i, (i - 1) % n_shards) for i in range(n_shards)]
    block = w.astype(jnp.float32)
    wgt32 = wgt.astype(jnp.float32)
    acc = jnp.zeros(idx.shape[:-1] + (w.shape[-1],), jnp.float32)
    for t in range(n_shards):
        src = (me + t) % n_shards          # whose global rows `block` holds now
        local = idx - src * k              # (..., k, B+1) block-relative
        in_block = (local >= 0) & (local < k)
        safe = jnp.where(in_block, local, 0)
        # (..., 1, k, D) block rows indexed by (..., k, B+1, 1) -> (..., k, B+1, D)
        rows = jnp.take_along_axis(block[..., None, :, :], safe[..., None], axis=-2)
        acc = acc + jnp.einsum(
            "...kb,...kbd->...kd", jnp.where(in_block, wgt32, 0.0), rows
        )
        if t + 1 < n_shards:
            block = jax.lax.ppermute(block, axis, perm)
    return acc.astype(w.dtype)


def process_row_slice(sharding: NamedSharding, global_shape: tuple) -> slice:
    """The contiguous block of axis-0 GLOBAL rows whose shards live on
    THIS process's devices.  Federation meshes order devices by process,
    so each host's rows are one contiguous [lo, hi) interval; anything
    else (interleaved placement) is a bug worth failing loudly on."""
    idx = sharding.addressable_devices_indices_map(tuple(global_shape))
    if not idx:
        raise ValueError(
            f"process {jax.process_index()} owns no shards of the "
            f"federation mesh (width {sharding.mesh.shape}) — pick a node "
            f"count whose mesh width spreads over every process"
        )
    rows = sorted(
        {(s[0].start or 0, s[0].stop if s[0].stop is not None else global_shape[0])
         for s in idx.values()}
    )
    lo, hi = rows[0][0], rows[-1][1]
    covered = sum(b - a for a, b in rows)
    if covered != hi - lo:
        raise ValueError(f"non-contiguous per-process rows: {rows}")
    return slice(lo, hi)


def addressable_node_rows(mesh: Mesh, num_nodes: int) -> slice:
    """The contiguous [lo, hi) interval of GLOBAL federation rows whose
    shards are addressable from this process under ``mesh``'s first
    (node) axis.  Single-process meshes own everything; multi-host
    meshes split the interval at process boundaries (device order is by
    process, so each host's rows are contiguous — asserted by
    :func:`process_row_slice`)."""
    sharding = NamedSharding(mesh, P(mesh.axis_names[0]))
    return process_row_slice(sharding, (num_nodes,))


_FED_MESH_CACHE: dict = {}


def _default_federation_mesh(num_nodes: int) -> Mesh:
    """Mesh for ``sharded_gossip_mix`` when the caller passes none —
    built once per (N, device-count) pair (mesh construction at trace
    time is cheap but not free inside a scanned round body)."""
    key = (num_nodes, jax.device_count())
    if key not in _FED_MESH_CACHE:
        from repro.launch.mesh import make_federation_mesh

        _FED_MESH_CACHE[key] = make_federation_mesh(num_nodes)
    return _FED_MESH_CACHE[key]


def sharded_gossip_mix(
    stacked_params: PyTree,
    mix: jnp.ndarray,
    active: jnp.ndarray | None = None,
    *,
    mesh: Mesh | None = None,
    node_axes: tuple[str, ...] | None = None,
    grid_axis: str | None = None,
    impl: str = "allgather",
) -> PyTree:
    """Device-parallel gossip mix — drop-in peer of ``gossip_mix_tree`` /
    ``gossip_mix_kernel`` (same ``(stacked, mix[, active])`` signature).

    The federation axis N is sharded over the mesh's node axes: each
    device holds N/devices rows of every leaf plus a block of the (N, N)
    mixing matrix.  ``impl`` selects the collective schedule:

      * ``"allgather"`` — gather the node axis once per leaf and contract
        locally against this shard's mix ROWS (``general_gossip_shard``);
        cheapest latency on ICI but per-device memory stays O(N · D);
      * ``"psum"``      — contract local rows against this shard's mix
        COLUMNS and reduce-scatter the partial products
        (``psum_gossip_shard``); per-device memory O(N/shards · D);
      * ``"masked"``    — pairwise-masked secure aggregation: the wire
        schedule is allgather, and the trainer adds the mask
        cancellation term (``core.secure_agg``) outside this collective.

    With no ``mesh`` a cached 1-axis ``("node",)`` mesh over the largest
    device count dividing N is used (``launch.mesh.make_federation_mesh``).

    Grid batching (the sweep engine's second engine): pass grid-stacked
    leaves ``(G, N, ...)``, per-scenario mixing matrices ``(G, N, N)``
    (+ ``(G, N)`` active masks) and a 2-D ``("grid", "node")`` mesh from
    ``launch.mesh.make_sweep_mesh`` — auto-detected when the mesh has a
    ``"grid"`` axis and ``mix`` is 3-D, or forced via ``grid_axis=``.
    The single shard_map then carries ``P("grid", ...)`` in_specs: the
    grid axis purely BATCHES (no collective ever crosses it) while the
    node-axis collectives run per scenario block.  Scenario/state shape
    mismatches fail here at trace time (leading-dim assertion below)
    instead of inside the collective.

    Jit/scan friendly: mesh resolution happens at trace time, so the
    whole FL round — including this collective — compiles into one
    program (the trainer's ``mixer="sharded"`` path).
    """
    if impl not in _DENSE_WIRE_SCHEDULES:
        raise ValueError(
            f"impl {impl!r} not in {tuple(_DENSE_WIRE_SCHEDULES)} "
            f"(dense wire schedules; 'gather' is sparse-only — "
            f"sharded_gossip_mix_gather)"
        )
    shard_body, mix_block = _DENSE_WIRE_SCHEDULES[impl]
    if mesh is None:
        mesh = _default_federation_mesh(mix.shape[0])
    axes = node_axes or tuple(
        a for a in mesh.axis_names if a not in ("model", "grid")
    )
    axis = axes if len(axes) > 1 else axes[0]
    if grid_axis is None and mix.ndim == 3 and "grid" in mesh.axis_names:
        grid_axis = "grid"
    g = (grid_axis,) if grid_axis else ()
    lead = 1 + len(g)  # stacked leading dims: [grid,] node
    if mix.ndim != 1 + lead:
        raise ValueError(
            f"mixing matrix must be {1 + lead}-D "
            f"({'(G, N, N)' if g else '(N, N)'}) for grid_axis={grid_axis!r}, "
            f"got shape {mix.shape}"
        )

    def leaf(l):
        flat = l.reshape(l.shape[:lead] + (-1,))
        if flat.shape[0] != mix.shape[0]:
            # fail at TRACE time with the shapes in hand — a mismatched
            # scenario grid inside the collective is far harder to read
            raise ValueError(
                f"stacked leading dim {flat.shape[0]} != mixing-matrix "
                f"leading dim {mix.shape[0]} (leaf {l.shape}, mix {mix.shape})"
            )
        # the schedule's declared matrix blocking picks the mix in_spec:
        # "rows" shards the leading matrix dim (each shard holds its
        # output rows), "cols" the trailing one (each shard holds the
        # column block its local params multiply)
        mix_spec = P(*g, None, axes) if mix_block == "cols" else P(*g, axes, None)
        out = _shard_map(
            partial(shard_body, axis=axis),
            mesh=mesh,
            in_specs=(P(*g, axes), mix_spec),
            out_specs=P(*g, axes),
        )(flat, mix)
        if active is not None:
            # jnp.where, not arithmetic blending: inactive rows stay
            # bit-exact even if the gathered params carry NaN/Inf
            a = (active > 0).reshape(active.shape + (1,) * (flat.ndim - active.ndim))
            out = jnp.where(a, out, flat.astype(out.dtype))
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(leaf, stacked_params)


def sharded_gossip_mix_sparse(
    stacked_params: PyTree,
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    active: jnp.ndarray | None = None,
    *,
    mesh: Mesh | None = None,
    node_axes: tuple[str, ...] | None = None,
    grid_axis: str | None = None,
) -> PyTree:
    """Sharded gossip from a neighbor table — ``gossip_repr="sparse"``
    sibling of :func:`sharded_gossip_mix` (same contract, the (N, N)
    matrix replaced by ``core.topology.neighbor_table``'s (N, B+1)
    ``(idx, wgt)``).

    Each device holds N/shards table rows next to its parameter rows;
    the node axis is all-gathered once per leaf (the existing collective)
    and each local row gathers just its B+1 referenced rows
    (``sparse_gossip_shard``) — per-device cost O(N/shards · B · D)
    instead of the dense O(N/shards · N · D), with no (N, N) operand
    anywhere.  The gathered (N, D) temp remains, as in the dense
    allgather impl; federations too big for it should shrink D per call
    (leaf-wise mixing already does) before reaching for psum-style
    scatters.

    Grid batching works exactly as in the dense sibling: grid-stacked
    ``(G, N, B+1)`` tables + a ``("grid", "node")`` mesh are auto-detected
    (table 3-D + ``"grid"`` axis present) or forced via ``grid_axis=``.
    """
    if mesh is None:
        mesh = _default_federation_mesh(idx.shape[-2])
    axes = node_axes or tuple(
        a for a in mesh.axis_names if a not in ("model", "grid")
    )
    axis = axes if len(axes) > 1 else axes[0]
    if grid_axis is None and idx.ndim == 3 and "grid" in mesh.axis_names:
        grid_axis = "grid"
    g = (grid_axis,) if grid_axis else ()
    lead = 1 + len(g)  # stacked leading dims: [grid,] node
    if idx.ndim != 1 + lead:
        raise ValueError(
            f"neighbor table must be {1 + lead}-D "
            f"({'(G, N, B+1)' if g else '(N, B+1)'}) for grid_axis={grid_axis!r}, "
            f"got shape {idx.shape}"
        )
    if idx.shape != wgt.shape:
        raise ValueError(f"idx {idx.shape} != wgt {wgt.shape}")

    def leaf(l):
        flat = l.reshape(l.shape[:lead] + (-1,))
        if flat.shape[0] != idx.shape[0]:
            raise ValueError(
                f"stacked leading dim {flat.shape[0]} != neighbor-table "
                f"leading dim {idx.shape[0]} (leaf {l.shape}, idx {idx.shape})"
            )
        # check_vma=False: under the swept engine's
        # ``vmap(..., spmd_axis_name="grid")`` the gather's index
        # clamping compares grid-varying indices against replicated
        # bounds, which the replication checker rejects even though the
        # grid axis purely batches here (no collective crosses it)
        out = _shard_map(
            partial(sparse_gossip_shard, axis=axis),
            mesh=mesh,
            in_specs=(P(*g, axes), P(*g, axes, None), P(*g, axes, None)),
            out_specs=P(*g, axes),
            check_vma=False,
        )(flat, idx.astype(jnp.int32), wgt.astype(jnp.float32))
        if active is not None:
            a = (active > 0).reshape(active.shape + (1,) * (flat.ndim - active.ndim))
            out = jnp.where(a, out, flat.astype(out.dtype))
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(leaf, stacked_params)


def sharded_gossip_mix_gather(
    stacked_params: PyTree,
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    active: jnp.ndarray | None = None,
    *,
    mesh: Mesh | None = None,
    node_axes: tuple[str, ...] | None = None,
    grid_axis: str | None = None,
) -> PyTree:
    """Fully sharded gossip from a neighbor table — ``gossip_impl=
    "gather"`` (backend ``sharded_gather_tables``).  Same call contract
    as :func:`sharded_gossip_mix_sparse`, different wire: the (N, B+1)
    tables AND the node rows stay sharded over the node mesh axes and
    the local row block ring-rotates via ``ppermute``
    (:func:`gather_tables_gossip_shard`), so only referenced remote rows
    are ever read and NO device materializes the gathered (N, D)
    federation — per-device memory O(N/shards · D) flat in N/shards,
    the population-scale (100k-node) schedule.

    Requires the node count to divide evenly over the node-axis width
    (the same divisibility ``launch.mesh.make_federation_mesh``
    guarantees).  Grid batching works as in the sparse sibling:
    grid-stacked ``(G, N, B+1)`` tables + a ``("grid", "node")`` mesh
    are auto-detected or forced via ``grid_axis=``.
    """
    if mesh is None:
        mesh = _default_federation_mesh(idx.shape[-2])
    axes = node_axes or tuple(
        a for a in mesh.axis_names if a not in ("model", "grid")
    )
    axis = axes if len(axes) > 1 else axes[0]
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if grid_axis is None and idx.ndim == 3 and "grid" in mesh.axis_names:
        grid_axis = "grid"
    g = (grid_axis,) if grid_axis else ()
    lead = 1 + len(g)  # stacked leading dims: [grid,] node
    if idx.ndim != 1 + lead:
        raise ValueError(
            f"neighbor table must be {1 + lead}-D "
            f"({'(G, N, B+1)' if g else '(N, B+1)'}) for grid_axis={grid_axis!r}, "
            f"got shape {idx.shape}"
        )
    if idx.shape != wgt.shape:
        raise ValueError(f"idx {idx.shape} != wgt {wgt.shape}")
    n = idx.shape[-2]
    if n % n_shards:
        raise ValueError(
            f"gather-table gossip needs num_nodes divisible by the node-"
            f"axis width, got N={n} over {n_shards} shards"
        )

    def leaf(l):
        flat = l.reshape(l.shape[:lead] + (-1,))
        if flat.shape[0] != idx.shape[0]:
            raise ValueError(
                f"stacked leading dim {flat.shape[0]} != neighbor-table "
                f"leading dim {idx.shape[0]} (leaf {l.shape}, idx {idx.shape})"
            )
        # check_vma=False for the same reason as the sparse sibling: the
        # in-block index clamp compares grid-varying indices against
        # replicated bounds under the swept engine's spmd vmap
        out = _shard_map(
            partial(gather_tables_gossip_shard, axis=axis, n_shards=n_shards),
            mesh=mesh,
            in_specs=(P(*g, axes), P(*g, axes, None), P(*g, axes, None)),
            out_specs=P(*g, axes),
            check_vma=False,
        )(flat, idx.astype(jnp.int32), wgt.astype(jnp.float32))
        if active is not None:
            # jnp.where keeps inactive rows bit-exact, matching every
            # other sparse mix path
            a = (active > 0).reshape(active.shape + (1,) * (flat.ndim - active.ndim))
            out = jnp.where(a, out, flat.astype(out.dtype))
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(leaf, stacked_params)


def make_sharded_gossip(
    mesh: Mesh,
    node_axes: tuple[str, ...],
    topology: str,
    *,
    gossip_impl: str = "allgather",
):
    """Returns gossip_fn(stacked_tree, mix or active) running under ``mesh``.

    The stacked node axis is sharded over ``node_axes`` (e.g. ("data",) or
    ("pod", "data")).  Parameters' trailing dims stay as they were.
    ``gossip_impl`` selects the general-topology collective schedule
    (see :func:`sharded_gossip_mix`); the ring fast path ignores it
    (two ppermutes are already O(D) per link).
    """
    axis = node_axes if len(node_axes) > 1 else node_axes[0]
    n_shards = 1
    for a in node_axes:
        n_shards *= mesh.shape[a]

    if topology == "ring":

        def gossip(stacked: PyTree, active: jnp.ndarray) -> PyTree:
            def leaf(l):
                flat = l.reshape(l.shape[0], -1)
                out = _shard_map(
                    partial(ring_gossip_shard, axis=axis, n_shards=n_shards),
                    mesh=mesh,
                    in_specs=(P(node_axes), P(node_axes)),
                    out_specs=P(node_axes),
                )(flat, active.reshape(-1, 1))
                return out.reshape(l.shape).astype(l.dtype)

            return jax.tree.map(leaf, stacked)

        return gossip

    def gossip(stacked: PyTree, mix: jnp.ndarray) -> PyTree:
        return sharded_gossip_mix(
            stacked, mix, mesh=mesh, node_axes=node_axes, impl=gossip_impl
        )

    return gossip
