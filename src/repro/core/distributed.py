"""Distributed gossip — the paper's communication step on a device mesh.

At fleet scale the federation axis N is sharded over the mesh's node axis
(single-pod: "data"; multi-pod: "pod"+"data").  The per-round mix
``W <- M_t @ W`` then needs real collectives.  Topology-aware lowering:

  * ring      — each node needs only neighbours i±1: TWO
                ``jax.lax.ppermute`` (collective-permute) hops, cost
                O(D) per link — the cheapest possible gossip;
  * cluster / random / star / full — general row-stochastic mix: the node
                axis is all-gathered and contracted locally (MXU matmul).
                For node counts in this paper's range (<= 256 shards) a
                single all-gather beats emulated point-to-point sends on
                TPU ICI (dense collectives are what the fabric is good at).

Both paths are ``shard_map``s so the collective schedule is explicit and
the dry-run can count its bytes.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def ring_gossip_shard(w, active, *, axis: str, self_w: float = 1.0 / 3.0):
    """shard_map body: ring mix via two collective-permutes.

    ``w``: local block of stacked params, leading dim = nodes-per-shard
    (1 when fully sharded).  ``active``: per-shard (1,) activity flag
    block.  Inactive nodes keep their row; active nodes average self with
    *active* ring neighbours.
    """
    n_shards = jax.lax.axis_size(axis)
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [((i + 1) % n_shards, i) for i in range(n_shards)]
    w_prev = jax.lax.ppermute(w, axis, fwd)
    w_next = jax.lax.ppermute(w, axis, bwd)
    a_prev = jax.lax.ppermute(active, axis, fwd)
    a_next = jax.lax.ppermute(active, axis, bwd)
    num = w + a_prev * w_prev + a_next * w_next
    den = 1.0 + a_prev + a_next
    mixed = num / den
    return jnp.where(active > 0, mixed, w)


def general_gossip_shard(w, mix_rows, *, axis: str):
    """shard_map body: general mix. ``mix_rows`` is this shard's rows of
    the (N, N) mixing matrix; the node axis of ``w`` is all-gathered and
    contracted against them."""
    w_all = jax.lax.all_gather(w, axis, tiled=True)  # (N, D_local)
    return jnp.einsum("km,md->kd", mix_rows, w_all.astype(jnp.float32)).astype(w.dtype)


def make_sharded_gossip(mesh: Mesh, node_axes: tuple[str, ...], topology: str):
    """Returns gossip_fn(stacked_tree, mix or active) running under ``mesh``.

    The stacked node axis is sharded over ``node_axes`` (e.g. ("data",) or
    ("pod", "data")).  Parameters' trailing dims stay as they were.
    """
    axis = node_axes if len(node_axes) > 1 else node_axes[0]

    if topology == "ring":

        def gossip(stacked: PyTree, active: jnp.ndarray) -> PyTree:
            def leaf(l):
                flat = l.reshape(l.shape[0], -1)
                out = jax.shard_map(
                    partial(ring_gossip_shard, axis=axis),
                    mesh=mesh,
                    in_specs=(P(node_axes), P(node_axes)),
                    out_specs=P(node_axes),
                )(flat, active.reshape(-1, 1))
                return out.reshape(l.shape).astype(l.dtype)

            return jax.tree.map(leaf, stacked)

        return gossip

    def gossip(stacked: PyTree, mix: jnp.ndarray) -> PyTree:
        def leaf(l):
            flat = l.reshape(l.shape[0], -1)
            out = jax.shard_map(
                partial(general_gossip_shard, axis=axis),
                mesh=mesh,
                in_specs=(P(node_axes), P(node_axes)),
                out_specs=P(node_axes),
            )(flat, mix)
            return out.reshape(l.shape).astype(l.dtype)

        return jax.tree.map(leaf, stacked)

    return gossip
