"""Distributed gossip — the paper's communication step on a device mesh.

At fleet scale the federation axis N is sharded over the mesh's node axis
(single-pod: "data"; multi-pod: "pod"+"data").  The per-round mix
``W <- M_t @ W`` then needs real collectives.  Topology-aware lowering:

  * ring      — each node needs only neighbours i±1: TWO
                ``jax.lax.ppermute`` (collective-permute) hops, cost
                O(D) per link — the cheapest possible gossip;
  * cluster / random / star / full — general row-stochastic mix, with two
                interchangeable schedules behind ``impl=``:
      - ``"allgather"``  the node axis is all-gathered and contracted
                locally (MXU matmul).  For node counts in this paper's
                range (<= 256 shards) a single all-gather beats emulated
                point-to-point sends on TPU ICI (dense collectives are
                what the fabric is good at) — but every device
                materializes the full (N, D) federation, so per-device
                memory does NOT shrink as the mesh grows;
      - ``"psum"``       psum-of-local-contributions: each shard
                contracts its LOCAL rows against its column block of the
                mixing matrix and the partial products are summed with a
                reduce-scatter (``jax.lax.psum_scatter``), which hands
                each device only its own (N/shards, D) output rows.  No
                device ever holds the gathered node axis, so per-device
                working set scales O(N/shards · D) — the multi-host /
                big-model schedule.

All paths are ``shard_map``s so the collective schedule is explicit and
the dry-run can count its bytes.

Multi-host: every shard body above indexes the node axis GLOBALLY — the
mixing-matrix row/column blocks are sliced by shard position on the mesh,
not by process — so the same programs lower unchanged when the federation
mesh spans ``jax.distributed`` processes (the all-gather / psum-scatter /
ppermute become cross-host transfers).  What IS per-process is data
residence: :func:`addressable_node_rows` names the contiguous global row
interval whose shards live on the calling process, which is the contract
``launch.multihost.place_federation`` fulfills when it materializes each
host's CGM windows.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map as _shard_map

PyTree = Any

# interchangeable schedules for the general (non-ring) sharded mix
GOSSIP_IMPLS = ("allgather", "psum")


def ring_gossip_shard(w, active, *, axis: str, n_shards: int, self_w: float = 1.0 / 3.0):
    """shard_map body: ring mix via two collective-permutes.

    ``w``: local block of stacked params, leading dim = nodes-per-shard
    (1 when fully sharded).  ``active``: per-shard (1,) activity flag
    block.  Inactive nodes keep their row; active nodes average self with
    *active* ring neighbours.  ``n_shards`` is static (the ppermute
    source/target lists need a Python int — the caller reads it off the
    mesh).
    """
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [((i + 1) % n_shards, i) for i in range(n_shards)]
    w_prev = jax.lax.ppermute(w, axis, fwd)
    w_next = jax.lax.ppermute(w, axis, bwd)
    a_prev = jax.lax.ppermute(active, axis, fwd)
    a_next = jax.lax.ppermute(active, axis, bwd)
    num = w + a_prev * w_prev + a_next * w_next
    den = 1.0 + a_prev + a_next
    mixed = num / den
    return jnp.where(active > 0, mixed, w)


def general_gossip_shard(w, mix_rows, *, axis: str):
    """shard_map body: general mix. ``mix_rows`` is this shard's rows of
    the (N, N) mixing matrix; the node axis of ``w`` is all-gathered and
    contracted against them."""
    w_all = jax.lax.all_gather(w, axis, tiled=True)  # (N, D_local)
    return jnp.einsum("km,md->kd", mix_rows, w_all.astype(jnp.float32)).astype(w.dtype)


def psum_gossip_shard(w, mix_cols, *, axis: str):
    """shard_map body: memory-scaled general mix.  ``mix_cols`` is this
    shard's (N, N/s) COLUMN block of the mixing matrix; ``w`` its local
    (N/s, D) rows.  The shard's contribution to EVERY output row is one
    local matmul, and the partial products are combined with a
    reduce-scatter that leaves each shard holding only its own rows —
    the node axis is never gathered on any device.

    fp32 accumulation matches ``general_gossip_shard`` so the two impls
    agree to float tolerance on the same mixing matrix.
    """
    contrib = jnp.einsum("nm,md->nd", mix_cols, w.astype(jnp.float32))
    out = jax.lax.psum_scatter(contrib, axis, scatter_dimension=0, tiled=True)
    return out.astype(w.dtype)


def process_row_slice(sharding: NamedSharding, global_shape: tuple) -> slice:
    """The contiguous block of axis-0 GLOBAL rows whose shards live on
    THIS process's devices.  Federation meshes order devices by process,
    so each host's rows are one contiguous [lo, hi) interval; anything
    else (interleaved placement) is a bug worth failing loudly on."""
    idx = sharding.addressable_devices_indices_map(tuple(global_shape))
    if not idx:
        raise ValueError(
            f"process {jax.process_index()} owns no shards of the "
            f"federation mesh (width {sharding.mesh.shape}) — pick a node "
            f"count whose mesh width spreads over every process"
        )
    rows = sorted(
        {(s[0].start or 0, s[0].stop if s[0].stop is not None else global_shape[0])
         for s in idx.values()}
    )
    lo, hi = rows[0][0], rows[-1][1]
    covered = sum(b - a for a, b in rows)
    if covered != hi - lo:
        raise ValueError(f"non-contiguous per-process rows: {rows}")
    return slice(lo, hi)


def addressable_node_rows(mesh: Mesh, num_nodes: int) -> slice:
    """The contiguous [lo, hi) interval of GLOBAL federation rows whose
    shards are addressable from this process under ``mesh``'s first
    (node) axis.  Single-process meshes own everything; multi-host
    meshes split the interval at process boundaries (device order is by
    process, so each host's rows are contiguous — asserted by
    :func:`process_row_slice`)."""
    sharding = NamedSharding(mesh, P(mesh.axis_names[0]))
    return process_row_slice(sharding, (num_nodes,))


_FED_MESH_CACHE: dict = {}


def _default_federation_mesh(num_nodes: int) -> Mesh:
    """Mesh for ``sharded_gossip_mix`` when the caller passes none —
    built once per (N, device-count) pair (mesh construction at trace
    time is cheap but not free inside a scanned round body)."""
    key = (num_nodes, jax.device_count())
    if key not in _FED_MESH_CACHE:
        from repro.launch.mesh import make_federation_mesh

        _FED_MESH_CACHE[key] = make_federation_mesh(num_nodes)
    return _FED_MESH_CACHE[key]


def sharded_gossip_mix(
    stacked_params: PyTree,
    mix: jnp.ndarray,
    active: jnp.ndarray | None = None,
    *,
    mesh: Mesh | None = None,
    node_axes: tuple[str, ...] | None = None,
    impl: str = "allgather",
) -> PyTree:
    """Device-parallel gossip mix — drop-in peer of ``gossip_mix_tree`` /
    ``gossip_mix_kernel`` (same ``(stacked, mix[, active])`` signature).

    The federation axis N is sharded over the mesh's node axes: each
    device holds N/devices rows of every leaf plus a block of the (N, N)
    mixing matrix.  ``impl`` selects the collective schedule:

      * ``"allgather"`` — gather the node axis once per leaf and contract
        locally against this shard's mix ROWS (``general_gossip_shard``);
        cheapest latency on ICI but per-device memory stays O(N · D);
      * ``"psum"``      — contract local rows against this shard's mix
        COLUMNS and reduce-scatter the partial products
        (``psum_gossip_shard``); per-device memory O(N/shards · D).

    With no ``mesh`` a cached 1-axis ``("node",)`` mesh over the largest
    device count dividing N is used (``launch.mesh.make_federation_mesh``).

    Jit/scan friendly: mesh resolution happens at trace time, so the
    whole FL round — including this collective — compiles into one
    program (the trainer's ``mixer="sharded"`` path).
    """
    if impl not in GOSSIP_IMPLS:
        raise ValueError(f"impl {impl!r} not in {GOSSIP_IMPLS}")
    if mesh is None:
        mesh = _default_federation_mesh(mix.shape[0])
    axes = node_axes or tuple(a for a in mesh.axis_names if a != "model")
    axis = axes if len(axes) > 1 else axes[0]

    def leaf(l):
        flat = l.reshape(l.shape[0], -1)
        if impl == "psum":
            out = _shard_map(
                partial(psum_gossip_shard, axis=axis),
                mesh=mesh,
                in_specs=(P(axes), P(None, axes)),  # rows | COLUMN block
                out_specs=P(axes),
            )(flat, mix)
        else:
            out = _shard_map(
                partial(general_gossip_shard, axis=axis),
                mesh=mesh,
                in_specs=(P(axes), P(axes)),
                out_specs=P(axes),
            )(flat, mix)
        if active is not None:
            # jnp.where, not arithmetic blending: inactive rows stay
            # bit-exact even if the gathered params carry NaN/Inf
            a = (active > 0).reshape((-1,) + (1,) * (flat.ndim - 1))
            out = jnp.where(a, out, flat.astype(out.dtype))
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(leaf, stacked_params)


def make_sharded_gossip(
    mesh: Mesh,
    node_axes: tuple[str, ...],
    topology: str,
    *,
    gossip_impl: str = "allgather",
):
    """Returns gossip_fn(stacked_tree, mix or active) running under ``mesh``.

    The stacked node axis is sharded over ``node_axes`` (e.g. ("data",) or
    ("pod", "data")).  Parameters' trailing dims stay as they were.
    ``gossip_impl`` selects the general-topology collective schedule
    (see :func:`sharded_gossip_mix`); the ring fast path ignores it
    (two ppermutes are already O(D) per link).
    """
    axis = node_axes if len(node_axes) > 1 else node_axes[0]
    n_shards = 1
    for a in node_axes:
        n_shards *= mesh.shape[a]

    if topology == "ring":

        def gossip(stacked: PyTree, active: jnp.ndarray) -> PyTree:
            def leaf(l):
                flat = l.reshape(l.shape[0], -1)
                out = _shard_map(
                    partial(ring_gossip_shard, axis=axis, n_shards=n_shards),
                    mesh=mesh,
                    in_specs=(P(node_axes), P(node_axes)),
                    out_specs=P(node_axes),
                )(flat, active.reshape(-1, 1))
                return out.reshape(l.shape).astype(l.dtype)

            return jax.tree.map(leaf, stacked)

        return gossip

    def gossip(stacked: PyTree, mix: jnp.ndarray) -> PyTree:
        return sharded_gossip_mix(
            stacked, mix, mesh=mesh, node_axes=node_axes, impl=gossip_impl
        )

    return gossip
