"""Distributed gossip — the paper's communication step on a device mesh.

At fleet scale the federation axis N is sharded over the mesh's node axis
(single-pod: "data"; multi-pod: "pod"+"data").  The per-round mix
``W <- M_t @ W`` then needs real collectives.  Topology-aware lowering:

  * ring      — each node needs only neighbours i±1: TWO
                ``jax.lax.ppermute`` (collective-permute) hops, cost
                O(D) per link — the cheapest possible gossip;
  * cluster / random / star / full — general row-stochastic mix: the node
                axis is all-gathered and contracted locally (MXU matmul).
                For node counts in this paper's range (<= 256 shards) a
                single all-gather beats emulated point-to-point sends on
                TPU ICI (dense collectives are what the fabric is good at).

Both paths are ``shard_map``s so the collective schedule is explicit and
the dry-run can count its bytes.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map as _shard_map

PyTree = Any


def ring_gossip_shard(w, active, *, axis: str, n_shards: int, self_w: float = 1.0 / 3.0):
    """shard_map body: ring mix via two collective-permutes.

    ``w``: local block of stacked params, leading dim = nodes-per-shard
    (1 when fully sharded).  ``active``: per-shard (1,) activity flag
    block.  Inactive nodes keep their row; active nodes average self with
    *active* ring neighbours.  ``n_shards`` is static (the ppermute
    source/target lists need a Python int — the caller reads it off the
    mesh).
    """
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [((i + 1) % n_shards, i) for i in range(n_shards)]
    w_prev = jax.lax.ppermute(w, axis, fwd)
    w_next = jax.lax.ppermute(w, axis, bwd)
    a_prev = jax.lax.ppermute(active, axis, fwd)
    a_next = jax.lax.ppermute(active, axis, bwd)
    num = w + a_prev * w_prev + a_next * w_next
    den = 1.0 + a_prev + a_next
    mixed = num / den
    return jnp.where(active > 0, mixed, w)


def general_gossip_shard(w, mix_rows, *, axis: str):
    """shard_map body: general mix. ``mix_rows`` is this shard's rows of
    the (N, N) mixing matrix; the node axis of ``w`` is all-gathered and
    contracted against them."""
    w_all = jax.lax.all_gather(w, axis, tiled=True)  # (N, D_local)
    return jnp.einsum("km,md->kd", mix_rows, w_all.astype(jnp.float32)).astype(w.dtype)


_FED_MESH_CACHE: dict = {}


def _default_federation_mesh(num_nodes: int) -> Mesh:
    """Mesh for ``sharded_gossip_mix`` when the caller passes none —
    built once per (N, device-count) pair (mesh construction at trace
    time is cheap but not free inside a scanned round body)."""
    key = (num_nodes, jax.device_count())
    if key not in _FED_MESH_CACHE:
        from repro.launch.mesh import make_federation_mesh

        _FED_MESH_CACHE[key] = make_federation_mesh(num_nodes)
    return _FED_MESH_CACHE[key]


def sharded_gossip_mix(
    stacked_params: PyTree,
    mix: jnp.ndarray,
    active: jnp.ndarray | None = None,
    *,
    mesh: Mesh | None = None,
    node_axes: tuple[str, ...] | None = None,
) -> PyTree:
    """Device-parallel gossip mix — drop-in peer of ``gossip_mix_tree`` /
    ``gossip_mix_kernel`` (same ``(stacked, mix[, active])`` signature).

    The federation axis N is sharded over the mesh's node axes: each
    device holds N/devices rows of every leaf plus the matching rows of
    the (N, N) mixing matrix, all-gathers the node axis once per leaf,
    and contracts locally (``general_gossip_shard``).  With no ``mesh``
    a cached 1-axis ``("node",)`` mesh over the largest device count
    dividing N is used (``launch.mesh.make_federation_mesh``).

    Jit/scan friendly: mesh resolution happens at trace time, so the
    whole FL round — including this collective — compiles into one
    program (the trainer's ``mixer="sharded"`` path).
    """
    if mesh is None:
        mesh = _default_federation_mesh(mix.shape[0])
    axes = node_axes or tuple(a for a in mesh.axis_names if a != "model")
    axis = axes if len(axes) > 1 else axes[0]

    def leaf(l):
        flat = l.reshape(l.shape[0], -1)
        out = _shard_map(
            partial(general_gossip_shard, axis=axis),
            mesh=mesh,
            in_specs=(P(axes), P(axes)),
            out_specs=P(axes),
        )(flat, mix)
        if active is not None:
            # jnp.where, not arithmetic blending: inactive rows stay
            # bit-exact even if the gathered params carry NaN/Inf
            a = (active > 0).reshape((-1,) + (1,) * (flat.ndim - 1))
            out = jnp.where(a, out, flat.astype(out.dtype))
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(leaf, stacked_params)


def make_sharded_gossip(mesh: Mesh, node_axes: tuple[str, ...], topology: str):
    """Returns gossip_fn(stacked_tree, mix or active) running under ``mesh``.

    The stacked node axis is sharded over ``node_axes`` (e.g. ("data",) or
    ("pod", "data")).  Parameters' trailing dims stay as they were.
    """
    axis = node_axes if len(node_axes) > 1 else node_axes[0]
    n_shards = 1
    for a in node_axes:
        n_shards *= mesh.shape[a]

    if topology == "ring":

        def gossip(stacked: PyTree, active: jnp.ndarray) -> PyTree:
            def leaf(l):
                flat = l.reshape(l.shape[0], -1)
                out = _shard_map(
                    partial(ring_gossip_shard, axis=axis, n_shards=n_shards),
                    mesh=mesh,
                    in_specs=(P(node_axes), P(node_axes)),
                    out_specs=P(node_axes),
                )(flat, active.reshape(-1, 1))
                return out.reshape(l.shape).astype(l.dtype)

            return jax.tree.map(leaf, stacked)

        return gossip

    def gossip(stacked: PyTree, mix: jnp.ndarray) -> PyTree:
        return sharded_gossip_mix(stacked, mix, mesh=mesh, node_axes=node_axes)

    return gossip
