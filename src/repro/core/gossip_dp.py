"""Gossip data-parallelism — the paper's algorithm promoted to datacenter
scale (beyond-paper; DESIGN.md §4).

The assigned large architectures train data-parallel over the mesh's
"data" (and "pod") axes.  Standard DP all-reduces gradients every step;
GossipDP instead treats each data-parallel group as a FEDERATED NODE
running GluADFL:

    every step:   local optimizer step on the node's shard of the batch
    every K steps: gossip mix of PARAMETERS across nodes using the
                   paper's topology mixing matrix (ring/cluster/random),
                   with the paper's active-mask asynchrony

This is exactly Algorithm 1 with "patient phone" -> "DP shard group", and
it is the collective-bound hillclimb lever in EXPERIMENTS.md §Perf: a
ring mix moves 2/N of the bytes of an all-reduce per mixing round, and
mixing every K steps amortizes it K-fold, at the cost of parameter
divergence between mixes (bounded by the topology's spectral gap).

Implementation: parameters keep their tensor-parallel sharding on
"model"; the gossip mix is expressed with ``jax.lax`` collectives over
the node axes inside shard_map, so the same code lowers single-pod
(nodes = 16 data groups) and multi-pod (nodes = 2x16 = 32 groups).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.topology import mixing_matrix, round_adjacency
from repro.utils.compat import shard_map as _shard_map

PyTree = Any


def node_count(mesh: Mesh, node_axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in node_axes]))


def _psum_all_combine(contrib, axis, idx):
    """Baseline combine: full psum of the (N, ...) stacked contributions,
    each node slices its own row — every device holds the N-fold temp."""
    summed = jax.lax.psum(contrib, axis)  # (N, ...) mixed for all nodes
    return summed[idx]


def _psum_scatter_combine(contrib, axis, idx):
    """Memory-scaled combine: reduce-scatter along the stacked node dim —
    with one node per shard group the (1, ...) result IS this node's row."""
    out = jax.lax.psum_scatter(contrib, axis, scatter_dimension=0, tiled=True)
    return out[0]


# combine-schedule registry, mirroring distributed._DENSE_WIRE_SCHEDULES:
# "masked" aliases the allgather combine (secure aggregation's mask
# cancellation is a trainer-level wrapper; the DP-mix wire underneath is
# the full-psum one).  The sparse-only "gather" schedule has no entry —
# gossip-DP nodes hold replicated full params, there is no row block to
# halo-rotate.
_DP_COMBINE = {
    "allgather": _psum_all_combine,
    "masked": _psum_all_combine,
    "psum": _psum_scatter_combine,
}


def gossip_mix_params(
    params: PyTree,
    mix: jnp.ndarray,
    mesh: Mesh,
    node_axes: tuple[str, ...],
    *,
    impl: str = "allgather",
):
    """Mix REPLICATED-over-node-axes parameters by M via psum weighting.

    In gossip-DP each node holds the FULL parameters, fully replicated
    over the mesh (leaves enter and leave as ``P()``) — tensor-parallel
    ("model"-sharded) parameters must go through :func:`ring_mix_params`
    with explicit ``specs`` instead.  The mix
    for node n is sum_m M[n,m] w_m: with w replicated, each participant
    contributes its own column-weighted copy and the contributions are
    summed over the node axes.  ``impl`` picks the collective:

      * ``"allgather"`` — BASELINE schedule: full ``psum`` of the
        (N, ...) stacked contributions, then each node slices its own
        row.  Every device holds an N-times-parameters temp (the same
        memory cliff as an all-gather, hence the shared knob name).
      * ``"psum"``      — memory-scaled: ``psum_scatter`` hands each
        node ONLY its own mixed row, so the temp never exceeds one
        parameter copy per device beyond the local shard.

    (The ring fast path in ``ring_mix_params`` cuts this to 2 permutes.)
    """
    if impl not in _DP_COMBINE:
        raise ValueError(f"impl {impl!r} not in {tuple(_DP_COMBINE)}")
    combine = _DP_COMBINE[impl]
    axis = node_axes if len(node_axes) > 1 else node_axes[0]

    def leaf(w):
        def body(w_local, mix_local):
            # node id along the (possibly compound) axis
            idx = jax.lax.axis_index(axis)
            # contribution of THIS node to everyone: w * M[:, idx]
            col = mix_local[:, idx]
            contrib = w_local[None, ...] * col.reshape((-1,) + (1,) * w_local.ndim)
            return combine(contrib, axis, idx)

        # node-replicated leaves: P() on both sides (tensor-parallel
        # sharding goes through ring_mix_params' explicit `specs`)
        return _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P()),
            out_specs=P(),
            check_vma=False,
        )(w, mix)

    return jax.tree.map(leaf, params)


def ring_mix_params(params: PyTree, mesh: Mesh, node_axes: tuple[str, ...],
                    specs: PyTree | None = None):
    """Ring gossip of node-replicated params: two collective_permutes of
    each device's LOCAL tensor-parallel shard + local average — the
    cheapest mixing schedule (2 neighbour transfers of P_local, equal to
    one ring all-reduce's wire at K=1 and K-fold cheaper amortized).

    ``specs``: PartitionSpec tree for the params' tensor-parallel
    sharding (e.g. from ``arch.sharding.param_pspecs``).  Without it the
    leaves are treated as replicated, which forces shard_map to
    all-gather tensor-sharded params first — 20x the wire (§Perf H3
    iteration 1, refuted variant).
    """
    axis = node_axes if len(node_axes) > 1 else node_axes[0]
    n = node_count(mesh, node_axes)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]

    def leaf(w, spec):
        spec = spec if spec is not None else P(*(None,) * w.ndim)

        def body(w_local):
            if n <= 1:
                return w_local
            w_prev = jax.lax.ppermute(w_local, axis, fwd)
            if n == 2:
                # fwd and bwd would deliver the SAME single peer — the
                # three-way average would weight it 2/3 instead of the
                # uniform 1/2 over {self, peer} that
                # mixing_matrix(ring_adjacency(2), ...) produces
                return (w_local + w_prev) / 2.0
            w_next = jax.lax.ppermute(w_local, axis, bwd)
            return (w_local + w_prev + w_next) / 3.0

        return _shard_map(
            body, mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False
        )(w)

    p_leaves, treedef = jax.tree.flatten(params)
    if specs is None:
        s_leaves = [None] * len(p_leaves)
    else:
        s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        if len(s_leaves) != len(p_leaves):
            raise ValueError(
                f"specs tree has {len(s_leaves)} leaves but params has "
                f"{len(p_leaves)} — a zip would silently truncate; pass "
                f"one PartitionSpec per parameter leaf"
            )
    return jax.tree.unflatten(
        treedef, [leaf(w, s) for w, s in zip(p_leaves, s_leaves)]
    )


class GossipDPSchedule:
    """Host-side schedule: which rounds mix, and with which matrix.

    ``schedule`` picks the participation process the mixing matrix is
    drawn under — ``"bernoulli"`` (iid per round, the default) or
    ``"markov"`` (sticky busy/free: ``async_sched.markov_active`` with
    the previous round's mask carried across ``next_mix`` calls) — the
    same two schedules the trainer's sweep axis batches."""

    def __init__(self, topology: str, num_nodes: int, comm_batch: int = 7,
                 mix_every: int = 1, inactive_ratio: float = 0.0, seed: int = 0,
                 schedule: str = "bernoulli", p_stay_active: float = 0.9,
                 p_stay_inactive: float = 0.7):
        if schedule not in ("bernoulli", "markov"):
            raise ValueError(f"unknown schedule {schedule!r}")
        self.topology = topology
        self.num_nodes = num_nodes
        self.comm_batch = comm_batch
        self.mix_every = mix_every
        self.inactive_ratio = inactive_ratio
        self.schedule = schedule
        self.p_stay_active = p_stay_active
        self.p_stay_inactive = p_stay_inactive
        self.key = jax.random.PRNGKey(seed)
        # the chain starts all-active, matching the trainer's convention
        # (fresh FLState staleness is all zeros)
        self.prev_active = jnp.ones((num_nodes,), jnp.float32)

    def should_mix(self, step: int) -> bool:
        return (step + 1) % self.mix_every == 0

    def next_mix(self) -> jnp.ndarray:
        self.key, k_top, k_act = jax.random.split(self.key, 3)
        from repro.core.async_sched import bernoulli_active, markov_active

        if self.schedule == "markov":
            active = markov_active(
                k_act, self.prev_active, self.p_stay_active, self.p_stay_inactive
            )
        else:
            active = bernoulli_active(k_act, self.num_nodes, self.inactive_ratio)
        self.prev_active = active
        adj = round_adjacency(
            self.topology, self.num_nodes, k_top, self.comm_batch
        )
        return mixing_matrix(adj, active, self.comm_batch)
