"""Pairwise-masked secure aggregation for gossip (``gossip_impl="masked"``).

The paper's privacy story so far is local-DP noise only (``gossip_dp``
path in the trainer).  This module adds the classic decentralized
secure-aggregation layer on top: every unordered edge ``(u, v)`` that
appears inside a round's mixing neighborhood gets a per-round PRNG mask
``z_{uv}`` known ONLY to its two endpoints, added with opposite signs to
what each endpoint puts on the wire.  Because the paper's mixing rows are
UNIFORM (``topology.mixing_matrix``: every kept participant of row ``n``
carries the same weight ``1/deg``), the weighted mask terms inside row
``n``'s contraction pair up as exact IEEE negations and the mask sum
cancels to EXACTLY ``+0.0`` — the aggregate is bit-identical to the
unmasked gossip, while no simulated wire tensor ever equals a node's raw
parameters.

Wire model (what a simulated recipient sees), per mixing row ``n`` with
participant set ``S_n`` = the valid slots of its neighbor-table row
(``core.topology.neighbor_table``; slot 0 is self, padding has weight 0):

  ``wire[n, b] = w[idx[n, b]] + Σ_{a ∈ S_n, a ≠ b} ±z_{edge(a, b)}``

with ``+z`` on the lower-node-id endpoint and ``-z`` on the higher.  The
mask key is ``fold_in(fold_in(round_mask_key, min(u, v)), max(u, v))`` —
per round, per unordered edge — so both endpoints can derive it without
any extra communication, and a fresh round re-keys every edge.

Threat model: honest-but-curious neighbors.  A recipient ``n`` knows the
keys of its OWN edges and can strip ``z_{nb}`` from neighbor ``b``'s
wire, but not the masks ``b`` shares with the row's other participants —
so ``w_b`` is hidden whenever ``|S_n| >= 3``.  Two-participant rows
degrade to the DP-noise layer (the only other participant could always
invert a uniform 2-average anyway), and collusion of ALL of a row's
participants is out of scope.  Inactive nodes transmit nothing: their
table rows have a single valid slot (self), which admits no pairs, and
dropped neighbors' slots carry weight 0 — so mid-round dropouts leave
cancellation intact by construction rather than by a recovery protocol.

The production path never materializes wires at all: the trainer mixes
plainly and adds :func:`masked_mix_zero` — the weighted mask sum, computed
term-by-term so each pair contributes ``u*z + u*(-z) = +0.0`` exactly.
XLA does not fold floating ``x + (-x)`` (unsafe for NaN/Inf), so the
per-edge mask generation stays live and the bench row prices the real
overhead.  :func:`simulate_wires` materializes the wire tensors for the
privacy/cancellation tests only.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.rng import split_like

PyTree = Any

# fold_in tag separating the mask key stream from every other consumer of
# the round key: the round body never SPLITS for masks, so turning masking
# on cannot perturb the activity/topology/batch/DP key chain (that is the
# bitwise-parity contract the tests pin)
MASK_STREAM_TAG = 0x6D61736B  # ascii "mask"


def _pair_slots(num_slots: int) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Static unordered slot-index pairs ``(a, b)``, ``a < b``, covering a
    neighbor-table row's ``num_slots`` slots."""
    pairs = [(a, b) for a in range(num_slots) for b in range(a + 1, num_slots)]
    return tuple(p[0] for p in pairs), tuple(p[1] for p in pairs)


def _edge_masks(key, idx: jnp.ndarray, wgt: jnp.ndarray, dim: int):
    """Per-(row, pair) masks for one leaf.

    Returns ``(z, sign_a, pa, pb)`` where ``z`` is ``(N, P, dim)`` fp32 —
    the pair's mask, already zeroed on invalid pairs (either slot padded /
    inactive, or a degenerate self-pair) — and ``sign_a`` is ``(N, P, 1)``
    ±1: the sign the FIRST slot of the pair carries (+1 when it holds the
    lower node id).  ``pa``/``pb`` are the static slot-index arrays.

    The key for a pair is derived from the unordered node-id edge, not the
    slot positions, so two rows that share an edge agree on its mask —
    exactly as two real endpoints deriving it from a shared seed would.
    """
    n, s = idx.shape
    pa_t, pb_t = _pair_slots(s)
    pa = jnp.asarray(pa_t, jnp.int32)
    pb = jnp.asarray(pb_t, jnp.int32)
    ida, idb = idx[:, pa], idx[:, pb]  # (N, P) node ids at the two slots
    lo = jnp.minimum(ida, idb)
    hi = jnp.maximum(ida, idb)
    valid = (wgt[:, pa] > 0) & (wgt[:, pb] > 0) & (ida != idb)

    def one_edge(l, h):
        k = jax.random.fold_in(jax.random.fold_in(key, l), h)
        return jax.random.normal(k, (dim,), jnp.float32)

    z = jax.vmap(jax.vmap(one_edge))(lo, hi)  # (N, P, dim)
    z = jnp.where(valid[..., None], z, 0.0)
    sign_a = jnp.where(ida <= idb, 1.0, -1.0)[..., None].astype(jnp.float32)
    return z, sign_a, pa, pb


def _cancellation_leaf(key, idx, wgt, leaf: jnp.ndarray) -> jnp.ndarray:
    """The weighted mask sum of one leaf's contraction — exactly ``+0.0``.

    Row weights are uniform over valid slots, so a pair's two weighted
    terms are ``u*z`` and ``u*(-z)`` — exact IEEE negations (multiplication
    is sign-magnitude) whose sum is ``+0.0`` for every finite mask.  The
    sum over pairs of ``+0.0`` is ``+0.0``, so adding this term to the
    plain mix leaves it bit-identical while the mask generation itself
    (the thing the bench row prices) stays in the compiled program.
    """
    n = leaf.shape[0]
    dim = math.prod(leaf.shape[1:]) if leaf.ndim > 1 else 1
    z, sign_a, pa, _ = _edge_masks(key, idx, wgt, dim)
    # uniform row weight: wgt[:, pa] == wgt[:, pb] on every valid pair
    u = wgt[:, pa].astype(jnp.float32)[..., None]
    t_pos = u * (sign_a * z)
    t_neg = u * (-(sign_a * z))
    zero = (t_pos + t_neg).sum(axis=1)  # (N, dim), every element +0.0
    return zero.reshape(leaf.shape).astype(leaf.dtype)


def masked_mix_zero(stacked: PyTree, idx, wgt, key) -> PyTree:
    """The pairwise-mask cancellation term for a whole stacked pytree —
    a tree shaped like ``stacked`` whose every element is ``+0.0``, built
    from the same per-leaf key layout as the DP noise path
    (``utils.rng.split_like``).  ``(idx, wgt)`` is the round's
    ``(N, B+1)`` neighbor table (``core.topology.neighbor_table``)."""
    keys = split_like(key, stacked)
    return jax.tree.map(
        lambda l, k: _cancellation_leaf(k, idx, wgt, l), stacked, keys
    )


def simulate_wires(stacked: PyTree, idx, wgt, key) -> PyTree:
    """Materialize the per-row wire tensors — test/audit path ONLY.

    Returns a tree of ``(N, B+1, D)`` fp32 arrays: ``wire[n, b]`` is what
    row ``n``'s recipient sees from its slot-``b`` participant — the
    participant's raw flattened leaf plus its signed mask sum over the
    row's OTHER valid slots.  Invariants the tests pin:

      * ``einsum("nb,nbd->nd", wgt, wire)`` ≈ the plain sparse mix (the
        books balance through the wires, to float tolerance — the exact
        bitwise path is :func:`masked_mix_zero`, which never re-orders
        the contraction);
      * for rows with >= 2 valid slots, NO valid slot's wire equals the
        raw parameters (every participant is masked);
      * rows with a single valid slot (inactive / isolated nodes) put
        nothing but their own unmasked row on their own wire — and a
        single-participant "aggregate" of yourself needs no masking.
    """
    keys = split_like(key, stacked)

    def leaf(l, k):
        n, s = idx.shape
        flat = l.reshape(n, -1).astype(jnp.float32)
        z, sign_a, pa, pb = _edge_masks(k, idx, wgt, flat.shape[1])
        masks = jnp.zeros((n, s, flat.shape[1]), jnp.float32)
        masks = masks.at[:, pa].add(sign_a * z)
        masks = masks.at[:, pb].add(-sign_a * z)
        return flat[idx] + masks

    return jax.tree.map(leaf, stacked, keys)
