"""GossipPlan — the mixing pipeline, resolved ONCE instead of dispatched
per call.

The trainer composes five orthogonal gossip knobs (``mixer`` tree /
kernel / sharded × ``gossip_impl`` allgather / psum / masked / gather ×
``gossip_repr`` dense / sparse × local-DP sigma × sweep batching).
Historically their interaction lived in ~17 nested ``if self.mixer ==
...`` branches spread over ``gluadfl.py`` / ``gossip.py`` /
``distributed.py`` / ``gossip_dp.py``; adding ONE new backend meant
editing every branch site.  This module collapses the maze:

* **Backend registry** — each mix backend is a registered callable with
  the uniform signature ``mix(stacked, mix_repr, *, key, mesh,
  grid_axis)`` plus declared capabilities (:class:`BackendCaps`:
  ``supports_sparse`` / ``supports_sweep_grid`` / ``supports_multihost``
  / ``memory_class`` / ``fused_dp``).  The registry is the single source
  of truth: the ARCHITECTURE.md knob matrix is GENERATED from it
  (``tools/gen_knob_matrix.py``) and the plan-totality test iterates it.
* **Resolution** — :func:`resolve_gossip_plan` turns ``(mixer,
  gossip_impl, gossip_repr, dp, masked, mesh)`` into a
  :class:`GossipPlan` at ``GluADFL.__init__`` (and again at
  ``train_sweep`` setup via :meth:`GossipPlan.require_sweep`): every
  refusal — unknown knob value, ``gather`` off the sharded mixer,
  kernel × sweep, non-sharded × multihost — raises HERE with a readable
  message, never mid-trace.
* **Pipeline** — a resolved plan is the explicit four-stage pipeline
  ``build_repr → [mask_wrap] → mix_backend → [dp_fuse]``:
  :meth:`GossipPlan.build_repr` makes the round's mixing operator
  (dense (N, N) matrix or sparse (N, B+1) neighbor table),
  :meth:`GossipPlan.mix` is the resolved noise-free contraction, and
  :meth:`GossipPlan.gossip` runs the full round step — optional local-DP
  fusion/composition first, the pairwise-mask cancellation term last —
  reproducing the pre-plan trainer BITWISE on every existing knob
  combination (the parity suites are the oracle).

``tools/check_gossip_dispatch.py`` keeps the refactor from regressing:
string-dispatch on the gossip knobs (``mixer == "..."`` and friends) is
linted out of ``core/`` everywhere but this module.

The policies ``choose_gossip_impl`` / ``choose_gossip_repr`` (formerly
``launch.mesh``) live here too: they are plan-resolution policies — the
``"auto"`` knob values defer to them, and ``launch.mesh`` re-exports
them for back-compat.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.gossip import (
    gossip_mix_dp_kernel,
    gossip_mix_kernel,
    gossip_mix_masked,
    gossip_mix_sparse_dp_kernel,
    gossip_mix_sparse_kernel,
    gossip_mix_sparse_tree,
    gossip_mix_tree,
    sharded_gossip_mix,
    sharded_gossip_mix_gather,
    sharded_gossip_mix_sparse,
)
from repro.core.topology import mixing_matrix, neighbor_candidates, neighbor_table
from repro.utils.rng import split_like

PyTree = Any

# the mixer knob's legal values (the backend registry below may hold
# MORE backends than mixers: `gossip_impl="gather"` reroutes the sharded
# mixer to the sharded_gather_tables backend)
MIXERS = ("tree", "kernel", "sharded")


class GossipPlanError(ValueError):
    """A knob combination the registry declares unsupported.  Subclasses
    ``ValueError`` so pre-plan call sites (and their tests) that caught
    ``ValueError`` keep working."""


@dataclass(frozen=True)
class MixRepr:
    """The round's mixing operator in its resolved representation.

    ``kind`` is ``"dense"`` (``operand`` = (N, N) row-stochastic matrix,
    identity rows already encode inactivity) or ``"sparse"`` (``operand``
    = the ``(idx, wgt)`` (N, B+1) neighbor table, slot 0 = self).
    ``active`` is the round's (N,) activity vector — the sparse paths
    use it for a bit-exact inactive-row where-select."""

    kind: str
    operand: Any
    active: Any = None


@dataclass(frozen=True)
class BackendCaps:
    """Declared capabilities of one registered mix backend — consumed by
    plan resolution (refusals), the generated knob matrix, and the
    plan-totality test."""

    supports_sparse: bool
    supports_dense: bool
    supports_sweep_grid: bool
    supports_multihost: bool
    memory_class: str        # per-device working set of the contraction
    fused_dp: bool           # noise+mix+self-restore fused in one pass
    uses_mesh: bool          # runs under a device mesh (shard_map)


@dataclass(frozen=True)
class MixBackend:
    """One registered mix backend: the uniform-signature callable plus
    its capabilities and knob routing.

    ``build(impl, default_mesh)`` returns the callable
    ``mix(stacked, mix_repr, *, key=None, mesh=None, grid_axis=None)``
    with the wire schedule and fallback mesh already bound — resolution
    calls it once, so the hot path holds a plain closure."""

    name: str
    mixer: str                   # the mixer knob value this backend serves
    impls: tuple[str, ...]       # wire schedules it accepts
    caps: BackendCaps
    build: Callable
    summary: str                 # one-line doc, surfaces in the knob matrix
    sweep_refusal: str | None = None   # message when supports_sweep_grid=False


# --------------------------------------------------------------------------
# the registry — the single source of truth for what composes with what
# --------------------------------------------------------------------------

_REGISTRY: dict[str, MixBackend] = {}


def register_mix_backend(backend: MixBackend) -> MixBackend:
    """Register a mix backend (latest registration wins — tests may
    shadow a backend with an instrumented twin)."""
    _REGISTRY[backend.name] = backend
    return backend


def mix_backends() -> dict[str, MixBackend]:
    """A copy of the backend registry, keyed by backend name."""
    return dict(_REGISTRY)


def _build_tree(impl, default_mesh):
    def mix(stacked, rep: MixRepr, *, key=None, mesh=None, grid_axis=None):
        if rep.kind == "sparse":
            idx, wgt = rep.operand
            return gossip_mix_sparse_tree(stacked, idx, wgt, rep.active)
        return gossip_mix_tree(stacked, rep.operand)

    return mix


def _build_kernel(impl, default_mesh):
    def mix(stacked, rep: MixRepr, *, key=None, mesh=None, grid_axis=None):
        if rep.kind == "sparse":
            idx, wgt = rep.operand
            return gossip_mix_sparse_kernel(stacked, idx, wgt, rep.active)
        return gossip_mix_kernel(stacked, rep.operand)

    return mix


def _build_sharded(impl, default_mesh):
    def mix(stacked, rep: MixRepr, *, key=None, mesh=None, grid_axis=None):
        if rep.kind == "sparse":
            idx, wgt = rep.operand
            return sharded_gossip_mix_sparse(
                stacked, idx, wgt, rep.active,
                mesh=mesh or default_mesh, grid_axis=grid_axis,
            )
        # dense identity rows already encode inactivity — no active mask
        return sharded_gossip_mix(
            stacked, rep.operand,
            mesh=mesh or default_mesh, impl=impl, grid_axis=grid_axis,
        )

    return mix


def _build_gather_tables(impl, default_mesh):
    def mix(stacked, rep: MixRepr, *, key=None, mesh=None, grid_axis=None):
        idx, wgt = rep.operand
        return sharded_gossip_mix_gather(
            stacked, idx, wgt, rep.active,
            mesh=mesh or default_mesh, grid_axis=grid_axis,
        )

    return mix


register_mix_backend(MixBackend(
    name="tree",
    mixer="tree",
    # the wire schedule only matters to the sharded mixer; tree/kernel
    # accept every schedule knob value and ignore it (masked composes
    # through the trainer-level cancellation wrapper either way)
    impls=("allgather", "psum", "masked"),
    caps=BackendCaps(
        supports_sparse=True, supports_dense=True,
        supports_sweep_grid=True, supports_multihost=False,
        memory_class="replicated O(N·D)", fused_dp=False, uses_mesh=False,
    ),
    build=_build_tree,
    summary="reference einsum per leaf (CPU default)",
))

register_mix_backend(MixBackend(
    name="kernel",
    mixer="kernel",
    impls=("allgather", "psum", "masked"),
    caps=BackendCaps(
        supports_sparse=True, supports_dense=True,
        supports_sweep_grid=False, supports_multihost=False,
        memory_class="replicated O(N·D), VMEM-blocked", fused_dp=True,
        uses_mesh=False,
    ),
    build=_build_kernel,
    summary="Pallas VMEM-blocked kernel; fuses the local-DP pass",
    sweep_refusal=(
        "train_sweep batches the tree or sharded mixer; "
        "mixer='kernel' (Pallas) is a per-scenario program — "
        "use serial train() for it"
    ),
))

register_mix_backend(MixBackend(
    name="sharded",
    mixer="sharded",
    impls=("allgather", "psum", "masked"),
    caps=BackendCaps(
        supports_sparse=True, supports_dense=True,
        supports_sweep_grid=True, supports_multihost=True,
        memory_class="allgather O(N·D) / psum O(N/shards·D) per device",
        fused_dp=False, uses_mesh=True,
    ),
    build=_build_sharded,
    summary="shard_map collectives over the node mesh axis",
))

register_mix_backend(MixBackend(
    name="sharded_gather_tables",
    mixer="sharded",
    impls=("gather",),
    caps=BackendCaps(
        supports_sparse=True, supports_dense=False,
        supports_sweep_grid=False, supports_multihost=True,
        memory_class="halo O(N/shards·D) per device, no gathered (N·D)",
        fused_dp=False, uses_mesh=True,
    ),
    build=_build_gather_tables,
    summary=(
        "sharded (N, B+1) tables + ppermute halo rotation — gathers only "
        "referenced remote rows (the 100k-node backend)"
    ),
    sweep_refusal=(
        "train_sweep batches the tree or sharded allgather/psum "
        "schedules; gossip_impl='gather' (sharded gather tables) is the "
        "single-run scale-out schedule — use allgather/psum for swept-"
        "sharded runs"
    ),
))


def _backend_for(mixer: str, gossip_impl: str) -> MixBackend:
    """Route (mixer, impl) to a registered backend, or raise the
    documented capability error."""
    for backend in _REGISTRY.values():
        if backend.mixer == mixer and gossip_impl in backend.impls:
            return backend
    # the only impl not universally accepted is the gather-tables one
    takers = sorted(b.mixer for b in _REGISTRY.values() if gossip_impl in b.impls)
    raise GossipPlanError(
        f"gossip_impl {gossip_impl!r} has no backend for mixer={mixer!r}"
        + (f" (it needs mixer in {takers})" if takers else "")
    )


# --------------------------------------------------------------------------
# plan-resolution policies (the "auto" knob values; formerly launch.mesh)
# --------------------------------------------------------------------------

# per-device budget for the gathered (N, D) federation before the
# allgather mixer's memory cliff outweighs its ICI-friendly schedule;
# ~1 GiB leaves headroom for the model step on current HBM/host parts
DEFAULT_GATHER_BUDGET_BYTES = 1 << 30


def choose_gossip_impl(
    num_nodes: int,
    param_bytes_per_node: int,
    *,
    shards: int | None = None,
    budget_bytes: int = DEFAULT_GATHER_BUDGET_BYTES,
    secure: bool = False,
) -> str:
    """Memory-scaled gossip-impl selection (``--gossip-impl auto``).

    The ``"allgather"`` mixer materializes the full federation —
    ``num_nodes * param_bytes_per_node`` — on EVERY device, regardless of
    how many shards the mesh has; ``"psum"`` keeps the per-device working
    set at O(N/shards · D) via reduce-scatter.  Below ``budget_bytes``
    the gathered form wins (one dense collective, what the ICI fabric is
    best at); above it, psum is the only schedule that fits.  ``shards``
    defaults to the federation mesh width for ``num_nodes``.

    ``secure=True`` requests pairwise-masked secure aggregation
    (``core.secure_agg``): the choice is then ``"masked"`` regardless of
    memory — its wire schedule rides allgather, so it is only offered
    while the gathered federation fits the budget; past that this raises
    rather than silently dropping the privacy layer (psum has no masked
    sibling: the reduce-scatter never materializes per-neighbor wires to
    mask).
    """
    if shards is None:
        from repro.launch.mesh import make_federation_mesh

        shards = make_federation_mesh(num_nodes).shape["node"]
    gathered = num_nodes * param_bytes_per_node
    if secure:
        if shards > 1 and gathered > budget_bytes:
            raise GossipPlanError(
                f"secure (masked) gossip rides the allgather schedule, but "
                f"the gathered federation ({gathered} bytes) exceeds the "
                f"per-device budget ({budget_bytes}); shrink the model or "
                f"raise budget_bytes"
            )
        return "masked"
    if shards <= 1:
        return "allgather"  # single shard: gather is a no-op copy
    return "allgather" if gathered <= budget_bytes else "psum"


# sparse tables win once the kept row (B+1 entries) is a small fraction
# of N; 4x covers the gather/top_k bookkeeping the dense matmul doesn't pay
SPARSE_GOSSIP_FACTOR = 4


def _node_axis_width(mesh) -> int:
    """Total node-axis width of a federation/sweep mesh — the product of
    every axis the gossip collectives run over (same convention as
    ``core.distributed``: everything except "model"/"grid")."""
    width = 1
    for name in mesh.axis_names:
        if name not in ("model", "grid"):
            width *= mesh.shape[name]
    return max(width, 1)


def choose_gossip_repr(
    num_nodes: int,
    comm_batch: int,
    *,
    factor: int = SPARSE_GOSSIP_FACTOR,
    mesh=None,
    budget_bytes: int = DEFAULT_GATHER_BUDGET_BYTES,
) -> str:
    """Mixing-operator representation selection (``--gossip-repr auto``).

    Every mixing row has at most ``comm_batch + 1`` nonzeros (Algorithm 1
    caps each node at B neighbours), so the dense (N, N) matrix carries
    ``N / (B+1)``-fold pure waste.  Pick the sparse neighbor table
    (``core.topology.neighbor_table``) once ``B+1 ≪ N`` — concretely
    ``num_nodes >= factor * (comm_batch + 1)`` — and keep the dense
    matrix for small federations where the one-matmul contraction is
    simpler than the gather and the waste is noise.  At the paper's
    N=226 / B=7 this picks sparse (226 >= 32); a 16-node smoke test
    stays dense.

    Mesh-aware (the sharded mixer's path): with a ``mesh``, the dense
    representation additionally keeps an ``(N/shards, N)`` row block of
    the mixing matrix resident on every device — once that block alone
    outgrows ``budget_bytes`` the flop heuristic is moot and only the
    ``(N/shards, B+1)`` table fits, so sparse is forced regardless of
    ``factor``.  Without a mesh the choice depends on (N, B) only."""
    if num_nodes >= factor * (comm_batch + 1):
        return "sparse"
    if mesh is not None:
        shards = _node_axis_width(mesh)
        per_device_matrix = (num_nodes // shards) * num_nodes * 4  # f32
        if per_device_matrix > budget_bytes:
            return "sparse"
    return "dense"


# --------------------------------------------------------------------------
# the resolved plan
# --------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class GossipPlan:
    """One resolved mixing pipeline: ``build_repr → [mask_wrap] →
    mix_backend → [dp_fuse]``, with every knob decision already taken.

    Resolved once per trainer (``GluADFL.__init__``) and re-checked at
    ``train_sweep`` setup (:meth:`require_sweep`) — the round body only
    ever calls :meth:`build_repr` / :meth:`mask_table` /
    :meth:`gossip`."""

    mixer: str               # resolved mixer knob value
    backend: str             # registered backend name serving it
    gossip_impl: str
    gossip_repr: str         # resolved: "dense" | "sparse" (never "auto")
    dp_noise_sigma: float
    masked: bool             # gossip_impl == "masked" resolved at build
    use_kernel: bool         # back-compat introspection flag
    uses_mesh: bool
    comm_batch: int
    caps: BackendCaps
    mesh: Any = None
    neighbor_cand: Any = None    # host-built static-topology candidates
    _build_repr: Callable = None
    _mask_table: Callable = None
    _mix: Callable = None
    _dp: Callable = None
    _sweep_refusal: str | None = None

    # -- stage 1: the round's mixing operator --------------------------
    def build_repr(self, adj, active, comm_batch: int | None = None) -> Any:
        """Dense (N, N) ``mixing_matrix`` or sparse ``(idx, wgt)``
        neighbor table (densifying the latter reproduces the former
        bitwise)."""
        b = self.comm_batch if comm_batch is None else comm_batch
        return self._build_repr(adj, active, b)

    def mask_table(self, operand, adj, active, comm_batch: int | None = None):
        """The (N, B+1) neighbor table the pairwise-mask wrapper needs:
        the operand itself under the sparse representation, or a table
        built alongside the dense matrix purely for mask bookkeeping."""
        b = self.comm_batch if comm_batch is None else comm_batch
        return self._mask_table(operand, adj, active, b)

    # -- stage 3: the resolved noise-free contraction ------------------
    def mix(self, stacked: PyTree, operand: Any, active=None, *,
            key=None, mesh=None, grid_axis=None) -> PyTree:
        """The plain mix on the resolved backend.  ``mesh`` overrides
        the plan's mesh for this call (the swept-sharded path threads
        its 2-D (grid, node) mesh down here)."""
        rep = MixRepr(kind=self.gossip_repr, operand=operand, active=active)
        return self._mix(stacked, rep, key=key, mesh=mesh, grid_axis=grid_axis)

    # -- the full pipeline ---------------------------------------------
    def gossip(self, premix: PyTree, operand: Any, active, k_dp, *,
               mesh=None, mask_ctx=None, dp_sigma=None) -> PyTree:
        """One round's mixing step: plain mix or the local-DP
        composition (stage 4 — fused into the kernel backend's single
        pass, composed as noise-add → mix → clean-self-restore
        elsewhere), then the pairwise-mask cancellation term (stage 2's
        wrapper) added to the FINAL mixed state — after the DP
        composition too, so masked runs stay bitwise twins of their
        unmasked counterparts on every backend/repr/DP combination.

        ``dp_sigma`` overrides the plan's ``dp_noise_sigma``: a python
        float (config path) keeps the concrete ``<= 0`` shortcut; a
        TRACED per-scenario scalar (the sweep's DP axis) always takes
        the noise path — a ``sigma=0`` scenario then contracts
        exact-zero noise, which the DP-off property test pins as
        bitwise-clean."""
        rep = MixRepr(kind=self.gossip_repr, operand=operand, active=active)
        if dp_sigma is None:
            dp_sigma = self.dp_noise_sigma
        concrete_off = isinstance(dp_sigma, (int, float)) and dp_sigma <= 0.0
        if k_dp is None or concrete_off:
            out = self._mix(stacked=premix, rep=rep, mesh=mesh)
        else:
            noise_keys = split_like(k_dp, premix)
            noise = jax.tree.map(
                lambda w, k_: dp_sigma * jax.random.normal(k_, w.shape, w.dtype),
                premix, noise_keys,
            )
            out = self._dp(premix, noise, rep, mesh=mesh)
        if mask_ctx is not None:
            k_mask, (t_idx, t_wgt) = mask_ctx
            out = gossip_mix_masked(out, t_idx, t_wgt, k_mask)
        return out

    # -- capability checks ---------------------------------------------
    def require_sweep(self) -> None:
        """Raise the documented refusal unless this plan's backend can
        batch under the sweep engine's grid vmap."""
        if not self.caps.supports_sweep_grid:
            raise NotImplementedError(
                self._sweep_refusal
                or f"backend {self.backend!r} does not support train_sweep"
            )

    def require_multihost(self) -> None:
        """Raise unless this plan's backend spans ``jax.distributed``
        processes (the node axis must be a real mesh axis)."""
        if not self.caps.supports_multihost:
            raise ValueError(
                f"multi-host training needs mixer='sharded' (the node "
                f"axis must span processes), got mixer={self.mixer!r}"
            )


def _resolve_dp_stage(backend: MixBackend, gossip_repr: str, mix_fn: Callable):
    """Stage 4 (``dp_fuse``): the kernel backend fuses noise-broadcast +
    mix + clean-self-restore into its single pass; every other backend
    composes — neighbours mix the NOISED view and each node re-adds its
    own clean self-contribution (it never needs to noise itself)."""
    if backend.caps.fused_dp:
        if gossip_repr == "sparse":
            def dp(premix, noise, rep: MixRepr, *, mesh=None):
                idx, wgt = rep.operand
                return gossip_mix_sparse_dp_kernel(
                    premix, noise, idx, wgt, rep.active
                )
        else:
            def dp(premix, noise, rep: MixRepr, *, mesh=None):
                return gossip_mix_dp_kernel(premix, noise, rep.operand, rep.active)
        return dp
    if gossip_repr == "sparse":
        def dp(premix, noise, rep: MixRepr, *, mesh=None):
            shared = jax.tree.map(jnp.add, premix, noise)
            mixed_noisy = mix_fn(shared, rep, mesh=mesh)
            # slot 0 is always self: wgt[:, 0] IS the densified diagonal.
            # the plain mix already where-selected inactive rows back to
            # the noised view, so restore them to the clean premix too.
            self_w = rep.operand[1][:, 0]
            out = jax.tree.map(
                lambda mn, z: mn - self_w.reshape((-1,) + (1,) * (z.ndim - 1)) * z,
                mixed_noisy, noise,
            )
            a = rep.active > 0
            return jax.tree.map(
                lambda o, p: jnp.where(a.reshape((-1,) + (1,) * (o.ndim - 1)), o, p),
                out, premix,
            )
        return dp

    def dp(premix, noise, rep: MixRepr, *, mesh=None):
        shared = jax.tree.map(jnp.add, premix, noise)
        mixed_noisy = mix_fn(shared, rep, mesh=mesh)
        self_w = jnp.diagonal(rep.operand)  # (N,)
        return jax.tree.map(
            lambda mn, z: mn - self_w.reshape((-1,) + (1,) * (z.ndim - 1)) * z,
            mixed_noisy, noise,
        )

    return dp


def resolve_gossip_plan(
    *,
    mixer: str | None = None,
    use_kernel: bool = False,
    gossip_impl: str = "allgather",
    gossip_repr: str = "dense",
    dp_noise_sigma: float = 0.0,
    mesh=None,
    num_nodes: int,
    comm_batch: int,
    topology: str | None = None,
    cluster_size: int = 4,
) -> GossipPlan:
    """Resolve the gossip knobs into one :class:`GossipPlan`.

    Every refusal raises here with the knob's name in the message:
    unknown ``mixer`` / ``gossip_impl`` / ``gossip_repr`` values are
    plain ``ValueError``s; combinations the registry declares
    unsupported (``gather`` off the sharded mixer or the dense repr)
    raise :class:`GossipPlanError`.  ``gossip_repr="auto"`` defers to
    the mesh-aware :func:`choose_gossip_repr` policy.

    ``use_kernel`` is the DEPRECATED pre-``mixer`` spelling of
    ``mixer="kernel"`` — it still maps through (and still conflicts
    loudly with a contradicting ``mixer``), but warns."""
    from repro.core.distributed import GOSSIP_IMPLS, GOSSIP_REPRS

    if use_kernel:
        warnings.warn(
            "use_kernel is deprecated; pass mixer='kernel' instead "
            "(the flag maps through for now and will be removed)",
            DeprecationWarning,
            stacklevel=3,
        )
        if mixer is None:
            mixer = "kernel"
        elif mixer != "kernel":
            raise ValueError(
                f"use_kernel=True contradicts mixer={mixer!r}; pass one or the other"
            )
    if mixer is None:
        mixer = "tree"
    if mixer not in MIXERS:
        raise ValueError(f"mixer {mixer!r} not in {MIXERS}")
    if gossip_impl not in GOSSIP_IMPLS:
        raise ValueError(f"gossip_impl {gossip_impl!r} not in {GOSSIP_IMPLS}")
    if gossip_repr == "auto":
        gossip_repr = choose_gossip_repr(num_nodes, comm_batch, mesh=mesh)
    if gossip_repr not in GOSSIP_REPRS:
        raise ValueError(
            f"gossip_repr {gossip_repr!r} not in {GOSSIP_REPRS}; 'auto' "
            f"resolves via the mesh-aware choose_gossip_repr policy before "
            f"this check"
        )

    backend = _backend_for(mixer, gossip_impl)
    if gossip_repr == "sparse" and not backend.caps.supports_sparse:
        raise GossipPlanError(
            f"backend {backend.name!r} does not support gossip_repr='sparse'"
        )
    if gossip_repr == "dense" and not backend.caps.supports_dense:
        raise GossipPlanError(
            f"gossip_impl {gossip_impl!r} (backend {backend.name!r}) needs "
            f"gossip_repr='sparse': the gather-table schedule shards the "
            f"(N, B+1) neighbor tables — there is no dense (N, N) variant"
        )

    mix_fn = backend.build(gossip_impl, mesh)
    dp_fn = _resolve_dp_stage(backend, gossip_repr, mix_fn)
    if gossip_repr == "sparse":
        build_repr = lambda adj, active, b: neighbor_table(adj, active, b)
        mask_tab = lambda operand, adj, active, b: operand
        # static-topology candidate lists, host-built once: the sparse
        # config-driven path builds its (N, B+1) table straight from
        # these — no (N, N) array ever exists (the population-scale
        # unlock).  None for "random" (per-round graphs go through
        # neighbor_table) and for topology-free resolutions.
        cand = (
            neighbor_candidates(topology, num_nodes, cluster_size)
            if topology is not None
            else None
        )
    else:
        build_repr = lambda adj, active, b: mixing_matrix(adj, active, b)
        # dense rounds build the (N, B+1) table alongside the matrix
        # purely for mask bookkeeping — the mix stays on the dense repr
        mask_tab = lambda operand, adj, active, b: neighbor_table(adj, active, b)
        cand = None

    return GossipPlan(
        mixer=mixer,
        backend=backend.name,
        gossip_impl=gossip_impl,
        gossip_repr=gossip_repr,
        dp_noise_sigma=dp_noise_sigma,
        masked=gossip_impl == "masked",
        use_kernel=backend.caps.fused_dp,
        uses_mesh=backend.caps.uses_mesh,
        comm_batch=comm_batch,
        caps=backend.caps,
        mesh=mesh,
        neighbor_cand=cand,
        _build_repr=build_repr,
        _mask_table=mask_tab,
        _mix=mix_fn,
        _dp=dp_fn,
        _sweep_refusal=backend.sweep_refusal,
    )


def supported_cells() -> list[dict]:
    """Every (mixer, gossip_impl, gossip_repr) cell the registry
    resolves, with its backend name and capabilities — the machine-
    readable form the knob-matrix generator and the totality test share."""
    from repro.core.distributed import GOSSIP_IMPLS, GOSSIP_REPRS

    cells = []
    for mixer in MIXERS:
        for impl in GOSSIP_IMPLS:
            for repr_ in GOSSIP_REPRS:
                try:
                    plan = resolve_gossip_plan(
                        mixer=mixer, gossip_impl=impl, gossip_repr=repr_,
                        num_nodes=8, comm_batch=2,
                    )
                except (GossipPlanError, ValueError):
                    continue
                cells.append({
                    "mixer": mixer,
                    "gossip_impl": impl,
                    "gossip_repr": repr_,
                    "backend": plan.backend,
                    "sweep": plan.caps.supports_sweep_grid,
                    "multihost": plan.caps.supports_multihost,
                    "memory_class": plan.caps.memory_class,
                })
    return cells
