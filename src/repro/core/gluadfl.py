"""GluADFL — Algorithm 1 of the paper, vectorized over the federation.

Faithfulness notes (numbered lines refer to the paper's Algorithm 1):
  * Line 3  — per-node random init (different seed per node).
  * Line 5  — broadcasting is implicit in the mixing matrix: only ACTIVE
    nodes' parameters reach neighbours, and only ACTIVE nodes mix.
  * Lines 7-9 — uniform average over {self} ∪ ≤B active neighbours,
    implemented as a row-stochastic matrix (topology.mixing_matrix) and a
    single gossip-mix contraction (gossip.py / Pallas kernel).
  * Lines 11-13 — local SGD step; per the paper's update rule
    ``w_t = ŵ_{t-1} - γ ∇J(·, w_{t-1})`` the gradient is evaluated at the
    PRE-MIX parameters and applied to the mixed ones (SWIFT-style).
    ``grad_at="mixed"`` gives the conventional DSGD variant (beyond-paper
    ablation).
  * Lines 15-16 — population model = uniform average of all node models.

The whole federation is a stacked pytree (leaves ``(N, ...)``); one round
is a single jitted function: mixing-matrix build -> gossip mix -> vmapped
local step, all masked by the round's active vector.  Nodes therefore
simulate wall-clock asynchrony exactly (inactive nodes are frozen), while
the host sees a deterministic, reproducible program.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import FLConfig
from repro.core.async_sched import bernoulli_active, staleness_update
from repro.core.gossip import gossip_mix_kernel, gossip_mix_tree
from repro.core.topology import mixing_matrix, round_adjacency
from repro.models.base import Model
from repro.optim import Optimizer
from repro.utils.pytree import tree_mean

PyTree = Any


@jax.tree_util.register_dataclass
@dataclass
class FLState:
    params: PyTree          # stacked (N, ...)
    opt_state: PyTree       # stacked (N, ...)
    staleness: jnp.ndarray  # (N,)
    round: jnp.ndarray      # scalar int
    key: jnp.ndarray


class GluADFL:
    """Asynchronous decentralized FL trainer (the paper's contribution)."""

    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        cfg: FLConfig,
        *,
        grad_at: str = "premix",
        use_kernel: bool = False,
        dp_noise_sigma: float = 0.0,
        loss_fn: Callable | None = None,
    ):
        assert grad_at in ("premix", "mixed")
        self.model = model
        self.optimizer = optimizer
        self.cfg = cfg
        self.grad_at = grad_at
        self.use_kernel = use_kernel
        # BEYOND-PAPER: local differential privacy on the broadcast —
        # Gaussian noise is added to the parameters a node SHARES (its
        # own copy stays clean), so neighbours only ever see a noised
        # view.  sigma is in parameter units; the paper motivates privacy
        # but shares exact parameters — this closes that gap optionally.
        self.dp_noise_sigma = dp_noise_sigma
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        self._round_jit = jax.jit(self._round, static_argnames=("batch_size",))

    # ------------------------------------------------------------------
    def init(self, key, example_x) -> FLState:
        n = self.cfg.num_nodes
        keys = jax.random.split(key, n + 1)
        params = jax.vmap(self.model.init)(keys[:n])
        opt_state = jax.vmap(self.optimizer.init)(params)
        return FLState(
            params=params,
            opt_state=opt_state,
            staleness=jnp.zeros((n,), jnp.float32),
            round=jnp.zeros((), jnp.int32),
            key=keys[n],
        )

    # ------------------------------------------------------------------
    def _sample_batch(self, key, x_node, y_node, count, batch_size):
        """Uniform with-replacement batch from one node's (padded) data."""
        idx = jax.random.randint(key, (batch_size,), 0, jnp.maximum(count, 1))
        return x_node[idx], y_node[idx]

    def _local_step(self, key, params_premix, params_mixed, opt_state, x, y, count, batch_size):
        """One (or more) local SGD steps for a single node."""

        def one_step(carry, k):
            p_for_grad, p_apply, st = carry
            bx, by = self._sample_batch(k, x, y, count, batch_size)
            loss, grads = jax.value_and_grad(self.loss_fn)(p_for_grad, bx, by)
            new_p, new_st = self.optimizer.update(grads, st, p_apply)
            # subsequent local steps are ordinary SGD at the new params
            return (new_p, new_p, new_st), loss

        keys = jax.random.split(key, self.cfg.local_steps)
        first_grad_p = params_premix if self.grad_at == "premix" else params_mixed
        (p, _, st), losses = jax.lax.scan(
            one_step, (first_grad_p, params_mixed, opt_state), keys
        )
        return p, st, jnp.mean(losses)

    # ------------------------------------------------------------------
    def _round(self, state: FLState, x, y, counts, *, batch_size: int):
        cfg = self.cfg
        n = cfg.num_nodes
        key, k_act, k_top, k_batch = jax.random.split(state.key, 4)

        active = bernoulli_active(k_act, n, cfg.inactive_ratio)
        adj = round_adjacency(cfg.topology, n, k_top, cfg.comm_batch, cfg.cluster_size)
        mix = mixing_matrix(adj, active, cfg.comm_batch)

        premix = state.params
        mixer = gossip_mix_kernel if self.use_kernel else gossip_mix_tree
        if self.dp_noise_sigma > 0.0:
            key, k_dp = jax.random.split(key)
            from repro.utils.rng import split_like

            noise_keys = split_like(k_dp, premix)
            shared = jax.tree.map(
                lambda w, k_: w + self.dp_noise_sigma * jax.random.normal(k_, w.shape, w.dtype),
                premix, noise_keys,
            )
            # neighbours mix the NOISED view; each node re-adds its own
            # clean self-contribution (it never needs to noise itself)
            self_w = jnp.diagonal(mix)  # (N,)
            mixed_noisy = mixer(shared, mix)
            mixed = jax.tree.map(
                lambda mn, sh, cl: mn
                + self_w.reshape((-1,) + (1,) * (cl.ndim - 1)) * (cl - sh),
                mixed_noisy, shared, premix,
            )
        else:
            mixed = mixer(premix, mix)

        node_keys = jax.random.split(k_batch, n)
        new_params, new_opt, losses = jax.vmap(
            partial(self._local_step, batch_size=batch_size)
        )(node_keys, premix, mixed, state.opt_state, x, y, counts)

        # inactive nodes keep their stale params / optimizer state
        def mask(new, old):
            bshape = (n,) + (1,) * (new.ndim - 1)
            a = active.reshape(bshape)
            return a * new + (1 - a) * old

        params = jax.tree.map(mask, new_params, premix)
        opt_state = jax.tree.map(
            lambda nw, od: mask(nw, od) if nw.ndim >= 1 and nw.shape[:1] == (n,) else nw,
            new_opt,
            state.opt_state,
        )
        loss = jnp.sum(losses * active) / jnp.maximum(jnp.sum(active), 1.0)
        return (
            FLState(
                params=params,
                opt_state=opt_state,
                staleness=staleness_update(state.staleness, active),
                round=state.round + 1,
                key=key,
            ),
            loss,
        )

    # ------------------------------------------------------------------
    def train(
        self,
        key,
        x,
        y,
        counts,
        *,
        batch_size: int = 64,
        rounds: int | None = None,
        eval_every: int = 0,
        eval_fn: Callable[[PyTree], dict] | None = None,
    ):
        """Run T rounds (python loop of a jitted round); returns
        (population_params, history)."""
        rounds = rounds if rounds is not None else self.cfg.rounds
        x, y = jnp.asarray(x), jnp.asarray(y)
        counts = jnp.asarray(counts)
        state = self.init(key, x[0, :1])
        history: list[dict] = []
        for t in range(rounds):
            state, loss = self._round_jit(state, x, y, counts, batch_size=batch_size)
            rec = {"round": t, "loss": float(loss)}
            if eval_every and eval_fn and (t + 1) % eval_every == 0:
                rec.update(eval_fn(self.population(state)))
            history.append(rec)
        return self.population(state), history, state

    # ------------------------------------------------------------------
    @staticmethod
    def population(state: FLState) -> PyTree:
        """Algorithm 1 lines 15-16: uniform average of all node models."""
        return tree_mean(state.params)
