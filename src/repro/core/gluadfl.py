"""GluADFL — Algorithm 1 of the paper, vectorized over the federation.

Faithfulness notes (numbered lines refer to the paper's Algorithm 1):
  * Line 3  — per-node random init (different seed per node).
  * Line 5  — broadcasting is implicit in the mixing matrix: only ACTIVE
    nodes' parameters reach neighbours, and only ACTIVE nodes mix.
  * Lines 7-9 — uniform average over {self} ∪ ≤B active neighbours,
    implemented as a row-stochastic matrix (topology.mixing_matrix) and a
    single gossip-mix contraction (gossip.py / Pallas kernel).
  * Lines 11-13 — local SGD step; per the paper's update rule
    ``w_t = ŵ_{t-1} - γ ∇J(·, w_{t-1})`` the gradient is evaluated at the
    PRE-MIX parameters and applied to the mixed ones (SWIFT-style).
    ``grad_at="mixed"`` gives the conventional DSGD variant (beyond-paper
    ablation).
  * Lines 15-16 — population model = uniform average of all node models.

The whole federation is a stacked pytree (leaves ``(N, ...)``); one round
is a single pure function: mixing-matrix build -> gossip mix -> vmapped
local step, all masked by the round's active vector.  Nodes therefore
simulate wall-clock asynchrony exactly (inactive nodes are frozen), while
the host sees a deterministic, reproducible program.

Engine design (the training hot path)
-------------------------------------
``_round`` is a pure ``FLState -> (FLState, loss)`` body, which makes the
multi-round engine a compiler problem rather than a host loop:

  * **Chunked scan** — :meth:`train_chunk` runs ``chunk`` rounds as ONE
    XLA program (``jax.lax.scan`` over ``_round``) and returns the
    stacked ``(chunk,)`` per-round losses, so the host synchronizes once
    per chunk instead of once per round.  The carried ``FLState`` buffers
    are donated (``donate_argnums``), so N-node parameter/optimizer
    state is updated in place across the whole chunk — no per-round
    host dispatch, no per-round device<->host ``float(loss)`` sync, no
    re-entry through the jit cache.
  * **Streaming eval** — cheap metrics no longer force the host between
    rounds: with ``eval_every > 0`` the scanned ``_round`` body carries a
    ``jax.lax.cond``-guarded eval branch on ``round % eval_every`` that
    computes val RMSE of the population model on a pre-batched
    validation set (scan constants), and ``train_chunk`` returns the
    stacked ``(chunk,)`` eval records next to the losses.  Rounds that
    don't hit the boundary pay only the cond's predicate.
  * **Loop fallback** — the original per-round Python loop survives ONLY
    behind the explicit ``engine="loop"`` debug flag (host callbacks with
    side effects, pdb between rounds).  Same numerics, one dispatch per
    round; it is never selected automatically.
  * **Mixer modes** — the gossip contraction dispatches on ``mixer``:
      - ``"tree"``     reference einsum per leaf (CPU default),
      - ``"kernel"``   Pallas VMEM-blocked kernel (interpret on CPU); the
        local-DP path fuses noise-broadcast + mix + clean-self-restore
        into the kernel's single pass over the (N, D) matrix
        (``gossip_mix_dp_kernel``) instead of three tree_maps,
      - ``"sharded"``  ``core.distributed.sharded_gossip_mix`` under a
        node-sharded mesh (``launch.mesh.make_federation_mesh``): the N
        federation rows split across devices and the mix runs as a real
        collective — the fleet-scale path, and it scans like the rest.
  * **Gossip representation** — orthogonal to the mixer,
    ``gossip_repr`` picks the mixing operator's storage: ``"dense"``
    contracts the (N, N) ``topology.mixing_matrix``; ``"sparse"`` uses
    the (N, B+1) neighbor table (``topology.neighbor_table``) whose
    densification is bitwise the same matrix, cutting the contraction
    from O(N²·D) to O(N·B·D).  Static topologies build the table from
    host-side candidate lists so no (N, N) array exists anywhere —
    federations of 10k+ nodes train where the dense path OOMs
    (``sparse-gossip-10k`` bench row).  ``"auto"`` defers to
    ``launch.mesh.choose_gossip_repr`` (sparse once B+1 ≪ N).  Every
    mixer has a sparse twin, including the fused DP kernel.

All RNG is threaded through ``FLState.key`` so every engine/mixer
combination consumes the identical key stream: ``train_chunk(chunk=k)``
matches k sequential ``_round`` calls to float tolerance (tested in
``tests/test_train_engine.py``), and inactive nodes stay bitwise frozen
across a chunk.

Scenario-sweep engine (the paper's ablation grids as ONE program)
-----------------------------------------------------------------
The Fig-4/Fig-5 ablations run the same trainer under many
(topology, inactive-ratio, seed) configurations.  :meth:`train_sweep`
batches that grid with ``jax.vmap`` over the scanned chunk instead of a
serial Python loop: a :class:`SweepGrid` carries every per-scenario knob
as DATA (stacked adjacency matrices + a per-round-resample flag from
``topology.stacked_adjacency``, ``(G,)`` inactive ratios, ``(G, 2)``
seed keys), so one compile executes all G scenarios and the streaming
eval branch returns a ``(G, chunk)`` record stack.  Scenario ``g``
consumes the identical key stream as ``train(PRNGKey(seed_g))`` under
the same config — swept results ARE the serial results, just batched
(``tests/test_sweep.py`` pins the parity; ``benchmarks/rounds_per_sec``
prices the speedup as the ``sweep-scan`` row).

Beyond the classic three axes the grid optionally sweeps the scenario
dimensions the paper's robustness story turns on, each as a traced
``(G,)`` array that arms independently (``SweepGrid.build(...,
schedules=, skews=, dp_sigmas=)``):

  * **Markov-sticky staleness** — per-scenario schedule choice between
    iid bernoulli participation and ``async_sched.markov_active``'s
    sticky busy/free chain.  Both schedules read the same single
    ``uniform(k_act, (N,))`` draw, so the choice is a ``jnp.where``
    select with zero key-stream drift; the serial twin is
    ``FLConfig(schedule="markov")``.
  * **Non-IID data skew** — node ``i`` trains on batches shifted by
    ``skew_g * data.synth.node_skew_offsets(N)[i]``; bitwise equal to
    training on host-pre-shifted arrays (gather commutes with the add),
    so the serial twin is a plain ``train()`` on skewed data
    (``FLConfig(data_skew=...)`` for the config-driven path).
  * **DP noise level** — the local-DP sigma as a traced scalar fed to
    ``_gossip_base``; the DP key split arms uniformly across a dp-armed
    grid so every scenario (including sigma=0) keeps one key stream,
    and the serial twin is ``GluADFL(dp_noise_sigma=sigma_g)``.

``tests/test_sweep_axes.py`` pins each axis against its serial twin
(losses, params, eval records, bitwise key chains) — those tests fail
if any axis' plumbing is reverted.

The sweep has a second engine for fleet scale: with ``mixer="sharded"``
the grid axis becomes a REAL mesh axis — the ``(G, N, ...)`` stacked
state lives on a 2-D ``("grid", "node")`` mesh
(``launch.mesh.make_sweep_mesh``), the vmap binds the scenario axis to
``"grid"`` (``spmd_axis_name``), and the gossip collectives inside the
round body stay scoped to ``"node"`` — so the memory-scaled psum
schedule keeps per-device state at O(G/grid · N/node · D) across the
whole grid (``sweep-sharded-psum`` bench row).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core.async_sched import bernoulli_active, markov_active, staleness_update
from repro.core.gossip_plan import MIXERS, resolve_gossip_plan
from repro.core.secure_agg import MASK_STREAM_TAG
from repro.core.topology import (
    neighbor_table_from_candidates,
    random_adjacency,
    round_adjacency,
    stacked_adjacency,
)
from repro.data.synth import node_skew_offsets
from repro.models.base import Model
from repro.optim import Optimizer
from repro.utils.pytree import tree_mean

PyTree = Any

# default scan-chunk length: long enough to amortize dispatch + the
# once-per-chunk loss sync, short enough that the first-compile cost and
# the host-side history granularity stay reasonable
DEFAULT_CHUNK = 32


def _to_host(v):
    """numpy copy of a per-chunk device output (losses, eval records).
    Multi-host global arrays are not fully addressable, so they come
    back through their replicated local shard instead of np.asarray."""
    if isinstance(v, jax.Array) and not v.is_fully_addressable:
        from repro.launch.multihost import fetch_replicated

        return fetch_replicated(v)
    return np.asarray(v)


@jax.tree_util.register_dataclass
@dataclass
class FLState:
    params: PyTree          # stacked (N, ...)
    opt_state: PyTree       # stacked (N, ...)
    staleness: jnp.ndarray  # (N,)
    round: jnp.ndarray      # scalar int
    key: jnp.ndarray


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "adjacency", "resample", "inactive_ratio", "init_keys",
        "markov", "skew", "dp_sigma",
    ),
    meta_fields=("labels",),
)
@dataclass
class SweepGrid:
    """A batch of G training scenarios for :meth:`GluADFL.train_sweep`.

    Every per-scenario knob the round body consumes is DATA, not
    structure, so the whole grid runs under one ``jax.vmap``:

      * ``adjacency``      — (G, N, N) static adjacency per scenario
                             (zeros placeholder for per-round-resampled
                             topologies), from ``topology.stacked_adjacency``;
      * ``resample``       — (G,) {0,1}: 1 = re-draw the graph each round
                             from that round's key ("random" topology);
      * ``inactive_ratio`` — (G,) Fig-5 asynchrony ratio per scenario;
      * ``init_keys``      — (G, 2) per-scenario PRNG init keys
                             (``PRNGKey(seed)`` — the exact key a serial
                             ``train(PRNGKey(seed), ...)`` run would use);
      * ``labels``         — static tuple naming scenario g for the host
                             side: ``(topology, ratio, seed)`` for a
                             classic 3-axis grid, ``(topology, ratio,
                             schedule, skew, dp_sigma, seed)`` once any
                             optional axis is armed (:meth:`label_dict`
                             normalizes either form).

    The optional scenario axes are each ``None`` (axis unarmed — the
    round body compiles the identical program as before the axis
    existed) or a ``(G,)`` array:

      * ``markov``         — {0,1}: 1 = Markov-sticky participation
                             (``async_sched.markov_active``) instead of
                             the bernoulli schedule;
      * ``skew``           — non-IID data-skew strength: node ``i``
                             trains on batches shifted by
                             ``skew * node_skew_offsets(N)[i]``;
      * ``dp_sigma``       — local-DP gossip noise sigma (traced; the
                             key stream arms the DP split for EVERY
                             scenario of a dp-armed grid).
    """

    adjacency: jnp.ndarray
    resample: jnp.ndarray
    inactive_ratio: jnp.ndarray
    init_keys: jnp.ndarray
    labels: tuple
    markov: jnp.ndarray | None = None
    skew: jnp.ndarray | None = None
    dp_sigma: jnp.ndarray | None = None

    @property
    def size(self) -> int:
        return len(self.labels)

    def label_dict(self, g: int) -> dict:
        """Scenario ``g``'s knobs as a dict, normalizing 3-tuple
        (classic grid) and 6-tuple (axis-armed grid) labels."""
        lab = self.labels[g]
        if len(lab) == 3:
            topo, ratio, seed = lab
            sched, skew, dp = "bernoulli", 0.0, 0.0
        else:
            topo, ratio, sched, skew, dp, seed = lab
        return {
            "topology": topo,
            "inactive_ratio": ratio,
            "schedule": sched,
            "skew": skew,
            "dp_sigma": dp,
            "seed": seed,
        }

    @classmethod
    def build(
        cls,
        topologies,
        inactive_ratios,
        seeds=(0,),
        *,
        num_nodes: int,
        cluster_size: int = 4,
        schedules=None,
        skews=None,
        dp_sigmas=None,
    ) -> "SweepGrid":
        """Cross-product grid (topology-major, then ratio, then
        schedule/skew/dp_sigma, seed innermost) — the paper's Fig-5
        layout: ``build(("ring","cluster","random"),
        (0.0, 0.3, 0.5, 0.7, 0.9), num_nodes=N)``.

        Each optional axis arms independently: ``None`` (default) keeps
        it out of the cross product AND out of the compiled program, so
        a classic grid stays bitwise the pre-axis engine.  Labels stay
        3-tuples unless some axis is armed (then 6-tuples)."""
        sched_ax = tuple(str(s) for s in schedules) if schedules else None
        if sched_ax is not None:
            bad = [s for s in sched_ax if s not in ("bernoulli", "markov")]
            if bad:
                raise ValueError(f"unknown schedule(s) {bad!r}")
        skew_ax = tuple(float(v) for v in skews) if skews else None
        dp_ax = tuple(float(v) for v in dp_sigmas) if dp_sigmas else None
        armed = any(ax is not None for ax in (sched_ax, skew_ax, dp_ax))
        scenarios = [
            (str(t), float(r), sc, sk, dp, int(s))
            for t in topologies
            for r in inactive_ratios
            for sc in (sched_ax or ("bernoulli",))
            for sk in (skew_ax or (0.0,))
            for dp in (dp_ax or (0.0,))
            for s in seeds
        ]
        if not scenarios:
            raise ValueError("empty sweep grid")
        adjacency, resample = stacked_adjacency(
            [t for t, *_ in scenarios], num_nodes, cluster_size
        )
        return cls(
            adjacency=adjacency,
            resample=resample,
            inactive_ratio=jnp.asarray([r for _, r, *_ in scenarios], jnp.float32),
            init_keys=jnp.stack(
                [jax.random.PRNGKey(s) for *_, s in scenarios]
            ),
            labels=tuple(
                scenarios
                if armed
                else [(t, r, s) for t, r, _, _, _, s in scenarios]
            ),
            markov=(
                None
                if sched_ax is None
                else jnp.asarray(
                    [1.0 if sc == "markov" else 0.0 for _, _, sc, _, _, _ in scenarios],
                    jnp.float32,
                )
            ),
            skew=(
                None
                if skew_ax is None
                else jnp.asarray([sk for _, _, _, sk, _, _ in scenarios], jnp.float32)
            ),
            dp_sigma=(
                None
                if dp_ax is None
                else jnp.asarray([dp for _, _, _, _, dp, _ in scenarios], jnp.float32)
            ),
        )


class GluADFL:
    """Asynchronous decentralized FL trainer (the paper's contribution)."""

    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        cfg: FLConfig,
        *,
        grad_at: str = "premix",
        use_kernel: bool = False,
        mixer: str | None = None,
        gossip_impl: str = "allgather",
        gossip_repr: str = "dense",
        dp_noise_sigma: float = 0.0,
        loss_fn: Callable | None = None,
        mesh=None,
    ):
        assert grad_at in ("premix", "mixed")
        # every gossip knob resolves HERE, once, into an explicit mixing
        # pipeline (core.gossip_plan): unknown values, unsupported
        # combinations and the deprecated use_kernel spelling all
        # surface at construction with the knob's name in the message
        self.plan = resolve_gossip_plan(
            mixer=mixer,
            use_kernel=use_kernel,
            gossip_impl=gossip_impl,
            gossip_repr=gossip_repr,
            dp_noise_sigma=dp_noise_sigma,
            mesh=mesh,
            num_nodes=cfg.num_nodes,
            comm_batch=cfg.comm_batch,
            topology=cfg.topology,
            cluster_size=cfg.cluster_size,
        )
        self.model = model
        self.optimizer = optimizer
        self.cfg = cfg
        self.grad_at = grad_at
        # resolved-knob mirrors, kept for back-compat introspection (the
        # plan is the source of truth)
        self.mixer = self.plan.mixer
        self.use_kernel = self.plan.use_kernel
        self.gossip_impl = self.plan.gossip_impl
        self.gossip_repr = self.plan.gossip_repr
        self._neighbor_cand = self.plan.neighbor_cand
        self.mesh = mesh                     # optional explicit mesh for "sharded"
        # BEYOND-PAPER: local differential privacy on the broadcast —
        # Gaussian noise is added to the parameters a node SHARES (its
        # own copy stays clean), so neighbours only ever see a noised
        # view.  sigma is in parameter units; the paper motivates privacy
        # but shares exact parameters — this closes that gap optionally.
        self.dp_noise_sigma = dp_noise_sigma
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        self._round_jit = jax.jit(
            self._round,
            static_argnames=("batch_size", "eval_every", "eval_fn", "mesh"),
        )
        self._chunk_jit = jax.jit(
            self._train_chunk,
            static_argnames=("batch_size", "chunk", "eval_every", "eval_fn", "mesh"),
            donate_argnums=(0,),
        )
        self._sweep_chunk_jit = jax.jit(
            self._sweep_chunk,
            static_argnames=("batch_size", "chunk", "eval_every", "eval_fn", "mesh"),
            donate_argnums=(0,),
        )
        self._sweep_init_jit = jax.jit(jax.vmap(self.init))
        self._sweep_pop_jit = jax.jit(jax.vmap(tree_mean))
        # canonical eval fns are jit-static: keep them identity-stable so
        # repeated train() calls hit the compile cache
        self._eval_wrappers: dict[int, Callable] = {}

    # ------------------------------------------------------------------
    def init(self, key, example_x=None) -> FLState:
        """Fresh federation state (``example_x`` is unused — the models
        init from shapes in their own config — and kept only for
        call-site back-compat)."""
        n = self.cfg.num_nodes
        keys = jax.random.split(key, n + 1)
        params = jax.vmap(self.model.init)(keys[:n])
        opt_state = jax.vmap(self.optimizer.init)(params)
        return FLState(
            params=params,
            opt_state=opt_state,
            staleness=jnp.zeros((n,), jnp.float32),
            round=jnp.zeros((), jnp.int32),
            key=keys[n],
        )

    def state_shardings(self, mesh) -> FLState:
        """NamedShardings for every ``FLState`` leaf under a node-sharded
        federation mesh: node-stacked leaves (params/opt-state leaves the
        vmapped init gave a leading ``(N, ...)`` axis, staleness) split
        over the mesh's first axis; the round counter and RNG key are
        replicated UNCONDITIONALLY (the key is shape ``(2,)`` and must
        never trip the leading-dim heuristic when ``num_nodes == 2``)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        n = self.cfg.num_nodes
        axis = mesh.axis_names[0]
        node = NamedSharding(mesh, P(axis))
        repl = NamedSharding(mesh, P())
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        stacked = lambda tree: jax.tree.map(
            lambda s: node if s.ndim >= 1 and s.shape[0] == n else repl, tree
        )
        return FLState(
            params=stacked(shapes.params),
            opt_state=stacked(shapes.opt_state),
            staleness=node,
            round=repl,
            key=repl,
        )

    def init_sharded(self, key, mesh) -> FLState:
        """Multi-host-safe init: the state is BORN node-sharded on the
        (possibly process-spanning) federation mesh — every process runs
        the same compiled init from a replicated key and only ever
        materializes its own node rows.  Single-process meshes work too
        (it is then just an explicitly-placed :meth:`init`)."""
        from repro.launch.multihost import replicate

        shardings = self.state_shardings(mesh)
        return jax.jit(self.init, out_shardings=shardings)(
            replicate(mesh, np.asarray(key))
        )

    def _sweep_state_shardings(self, mesh) -> FLState:
        """NamedShardings for the ``(G, N, ...)`` grid-stacked ``FLState``
        on a 2-D (grid, node) sweep mesh: node-stacked leaves split over
        BOTH axes, per-scenario scalars (round counter, key chain,
        non-node optimizer leaves) over the grid axis only.  Field-wise
        like :meth:`state_shardings` — the ``(G, 2)`` key must never
        trip the node heuristic when ``num_nodes == 2``."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        n = self.cfg.num_nodes
        g_ax, n_ax = mesh.axis_names
        gn = NamedSharding(mesh, P(g_ax, n_ax))
        g_only = NamedSharding(mesh, P(g_ax))
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        stacked = lambda tree: jax.tree.map(
            lambda s: gn if s.ndim >= 1 and s.shape[0] == n else g_only, tree
        )
        return FLState(
            params=stacked(shapes.params),
            opt_state=stacked(shapes.opt_state),
            staleness=gn,
            round=g_only,
            key=g_only,
        )

    def _place_sweep_data(self, mesh, grid: SweepGrid, x, y, counts, val_x, val_y):
        """Place the scenario grid + federation data for the swept-
        sharded engine: per-scenario arrays split over the grid axis,
        the (shared) federation data over the node axis, validation set
        replicated — so no device ever materializes rows it doesn't
        own before the compiled program even starts."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        g_ax, n_ax = mesh.axis_names
        g_only = NamedSharding(mesh, P(g_ax))
        node = NamedSharding(mesh, P(n_ax))
        repl = NamedSharding(mesh, P())
        put_ax = lambda v: None if v is None else jax.device_put(v, g_only)
        grid = SweepGrid(
            adjacency=jax.device_put(grid.adjacency, g_only),
            resample=jax.device_put(grid.resample, g_only),
            inactive_ratio=jax.device_put(grid.inactive_ratio, g_only),
            init_keys=jax.device_put(grid.init_keys, g_only),
            labels=grid.labels,
            markov=put_ax(grid.markov),
            skew=put_ax(grid.skew),
            dp_sigma=put_ax(grid.dp_sigma),
        )
        x, y, counts = (jax.device_put(v, node) for v in (x, y, counts))
        if val_x is not None:
            val_x, val_y = (jax.device_put(v, repl) for v in (val_x, val_y))
        return grid, x, y, counts, val_x, val_y

    # ------------------------------------------------------------------
    def _sample_batch(self, key, x_node, y_node, count, batch_size):
        """Uniform with-replacement batch from one node's (padded) data."""
        idx = jax.random.randint(key, (batch_size,), 0, jnp.maximum(count, 1))
        return x_node[idx], y_node[idx]

    def _local_step(
        self, key, params_premix, params_mixed, opt_state, x, y, count,
        shift=None, *, batch_size,
    ):
        """One (or more) local SGD steps for a single node.

        ``shift`` (a per-node scalar, or ``None``) is the non-IID skew
        axis: it offsets the sampled batch — inputs AND targets — in
        (normalized) glucose units.  Shifting the gathered batch is
        bitwise-identical to gathering from host-pre-shifted arrays
        (``(x + c)[idx] == x[idx] + c``), which is what makes a skewed
        scenario's serial twin a plain ``train()`` on shifted data."""

        def one_step(carry, k):
            p_for_grad, p_apply, st = carry
            bx, by = self._sample_batch(k, x, y, count, batch_size)
            if shift is not None:
                bx = bx + shift
                by = by + shift
            loss, grads = jax.value_and_grad(self.loss_fn)(p_for_grad, bx, by)
            new_p, new_st = self.optimizer.update(grads, st, p_apply)
            # subsequent local steps are ordinary SGD at the new params
            return (new_p, new_p, new_st), loss

        keys = jax.random.split(key, self.cfg.local_steps)
        first_grad_p = params_premix if self.grad_at == "premix" else params_mixed
        (p, _, st), losses = jax.lax.scan(
            one_step, (first_grad_p, params_mixed, opt_state), keys
        )
        return p, st, jnp.mean(losses)

    # ------------------------------------------------------------------
    def _mix_repr(self, adj: jnp.ndarray, active) -> Any:
        """The round's mixing operator in the plan's representation:
        dense (N, N) ``mixing_matrix`` or sparse ``(idx, wgt)``
        neighbor table (densifying the latter reproduces the former
        bitwise)."""
        return self.plan.build_repr(adj, active)

    def _plain_mix(self, stacked: PyTree, mix: Any, mesh=None, active=None) -> PyTree:
        """The plan's noise-free contraction.  ``mix`` is the dense
        matrix or the sparse ``(idx, wgt)`` table per the plan's repr;
        dense identity rows already encode inactivity, the sparse paths
        take ``active`` for a bit-exact where-select.  ``mesh`` overrides
        the plan's mesh — the swept-sharded path threads its 2-D
        (grid, node) mesh down here."""
        return self.plan.mix(stacked, mix, active, mesh=mesh)

    def _gossip(
        self, premix: PyTree, mix: Any, active, k_dp, mesh=None, mask_ctx=None,
        dp_sigma=None,
    ) -> PyTree:
        """Steps 2+3 (+ optional local-DP broadcast noise, + optional
        pairwise-masked secure aggregation) — the plan's full pipeline.
        ``mask_ctx`` is the ``(mask_key, (idx, wgt))`` pair ``_round``
        builds for ``gossip_impl="masked"``."""
        return self.plan.gossip(
            premix, mix, active, k_dp,
            mesh=mesh, mask_ctx=mask_ctx, dp_sigma=dp_sigma,
        )

    def _gossip_base(
        self, premix: PyTree, mix: Any, active, k_dp, mesh=None, dp_sigma=None
    ) -> PyTree:
        """The unmasked gossip: the plan pipeline without the mask stage
        (kept as a named seam — the parity tests drive it directly)."""
        return self.plan.gossip(premix, mix, active, k_dp, mesh=mesh,
                                dp_sigma=dp_sigma)

    # ------------------------------------------------------------------
    def _default_eval_metrics(self, pop_params, val_x, val_y):
        """Built-in streaming-eval metric: val RMSE of the population
        model on the pre-batched validation set (scan constants)."""
        pred = self.model.apply(pop_params, val_x)
        return {"val_rmse": jnp.sqrt(jnp.mean(jnp.square(pred - val_y)))}

    def _resolve_eval_fn(self, eval_fn: Callable | None) -> Callable:
        """Normalize to the canonical ``f(pop_params, val_x, val_y) ->
        dict`` signature.  ``None`` -> built-in val-RMSE; a legacy 1-arg
        ``f(pop_params)`` is wrapped (wrapper cached per fn so the jit
        static-arg cache keeps hitting)."""
        if eval_fn is None:
            return self._default_eval_metrics
        try:
            n_params = len(inspect.signature(eval_fn).parameters)
        except (TypeError, ValueError):
            n_params = 3
        if n_params != 1:
            return eval_fn
        key = id(eval_fn)
        if key not in self._eval_wrappers:
            # bounded: each cached wrapper pins its eval_fn (which also
            # keeps the id stable) and is a distinct jit static arg, so a
            # long-lived sweep over fresh lambdas must not grow forever
            if len(self._eval_wrappers) >= 64:
                self._eval_wrappers.clear()
            self._eval_wrappers[key] = lambda pop, vx, vy: eval_fn(pop)
        return self._eval_wrappers[key]

    def _eval_metrics(self, params, new_round, val_x, val_y, eval_every, eval_fn):
        """The cond-guarded in-scan eval branch: at ``new_round %
        eval_every == 0`` boundaries compute ``eval_fn(population, val_x,
        val_y)``; off-boundary rounds return the same dict filled with
        NaN (the host-side sentinel) and pay only the predicate — the
        population average itself lives INSIDE the true branch, so
        off-boundary rounds skip the O(N·D) reduction too."""

        def run_eval(op):
            p, vx, vy = op
            return eval_fn(tree_mean(p), vx, vy)

        operand = (params, val_x, val_y)
        shapes = jax.eval_shape(run_eval, operand)
        if not isinstance(shapes, dict):
            raise TypeError(
                f"streaming eval_fn must return a dict of float scalars, "
                f"got {type(shapes).__name__}"
            )
        for k, s in shapes.items():
            if not (jnp.issubdtype(s.dtype, jnp.floating) and s.shape == ()):
                raise TypeError(
                    f"streaming eval_fn output {k!r} must be a floating "
                    f"SCALAR (NaN is the off-boundary sentinel and the "
                    f"history records floats), got {s.dtype}{s.shape}"
                )
        return jax.lax.cond(
            new_round % eval_every == 0,
            run_eval,
            lambda op: jax.tree.map(
                lambda s: jnp.full(s.shape, jnp.nan, s.dtype), shapes
            ),
            operand,
        )

    # ------------------------------------------------------------------
    def _round(
        self,
        state: FLState,
        x,
        y,
        counts,
        val_x=None,
        val_y=None,
        scenario=None,
        *,
        batch_size: int,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
        mesh=None,
    ):
        """One FL round as a pure ``FLState -> (FLState, aux)`` body —
        directly scannable (train_chunk) and jit-able (loop engine).
        ``aux`` is the scalar loss, or ``(loss, metrics_dict)`` when the
        streaming-eval branch is armed (``eval_every > 0`` with an
        ``eval_fn``).

        ``scenario`` is ``None`` for the config-driven path, or a traced
        ``(adjacency (N,N), resample scalar, inactive_ratio scalar)``
        triple — optionally extended to a 6-tuple ``(..., markov, skew,
        dp_sigma)`` whose last three entries are each ``None`` (axis
        unarmed; the identical program as the triple) or a traced
        per-scenario scalar — overriding the config's topology/
        asynchrony/heterogeneity/privacy knobs.  The sweep engine vmaps
        this body over a stacked grid of such tuples.  The key stream is
        IDENTICAL either way: every round splits the same four subkeys;
        the markov and bernoulli schedules consume the SAME single
        ``uniform(k_act, (N,))`` draw, and the DP split is armed
        uniformly across a dp-armed grid — so a swept scenario
        reproduces its serial twin (``schedule=cfg.schedule``,
        ``data_skew``, ``dp_noise_sigma=sigma_g``) exactly.

        ``mesh`` (static) overrides the sharded mixer's mesh — the
        swept-sharded path threads the 2-D (grid, node) sweep mesh down
        to the gossip contraction; ``None`` keeps ``self.mesh`` /
        the default federation mesh."""
        cfg = self.cfg
        n = cfg.num_nodes
        key, k_act, k_top, k_batch = jax.random.split(state.key, 4)

        sc_markov = sc_skew = sc_dp = None
        if scenario is not None and len(scenario) == 6:
            adj_static, resample, inactive_ratio, sc_markov, sc_skew, sc_dp = scenario
        elif scenario is not None:
            adj_static, resample, inactive_ratio = scenario

        # a node that ended last round with staleness 0 participated in
        # it — the markov chain's previous state, derivable in the swept
        # and serial paths alike (staleness is carried in FLState)
        prev_active = (state.staleness == 0).astype(jnp.float32)
        adj = None  # stays None on the sparse static-topology fast path
        if scenario is None:
            if cfg.schedule == "markov":
                active = markov_active(
                    k_act, prev_active, cfg.p_stay_active, cfg.p_stay_inactive
                )
            else:
                active = bernoulli_active(k_act, n, cfg.inactive_ratio)
            if self._neighbor_cand is not None:
                # sparse static topology: table straight from the host-
                # built candidate lists — no (N, N) array in the program
                cand_idx, cand_valid = self._neighbor_cand
                mix = neighbor_table_from_candidates(
                    cand_idx, cand_valid, active, cfg.comm_batch
                )
            else:
                adj = round_adjacency(
                    cfg.topology, n, k_top, cfg.comm_batch, cfg.cluster_size
                )
                mix = self._mix_repr(adj, active)
        else:
            if sc_markov is None:
                active = bernoulli_active(k_act, n, inactive_ratio)
            else:
                # per-scenario schedule choice as a select: both masks
                # read the SAME uniform(k_act, (N,)) draw, so arming the
                # axis never shifts the main key chain
                active = jnp.where(
                    sc_markov > 0,
                    markov_active(
                        k_act, prev_active, cfg.p_stay_active, cfg.p_stay_inactive
                    ),
                    bernoulli_active(k_act, n, inactive_ratio),
                )
            # both graph flavours are cheap relative to the local step, so
            # the data-dependent choice is a select, not a cond: random
            # topologies draw from the SAME k_top a serial run would use
            adj = jnp.where(
                resample > 0,
                random_adjacency(k_top, n, min(cfg.comm_batch, n - 1)),
                adj_static,
            )
            mix = self._mix_repr(adj, active)

        premix = state.params
        k_dp = None
        if sc_dp is not None or self.dp_noise_sigma > 0.0:
            key, k_dp = jax.random.split(key)
        mask_ctx = None
        if self.plan.masked:
            # the mask stream is FOLDED off the round key, never split:
            # enabling secure aggregation must not perturb the
            # activity/topology/batch/DP key chain (the bitwise-parity
            # contract)
            k_mask = jax.random.fold_in(state.key, MASK_STREAM_TAG)
            mask_ctx = (k_mask, self.plan.mask_table(mix, adj, active))
        mixed = self._gossip(premix, mix, active, k_dp, mesh, mask_ctx, sc_dp)

        node_keys = jax.random.split(k_batch, n)
        step = partial(self._local_step, batch_size=batch_size)
        if sc_skew is None and cfg.data_skew == 0.0:
            new_params, new_opt, losses = jax.vmap(step)(
                node_keys, premix, mixed, state.opt_state, x, y, counts
            )
        else:
            # per-node batch shift: the offsets are a trace-time constant
            # table, scaled by the (possibly traced) scenario skew
            skew = cfg.data_skew if sc_skew is None else sc_skew
            shift = skew * jnp.asarray(node_skew_offsets(n))
            new_params, new_opt, losses = jax.vmap(step)(
                node_keys, premix, mixed, state.opt_state, x, y, counts, shift
            )

        # inactive nodes keep their stale params / optimizer state.
        # jnp.where (not arithmetic blending) so inactive rows are BITWISE
        # copies and integer leaves (optimizer step) keep their dtype —
        # the scan carry must be type-stable across rounds.
        def mask(new, old):
            bshape = (n,) + (1,) * (new.ndim - 1)
            a = active.reshape(bshape) > 0
            return jnp.where(a, new, old)

        params = jax.tree.map(mask, new_params, premix)
        opt_state = jax.tree.map(
            lambda nw, od: mask(nw, od) if nw.ndim >= 1 and nw.shape[:1] == (n,) else nw,
            new_opt,
            state.opt_state,
        )
        loss = jnp.sum(losses * active) / jnp.maximum(jnp.sum(active), 1.0)
        new_round = state.round + 1
        aux = loss
        if eval_every and eval_fn is not None:
            metrics = self._eval_metrics(
                params, new_round, val_x, val_y, eval_every, eval_fn
            )
            aux = (loss, metrics)
        return (
            FLState(
                params=params,
                opt_state=opt_state,
                staleness=staleness_update(state.staleness, active),
                round=new_round,
                key=key,
            ),
            aux,
        )

    # ------------------------------------------------------------------
    def _train_chunk(
        self,
        state: FLState,
        x,
        y,
        counts,
        val_x=None,
        val_y=None,
        scenario=None,
        *,
        batch_size: int,
        chunk: int,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
        mesh=None,
    ):
        def body(st, _):
            return self._round(
                st, x, y, counts, val_x, val_y, scenario,
                batch_size=batch_size, eval_every=eval_every, eval_fn=eval_fn,
                mesh=mesh,
            )

        return jax.lax.scan(body, state, None, length=chunk)

    def _sweep_chunk(
        self,
        states: FLState,
        adjacency,
        resample,
        inactive_ratio,
        extras,
        x,
        y,
        counts,
        val_x=None,
        val_y=None,
        *,
        batch_size: int,
        chunk: int,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
        mesh=None,
    ):
        """``chunk`` rounds of EVERY scenario as one vmapped scan: the
        grid axis G batches the whole ``_train_chunk`` program (states,
        adjacencies, resample flags and inactive ratios all carry a
        leading G), while the federation data/validation set broadcast
        unbatched.  ``extras`` is a dict holding whichever optional
        scenario axes are armed (``"markov"``/``"skew"``/``"dp_sigma"``,
        each ``(G,)``) — an empty dict compiles the identical program as
        the classic 3-axis grid.  Returns ``(states, losses (G, chunk))``
        — plus a metrics dict of ``(G, chunk)`` records when eval is
        armed.

        Mixer dispatch: the tree mixer is a plain ``jax.vmap``.  The
        SHARDED mixer instead binds the vmapped axis to the 2-D sweep
        mesh's ``"grid"`` axis (``spmd_axis_name``): the per-scenario
        shard_map collectives inside ``_round`` keep their node-only
        axis names, and the batching rule turns them into ONE shard_map
        over the (grid, node) mesh whose in_specs gain a leading
        ``P("grid", ...)`` — the grid axis batches, the node axis
        communicates, and no collective crosses scenarios."""

        def one(state, adj, rs, ratio, extra):
            sc = (
                adj, rs, ratio,
                extra.get("markov"), extra.get("skew"), extra.get("dp_sigma"),
            )
            return self._train_chunk(
                state, x, y, counts, val_x, val_y, sc,
                batch_size=batch_size, chunk=chunk,
                eval_every=eval_every, eval_fn=eval_fn, mesh=mesh,
            )

        if self.plan.uses_mesh:
            return jax.vmap(one, spmd_axis_name=mesh.axis_names[0])(
                states, adjacency, resample, inactive_ratio, extras
            )
        return jax.vmap(one)(states, adjacency, resample, inactive_ratio, extras)

    def train_chunk(
        self,
        state: FLState,
        x,
        y,
        counts,
        *,
        batch_size: int = 64,
        chunk: int = DEFAULT_CHUNK,
        val_x=None,
        val_y=None,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
    ) -> tuple[FLState, Any]:
        """Run ``chunk`` rounds as one compiled ``lax.scan`` program.

        Returns ``(new_state, losses)`` with ``losses.shape == (chunk,)``
        (per-round mean active loss, still on device — the caller decides
        when to sync).  With the streaming-eval branch armed
        (``eval_every > 0`` and an ``eval_fn``), returns
        ``(new_state, (losses, metrics))`` where ``metrics`` is a dict of
        ``(chunk,)`` arrays that hold the eval values at
        ``round % eval_every == 0`` boundaries and NaN elsewhere —
        eval never leaves the compiled program.  ``eval_fn`` must be the
        canonical traceable ``f(pop_params, val_x, val_y) -> dict``
        (see :meth:`_resolve_eval_fn`).  The input ``state``'s buffers
        are DONATED: do not reuse it after the call.  Recompiles once per
        distinct ``(batch_size, chunk, eval_every, eval_fn)`` tuple.
        """
        return self._chunk_jit(
            state, x, y, counts, val_x, val_y,
            batch_size=batch_size, chunk=chunk,
            eval_every=eval_every, eval_fn=eval_fn,
        )

    # ------------------------------------------------------------------
    def train(
        self,
        key,
        x,
        y,
        counts,
        *,
        batch_size: int = 64,
        rounds: int | None = None,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
        val_data: tuple | None = None,
        chunk: int | None = None,
        engine: str = "scan",
    ):
        """Run T rounds; returns (population_params, history, state).

        Engine selection:

        * ``engine="scan"`` (default — the one production path): chunked
          ``train_chunk`` programs, one host sync per chunk, WITH OR
          WITHOUT eval.  ``eval_every > 0`` arms the in-scan streaming
          eval branch: ``eval_fn`` must be pure/traceable —
          ``f(pop_params, val_x, val_y) -> dict`` of float scalars (a
          legacy 1-arg ``f(pop_params)`` is auto-wrapped); with
          ``eval_fn=None`` and ``val_data=(val_x, val_y)`` the built-in
          population val-RMSE is used.  Eval values surface in the
          history at each boundary, same as the loop engine's records.
        * ``engine="loop"`` — explicit DEBUG fallback only (never
          selected automatically): per-round Python loop, one jit
          dispatch + host sync per round; ``eval_fn`` may be an
          arbitrary host callback (side effects, non-traceable code).
          Single-process only (its eval callback runs EAGERLY on the
          population params, which are not addressable across hosts).

        Multi-host: after ``launch.multihost.initialize`` this method is
        process-count aware — it requires ``mixer="sharded"``, places
        host-side ``x/y/counts`` node-sharded on the global federation
        mesh (each process materializes only its own rows; pre-placed
        global ``jax.Array`` inputs are used as-is), replicates the
        validation set, and inits the state with :meth:`init_sharded`.
        Every process runs the identical program and assembles the
        identical history from the replicated per-round losses.

        History is identical either way: one record per round, eval keys
        merged into the boundary rounds' records.
        """
        assert engine in ("scan", "loop"), engine
        rounds = rounds if rounds is not None else self.cfg.rounds
        multihost = jax.process_count() > 1
        if multihost:
            if engine == "loop":
                raise NotImplementedError(
                    "engine='loop' is the single-process debug fallback; "
                    "multi-host runs use the scan engine"
                )
            self.plan.require_multihost()
            from repro.core.distributed import _default_federation_mesh
            from repro.launch.multihost import place_federation

            mesh = self.mesh or _default_federation_mesh(self.cfg.num_nodes)
            if not (isinstance(x, jax.Array) and not x.is_fully_addressable):
                x, y, counts, val_data = place_federation(
                    mesh, x, y, counts, val_data
                )
            val_x, val_y = val_data if val_data is not None else (None, None)
            state = self.init_sharded(key, mesh)
        else:
            x, y = jnp.asarray(x), jnp.asarray(y)
            counts = jnp.asarray(counts)
            val_x = val_y = None
            if val_data is not None:
                val_x, val_y = (jnp.asarray(v) for v in val_data)
            state = self.init(key)
        do_eval = bool(eval_every) and (eval_fn is not None or val_data is not None)
        history: list[dict] = []

        if engine == "loop":
            resolved = self._resolve_eval_fn(eval_fn) if do_eval else None
            for t in range(rounds):
                state, loss = self._round_jit(state, x, y, counts, batch_size=batch_size)
                rec = {"round": t, "loss": float(loss)}
                if do_eval and (t + 1) % eval_every == 0:
                    out = resolved(self.population(state), val_x, val_y)
                    rec.update(
                        {k: (float(v) if hasattr(v, "item") else v)
                         for k, v in out.items()}
                    )
                history.append(rec)
            return self.population(state), history, state

        chunk = max(1, min(chunk or DEFAULT_CHUNK, rounds))
        full, rem = divmod(rounds, chunk)
        t = 0
        if do_eval:
            resolved = self._resolve_eval_fn(eval_fn)
            # the tail also runs as a (shorter) scan so eval stays inside
            # the compiled program for every round
            for c in [chunk] * full + ([rem] if rem else []):
                state, (losses, metrics) = self.train_chunk(
                    state, x, y, counts, batch_size=batch_size, chunk=c,
                    val_x=val_x, val_y=val_y,
                    eval_every=eval_every, eval_fn=resolved,
                )
                # ONE host sync per chunk, eval records included
                losses = _to_host(losses)
                metrics = {k: _to_host(v) for k, v in metrics.items()}
                for i in range(c):
                    rec = {"round": t + i, "loss": float(losses[i])}
                    if (t + i + 1) % eval_every == 0:
                        rec.update({k: float(v[i]) for k, v in metrics.items()})
                    history.append(rec)
                t += c
            return self.population(state), history, state

        for _ in range(full):
            state, losses = self.train_chunk(
                state, x, y, counts, batch_size=batch_size, chunk=chunk
            )
            # ONE host sync per chunk (vs one per round in the loop engine)
            for i, lv in enumerate(_to_host(losses).tolist()):
                history.append({"round": t + i, "loss": lv})
            t += chunk
        if rem and multihost:
            # the tail must stay a compiled scan: the per-round jit's
            # float(loss) sync can't read a cross-process scalar eagerly
            state, losses = self.train_chunk(
                state, x, y, counts, batch_size=batch_size, chunk=rem
            )
            for i, lv in enumerate(_to_host(losses).tolist()):
                history.append({"round": t + i, "loss": lv})
            t += rem
        elif rem:
            # drain the tail through the per-round jit: rem < chunk rounds
            # are not worth compiling a second whole-scan program for
            for _ in range(rem):
                state, loss = self._round_jit(state, x, y, counts, batch_size=batch_size)
                history.append({"round": t, "loss": float(loss)})
                t += 1
        return self.population(state), history, state

    # ------------------------------------------------------------------
    def train_sweep(
        self,
        x,
        y,
        counts,
        *,
        grid: SweepGrid,
        batch_size: int = 64,
        rounds: int | None = None,
        chunk: int | None = None,
        eval_every: int = 0,
        eval_fn: Callable | None = None,
        val_data: tuple | None = None,
    ):
        """Train EVERY scenario of ``grid`` as one batched device
        program; returns ``(populations, histories, states)``.

        This is the scenario-sweep engine: the per-round body is vmapped
        over the grid axis G — topologies enter as stacked per-scenario
        adjacency matrices (+ a resample flag for per-round random
        graphs), inactive ratios and seeds as plain ``(G,)``/``(G, 2)``
        arrays — so the whole Fig-4/Fig-5 grid compiles ONCE per chunk
        shape and executes as a single XLA program instead of G serial
        ``train()`` runs.  What is vmapped vs scan-carried:

          * vmapped (leading G): FLState leaves, adjacency, resample,
            inactive ratio, every per-round loss/eval record;
          * scan-carried (inside each scenario): the round counter, RNG
            key chain, staleness — exactly as in :meth:`train_chunk`;
          * broadcast (no G axis): the federation data ``x/y/counts``
            and the pre-batched validation set.

        Scenario ``g`` consumes the IDENTICAL key stream as a serial
        ``train(PRNGKey(seed_g), ...)`` run of the same config — the
        parity test pins this — so the sweep is a pure re-batching, not
        a re-definition, of the experiment.

        Returns:
          * ``populations`` — population params stacked ``(G, ...)``
            (index one out with ``utils.pytree.tree_index``);
          * ``histories`` — list of G per-scenario history lists, each
            record-compatible with :meth:`train` (eval keys merged into
            boundary rounds);
          * ``states`` — final ``FLState`` stacked ``(G, ...)``.

        Mixer dispatch — the sweep has two engines:

          * ``mixer="tree"`` — plain ``jax.vmap`` of the reference
            einsum path (the single-device default);
          * ``mixer="sharded"`` — the grid becomes a REAL mesh axis: the
            ``(G, N, ...)`` stacked state is placed on a 2-D
            ``("grid", "node")`` mesh (``self.mesh`` if given, else
            ``launch.mesh.make_sweep_mesh``), scenarios batch over
            ``"grid"`` while the gossip collectives (all-gather /
            psum-scatter, per ``gossip_impl``) stay scoped to
            ``"node"`` — per-device memory O(G/grid · N/node · D) with
            the psum schedule, so paper-scale federations sweep without
            any device holding the whole grid.

        Single-process only; the Pallas kernel mixer is a per-scenario
        program and still refuses (run it through serial :meth:`train`).
        """
        if jax.process_count() > 1:
            raise NotImplementedError(
                "train_sweep batches scenarios on ONE process; multi-host "
                "runs sweep via serial train() per scenario"
            )
        self.plan.require_sweep()
        n = self.cfg.num_nodes
        if grid.adjacency.shape[-1] != n:
            raise ValueError(
                f"grid built for N={grid.adjacency.shape[-1]} nodes but "
                f"cfg.num_nodes={n}"
            )
        rounds = rounds if rounds is not None else self.cfg.rounds
        x, y = jnp.asarray(x), jnp.asarray(y)
        counts = jnp.asarray(counts)
        val_x = val_y = None
        if val_data is not None:
            val_x, val_y = (jnp.asarray(v) for v in val_data)
        do_eval = bool(eval_every) and (eval_fn is not None or val_data is not None)
        resolved = self._resolve_eval_fn(eval_fn) if do_eval else None

        mesh = None
        if self.plan.uses_mesh:
            from repro.launch.mesh import make_sweep_mesh

            mesh = self.mesh or make_sweep_mesh(grid.size, n)
            if mesh.axis_names != ("grid", "node"):
                # the names are the contract: the gossip layer scopes its
                # collectives to "node" and batches over "grid" by name
                raise ValueError(
                    f"swept-sharded training needs a 2-D ('grid', 'node') "
                    f"mesh (launch.mesh.make_sweep_mesh), got axes "
                    f"{mesh.axis_names}"
                )
            g_ax, n_ax = mesh.axis_names
            if grid.size % mesh.shape[g_ax] or n % mesh.shape[n_ax]:
                raise ValueError(
                    f"sweep mesh {dict(mesh.shape)} does not divide the grid: "
                    f"G={grid.size}, N={n}"
                )

        states = self._sweep_init_jit(grid.init_keys)
        if mesh is not None:
            states = jax.device_put(states, self._sweep_state_shardings(mesh))
            grid, x, y, counts, val_x, val_y = self._place_sweep_data(
                mesh, grid, x, y, counts, val_x, val_y
            )
        g_count = grid.size
        # only armed axes enter the program: an unarmed grid's extras
        # dict is empty and the compiled sweep is the classic one
        extras = {
            k: v
            for k, v in (
                ("markov", grid.markov),
                ("skew", grid.skew),
                ("dp_sigma", grid.dp_sigma),
            )
            if v is not None
        }
        histories: list[list[dict]] = [[] for _ in range(g_count)]
        chunk = max(1, min(chunk or DEFAULT_CHUNK, rounds))
        full, rem = divmod(rounds, chunk)
        t = 0
        for c in [chunk] * full + ([rem] if rem else []):
            states, aux = self._sweep_chunk_jit(
                states, grid.adjacency, grid.resample, grid.inactive_ratio,
                extras, x, y, counts, val_x, val_y,
                batch_size=batch_size, chunk=c,
                eval_every=eval_every if do_eval else 0,
                eval_fn=resolved, mesh=mesh,
            )
            # ONE host sync per chunk for the WHOLE grid
            if do_eval:
                losses, metrics = aux
                metrics = {k: np.asarray(v) for k, v in metrics.items()}
            else:
                losses, metrics = aux, {}
            losses = np.asarray(losses)  # (G, c)
            for g in range(g_count):
                for i in range(c):
                    rec = {"round": t + i, "loss": float(losses[g, i])}
                    if do_eval and (t + i + 1) % eval_every == 0:
                        rec.update(
                            {k: float(v[g, i]) for k, v in metrics.items()}
                        )
                    histories[g].append(rec)
            t += c
        return self._sweep_pop_jit(states.params), histories, states

    # ------------------------------------------------------------------
    @staticmethod
    def population(state: FLState) -> PyTree:
        """Algorithm 1 lines 15-16: uniform average of all node models.

        Multi-host-safe: node-sharded params are reduced inside a jit
        (eager jnp ops refuse arrays that are not fully addressable);
        the result is replicated, so every process can fetch it."""
        leaves = jax.tree.leaves(state.params)
        if leaves and isinstance(leaves[0], jax.Array) and not leaves[0].is_fully_addressable:
            return jax.jit(tree_mean)(state.params)
        return tree_mean(state.params)
