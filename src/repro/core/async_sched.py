"""Wait-free (asynchronous) participation scheduling (paper §3.3, Fig 5).

The paper's asynchrony is wall-clock: slow/busy phones drop out of rounds
and rejoin at will.  Inside one SPMD program the statistically equivalent
object is the per-round *active mask*; inactive nodes neither communicate
nor train that round (their mixing row is the identity and their SGD step
is masked out), i.e. they hold stale parameters until they rejoin —
exactly the SWIFT-style wait-free semantics the paper adopts.

Schedules provided:
  * bernoulli   — iid node activity, P(active) = 1 - inactive_ratio
                  (what the paper sweeps in Fig 5),
  * markov      — sticky busy/free states (a phone that is busy tends to
                  stay busy), for the beyond-paper staleness study,
  * round_robin — deterministic fraction active, for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bernoulli_active(key, n: int, inactive_ratio) -> jnp.ndarray:
    """iid active mask; ``inactive_ratio`` may be a python float OR a
    traced scalar (the sweep engine vmaps it over scenarios).  The
    concrete ``<= 0`` shortcut and the traced ``u >= ratio`` path agree
    exactly: uniform draws live in [0, 1), so ratio 0 activates every
    node either way."""
    if isinstance(inactive_ratio, (int, float)) and inactive_ratio <= 0.0:
        return jnp.ones((n,), jnp.float32)
    u = jax.random.uniform(key, (n,))
    active = (u >= inactive_ratio).astype(jnp.float32)
    # guarantee >= 1 active node (the round is a no-op otherwise)
    any_active = jnp.max(active)
    fallback = jnp.zeros((n,)).at[jnp.argmax(u)].set(1.0)
    return jnp.where(any_active > 0, active, fallback)


def sweep_active_masks(key, n: int, inactive_ratios: jnp.ndarray) -> jnp.ndarray:
    """Per-scenario active masks from split keys: one independent key
    per scenario, each drawing its :func:`bernoulli_active` mask at its
    own (possibly traced) ratio.  Returns ``(G, N)``; scenario ``g``
    matches ``bernoulli_active(split(key, G)[g], n, ratios[g])``
    bitwise.

    This is the grid-level/host-side sampler (activity analyses,
    schedule visualisation, tests).  Inside ``GluADFL.train_sweep``
    itself the masks are NOT drawn here: each scenario's round body
    calls ``bernoulli_active`` on its own scan-carried key chain under
    ``jax.vmap`` — which is what makes a swept scenario's key stream
    identical to its serial twin's."""
    inactive_ratios = jnp.asarray(inactive_ratios)
    keys = jax.random.split(key, inactive_ratios.shape[0])
    return jax.vmap(lambda k, r: bernoulli_active(k, n, r))(keys, inactive_ratios)


def markov_active(key, prev_active: jnp.ndarray, p_stay_active=0.9, p_stay_inactive=0.7):
    """Sticky busy/free chain: a node active (inactive) last round stays
    active with ``p_stay_active`` (activates with ``1 - p_stay_inactive``).
    Same ≥1-active guarantee as :func:`bernoulli_active` — a sticky
    all-busy draw would otherwise make the round a silent global no-op
    (and, at ``p_stay_inactive=1``, an absorbing state no later round
    escapes)."""
    u = jax.random.uniform(key, prev_active.shape)
    stay = jnp.where(prev_active > 0, p_stay_active, 1.0 - p_stay_inactive)
    active = (u < stay).astype(jnp.float32)
    any_active = jnp.max(active)
    # the node closest to its activation threshold flips on
    fallback = jnp.zeros_like(active).at[jnp.argmin(u - stay)].set(1.0)
    return jnp.where(any_active > 0, active, fallback)


def round_robin_active(t: int, n: int, active_fraction: float) -> jnp.ndarray:
    k = max(1, int(n * active_fraction))
    idx = (jnp.arange(k) + t * k) % n
    return jnp.zeros((n,), jnp.float32).at[idx].set(1.0)


def staleness_update(staleness: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Rounds since each node last participated (0 when active)."""
    return (staleness + 1) * (1 - active)
