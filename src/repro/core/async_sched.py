"""Wait-free (asynchronous) participation scheduling (paper §3.3, Fig 5).

The paper's asynchrony is wall-clock: slow/busy phones drop out of rounds
and rejoin at will.  Inside one SPMD program the statistically equivalent
object is the per-round *active mask*; inactive nodes neither communicate
nor train that round (their mixing row is the identity and their SGD step
is masked out), i.e. they hold stale parameters until they rejoin —
exactly the SWIFT-style wait-free semantics the paper adopts.

Schedules provided:
  * bernoulli   — iid node activity, P(active) = 1 - inactive_ratio
                  (what the paper sweeps in Fig 5),
  * markov      — sticky busy/free states (a phone that is busy tends to
                  stay busy), for the beyond-paper staleness study,
  * round_robin — deterministic fraction active, for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bernoulli_active(key, n: int, inactive_ratio: float) -> jnp.ndarray:
    if inactive_ratio <= 0.0:
        return jnp.ones((n,), jnp.float32)
    u = jax.random.uniform(key, (n,))
    active = (u >= inactive_ratio).astype(jnp.float32)
    # guarantee >= 1 active node (the round is a no-op otherwise)
    any_active = jnp.max(active)
    fallback = jnp.zeros((n,)).at[jnp.argmax(u)].set(1.0)
    return jnp.where(any_active > 0, active, fallback)


def markov_active(key, prev_active: jnp.ndarray, p_stay_active=0.9, p_stay_inactive=0.7):
    u = jax.random.uniform(key, prev_active.shape)
    stay = jnp.where(prev_active > 0, p_stay_active, 1.0 - p_stay_inactive)
    return (u < stay).astype(jnp.float32)


def round_robin_active(t: int, n: int, active_fraction: float) -> jnp.ndarray:
    k = max(1, int(n * active_fraction))
    idx = (jnp.arange(k) + t * k) % n
    return jnp.zeros((n,), jnp.float32).at[idx].set(1.0)


def staleness_update(staleness: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Rounds since each node last participated (0 when active)."""
    return (staleness + 1) * (1 - active)
