"""Communication topologies (paper §3.3) as adjacency / mixing matrices.

A topology yields, per round t, an adjacency matrix A_t in {0,1}^(N,N)
(no self loops).  Combined with the round's active mask, it becomes the
row-stochastic mixing matrix M_t of Algorithm 1 lines 7-9:

    M[n]  = uniform over ({n} ∪ active neighbours of n, capped at B)    if n active
    M[n]  = e_n (identity row — keeps its stale model)                   if n inactive

``mixing_matrix`` is pure-jnp so whole FL rounds jit/scan; the static
topologies (ring, cluster, star, full) are constants, the random topology
re-samples each round from a PRNG key.

Because each row of M_t has at most ``comm_batch + 1`` nonzeros, the
dense (N, N) matrix is pure waste at population scale.  The sparse
*neighbor table* twin — :func:`neighbor_table` and friends — represents
the same M_t as ``(idx, wgt)`` arrays of shape (N, B+1): slot 0 is
always self, slots 1..B hold the kept active neighbours in ascending
column order, and padding slots point back at self with weight 0.
:func:`densify_neighbor_table` recovers the dense matrix bitwise, which
is the contract every sparse consumer is tested against.
:func:`neighbor_candidates` builds static per-node candidate lists on
the host so ring/cluster/star federations never materialize an (N, N)
array at all — the O(N·B) path to population-scale N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ring_adjacency(n: int) -> jnp.ndarray:
    """Each node talks to its two ring neighbours."""
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, (i + 1) % n] = 1
        a[i, (i - 1) % n] = 1
    if n <= 2:
        np.fill_diagonal(a, 0)
    return jnp.asarray(a)


def cluster_adjacency(n: int, cluster_size: int = 4) -> jnp.ndarray:
    """Fully-connected clusters arranged on a ring; one bridge node links
    each cluster to the next (SWIFT-style cluster-ring)."""
    a = np.zeros((n, n), np.float32)
    n_clusters = max(1, -(-n // cluster_size))
    for c in range(n_clusters):
        lo, hi = c * cluster_size, min((c + 1) * cluster_size, n)
        for i in range(lo, hi):
            for j in range(lo, hi):
                if i != j:
                    a[i, j] = 1
        # bridge: last member of this cluster <-> first member of next
        nxt = ((c + 1) % n_clusters) * cluster_size
        if hi - 1 != nxt:
            a[hi - 1, nxt] = 1
            a[nxt, hi - 1] = 1
    return jnp.asarray(a)


def star_adjacency(n: int) -> jnp.ndarray:
    """FedAvg's topology: node 0 is the server."""
    a = np.zeros((n, n), np.float32)
    a[0, 1:] = 1
    a[1:, 0] = 1
    return jnp.asarray(a)


def full_adjacency(n: int) -> jnp.ndarray:
    return jnp.ones((n, n), jnp.float32) - jnp.eye(n, dtype=jnp.float32)


def random_adjacency(key, n: int, degree: int) -> jnp.ndarray:
    """Time-varying random graph: each node draws ``degree`` distinct
    peers; symmetrized (paper: up to B random connections per round)."""
    # score-based sampling: peers = top-degree of random scores (excl. self)
    scores = jax.random.uniform(key, (n, n))
    scores = scores - 2.0 * jnp.eye(n)  # never pick self
    _, idx = jax.lax.top_k(scores, degree)
    a = jnp.zeros((n, n), jnp.float32)
    rows = jnp.repeat(jnp.arange(n), degree)
    a = a.at[rows, idx.reshape(-1)].set(1.0)
    return jnp.maximum(a, a.T)  # undirected


def static_adjacency(topology: str, n: int, cluster_size: int = 4) -> jnp.ndarray | None:
    if topology == "ring":
        return ring_adjacency(n)
    if topology == "cluster":
        return cluster_adjacency(n, cluster_size)
    if topology == "star":
        return star_adjacency(n)
    if topology == "full":
        return full_adjacency(n)
    if topology == "random":
        return None  # sampled per round
    raise KeyError(f"unknown topology {topology!r}")


def round_adjacency(
    topology: str, n: int, key, comm_batch: int, cluster_size: int = 4
) -> jnp.ndarray:
    """Adjacency for round t (jnp; random resamples, others constant)."""
    static = static_adjacency(topology, n, cluster_size)
    if static is not None:
        return static
    return random_adjacency(key, n, min(comm_batch, n - 1))


def mixing_matrix(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> jnp.ndarray:
    """Algorithm 1 lines 7-9 as a row-stochastic matrix.

    ``active``: (N,) {0,1}.  Only active nodes mix, and they only count
    *active* neighbours; each node keeps at most ``comm_batch`` neighbours.
    The cap is deterministic so the op stays jittable: the left-to-right
    cumulative count keeps the B LOWEST-index active neighbours of each
    row and drops the rest (``csum <= comm_batch`` admits a neighbour
    only while fewer than B active neighbours precede it) — pinned by
    ``tests/test_topology.py::test_mixing_matrix_cap_keeps_lowest_index``.
    """
    n = adjacency.shape[0]
    act = active.astype(jnp.float32)
    # neighbours that are active
    neigh = adjacency * act[None, :]
    # cap at comm_batch per row (keep the B lowest-index active neighbours)
    csum = jnp.cumsum(neigh, axis=1)
    neigh = neigh * (csum <= comm_batch)
    # self weight always included for active rows
    w = neigh + jnp.eye(n, dtype=jnp.float32)
    denom = jnp.sum(w, axis=1, keepdims=True)
    mix_active = w / denom
    eye = jnp.eye(n, dtype=jnp.float32)
    return act[:, None] * mix_active + (1 - act)[:, None] * eye


def stacked_adjacency(
    topologies, n: int, cluster_size: int = 4
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched adjacency builder for the sweep engine.

    Returns ``(adjacency, resample)`` with ``adjacency`` shaped
    ``(G, N, N)`` — one static adjacency per scenario — and ``resample``
    shaped ``(G,)`` in {0, 1}: scenarios whose topology re-draws its
    graph every round (``"random"``) get ``resample == 1`` and a zero
    adjacency placeholder; the round body then substitutes a fresh
    :func:`random_adjacency` draw from that round's key, so batched
    scenarios consume the identical key stream as a serial run of the
    same topology.
    """
    adjs, flags = [], []
    for topo in topologies:
        static = static_adjacency(topo, n, cluster_size)
        if static is None:  # "random": sampled per round from the key
            adjs.append(jnp.zeros((n, n), jnp.float32))
            flags.append(1.0)
        else:
            adjs.append(static)
            flags.append(0.0)
    return jnp.stack(adjs), jnp.asarray(flags, jnp.float32)


def mixing_matrix_stacked(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> jnp.ndarray:
    """Batched :func:`mixing_matrix`: ``(G, N, N)`` adjacencies and
    ``(G, N)`` active masks in, ``(G, N, N)`` row-stochastic mixing
    matrices out — one vmap, scenario ``g`` bitwise-identical to
    ``mixing_matrix(adjacency[g], active[g], comm_batch)``.

    Standalone grid-level builder (spectral-gap sweeps, schedule
    analyses); ``GluADFL.train_sweep`` itself batches plain
    ``mixing_matrix`` under its own vmap of the round body."""
    return jax.vmap(mixing_matrix, in_axes=(0, 0, None))(
        adjacency, active, comm_batch
    )


def neighbor_table_from_candidates(
    cand_idx: jnp.ndarray,
    cand_valid: jnp.ndarray,
    active: jnp.ndarray,
    comm_batch: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sparse mixing rows from per-node candidate lists.

    ``cand_idx`` (N, C) int: each row's potential neighbours in ASCENDING
    column order; ``cand_valid`` (N, C) {0,1} masks padding slots (their
    ``cand_idx`` values are ignored).  Applies exactly the
    :func:`mixing_matrix` semantics — keep the ``comm_batch`` lowest-index
    ACTIVE candidates, uniform 1/(deg+1) weights, identity rows for
    inactive nodes — and returns ``(idx, wgt)`` of shape
    (N, min(comm_batch, C) + 1):

      * slot 0 is always self: weight ``1/denom`` (active) or 1.0
        (inactive, making the row an identity row);
      * slots 1.. hold the kept neighbours with weight ``1/denom``;
      * unused slots have ``idx == row`` and ``wgt == 0`` so gathers stay
        in-bounds and contribute nothing.

    Densifying (:func:`densify_neighbor_table`) reproduces
    ``mixing_matrix`` bitwise: both divide the same 1.0 by the same
    denominator.  Cost is O(N·C) — with host-built candidate lists
    (:func:`neighbor_candidates`) no (N, N) array ever exists.
    """
    n, c = cand_idx.shape
    b = int(min(comm_batch, c))
    act = active.astype(jnp.float32)
    self_idx = jnp.arange(n, dtype=jnp.int32)
    # candidates that are valid AND active; cap by cumulative count keeps
    # the B lowest-index survivors (same csum rule as mixing_matrix)
    avail = cand_valid.astype(jnp.float32) * act[cand_idx]
    csum = jnp.cumsum(avail, axis=1)
    keep = avail * (csum <= comm_batch)
    denom = 1.0 + jnp.sum(keep, axis=1)  # (N,) — self + kept neighbours
    if b > 0:
        # compact the kept slots to the front, preserving ascending order:
        # top_k of -position over kept slots returns positions ascending
        score = jnp.where(keep > 0, -jnp.arange(c, dtype=jnp.float32), -jnp.inf)
        _, pos = jax.lax.top_k(score, b)
        sel_keep = jnp.take_along_axis(keep, pos, axis=1)
        sel_idx = jnp.take_along_axis(cand_idx.astype(jnp.int32), pos, axis=1)
        nb_wgt = act[:, None] * sel_keep / denom[:, None]
    else:
        sel_idx = jnp.zeros((n, 0), jnp.int32)
        nb_wgt = jnp.zeros((n, 0), jnp.float32)
    self_wgt = jnp.where(act > 0, 1.0 / denom, 1.0)
    idx = jnp.concatenate([self_idx[:, None], sel_idx], axis=1)
    wgt = jnp.concatenate([self_wgt[:, None], nb_wgt], axis=1)
    # zero-weight slots point at self: gathers stay in-bounds, 0·w[n] adds
    # nothing, and garbage candidate padding never leaks through
    idx = jnp.where(wgt > 0, idx, self_idx[:, None])
    return idx, wgt


def neighbor_table(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sparse twin of :func:`mixing_matrix`: same (adjacency, active,
    comm_batch) inputs, ``(idx, wgt)`` of shape (N, min(B, N)+1) out,
    with ``densify_neighbor_table(idx, wgt) == mixing_matrix(...)``
    bitwise.  O(N²) build (it reads the dense adjacency) but the
    downstream contraction drops to O(N·B·D); use
    :func:`neighbor_candidates` to skip the dense build for static
    topologies."""
    n = adjacency.shape[0]
    cand_idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (n, n))
    return neighbor_table_from_candidates(
        cand_idx, adjacency.astype(jnp.float32), active, comm_batch
    )


def stacked_neighbor_table(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched :func:`neighbor_table` for the sweep grid: ``(G, N, N)``
    adjacencies + ``(G, N)`` masks in, ``(G, N, B+1)`` tables out —
    scenario ``g`` bitwise-identical to the unbatched call."""
    return jax.vmap(neighbor_table, in_axes=(0, 0, None))(
        adjacency, active, comm_batch
    )


def neighbor_candidates(
    topology: str, n: int, cluster_size: int = 4
) -> tuple[jnp.ndarray, jnp.ndarray] | None:
    """Host-built static candidate lists ``(cand_idx, cand_valid)`` for
    :func:`neighbor_table_from_candidates` — ``None`` for ``"random"``
    (its graph re-draws per round, so the trainer builds a dense
    adjacency from the round key instead).

    The ring path is direct O(N) (sorted ``{i-1, i+1} mod n``) — the
    population-scale federations the sparse representation exists for
    are rings, and this path never allocates an (N, N) array.  The other
    static topologies go through :func:`static_adjacency` once at trainer
    construction (host numpy, outside jit) and pad each row's nonzero
    columns to the max degree."""
    if topology == "random":
        return None
    if topology == "ring":
        if n <= 1:
            return (jnp.zeros((n, 1), jnp.int32), jnp.zeros((n, 1), jnp.float32))
        i = np.arange(n)
        if n == 2:
            cand = (1 - i)[:, None]
        else:
            cand = np.sort(np.stack([(i - 1) % n, (i + 1) % n], axis=1), axis=1)
        return jnp.asarray(cand, jnp.int32), jnp.ones(cand.shape, jnp.float32)
    adj = np.asarray(static_adjacency(topology, n, cluster_size))
    deg = adj.sum(axis=1).astype(int)
    c = max(1, int(deg.max()))
    cand = np.zeros((n, c), np.int32)
    valid = np.zeros((n, c), np.float32)
    for row in range(n):
        nz = np.nonzero(adj[row])[0]
        cand[row, : len(nz)] = nz
        valid[row, : len(nz)] = 1.0
    return jnp.asarray(cand), jnp.asarray(valid)


def densify_neighbor_table(idx: jnp.ndarray, wgt: jnp.ndarray) -> jnp.ndarray:
    """Scatter a neighbor table back to the dense (N, N) mixing matrix —
    the oracle relation every sparse consumer is tested through.  Padding
    slots scatter-add 0.0 onto the diagonal, which leaves the positive
    self weight bit-identical."""
    n = idx.shape[0]
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], idx.shape)
    return jnp.zeros((n, n), jnp.float32).at[rows, idx].add(wgt)


def spectral_gap(mix: jnp.ndarray) -> float:
    """1 - |lambda_2| of a (symmetric-ish) mixing matrix — the standard
    gossip convergence-rate proxy, reported by the topology benchmark."""
    lam = np.linalg.eigvals(np.asarray(mix, np.float64))
    lam = np.sort(np.abs(lam))[::-1]
    return float(1.0 - (lam[1] if len(lam) > 1 else 0.0))
