"""Communication topologies (paper §3.3) as adjacency / mixing matrices.

A topology yields, per round t, an adjacency matrix A_t in {0,1}^(N,N)
(no self loops).  Combined with the round's active mask, it becomes the
row-stochastic mixing matrix M_t of Algorithm 1 lines 7-9:

    M[n]  = uniform over ({n} ∪ active neighbours of n, capped at B)    if n active
    M[n]  = e_n (identity row — keeps its stale model)                   if n inactive

``mixing_matrix`` is pure-jnp so whole FL rounds jit/scan; the static
topologies (ring, cluster, star, full) are constants, the random topology
re-samples each round from a PRNG key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ring_adjacency(n: int) -> jnp.ndarray:
    """Each node talks to its two ring neighbours."""
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, (i + 1) % n] = 1
        a[i, (i - 1) % n] = 1
    if n <= 2:
        np.fill_diagonal(a, 0)
    return jnp.asarray(a)


def cluster_adjacency(n: int, cluster_size: int = 4) -> jnp.ndarray:
    """Fully-connected clusters arranged on a ring; one bridge node links
    each cluster to the next (SWIFT-style cluster-ring)."""
    a = np.zeros((n, n), np.float32)
    n_clusters = max(1, -(-n // cluster_size))
    for c in range(n_clusters):
        lo, hi = c * cluster_size, min((c + 1) * cluster_size, n)
        for i in range(lo, hi):
            for j in range(lo, hi):
                if i != j:
                    a[i, j] = 1
        # bridge: last member of this cluster <-> first member of next
        nxt = ((c + 1) % n_clusters) * cluster_size
        if hi - 1 != nxt:
            a[hi - 1, nxt] = 1
            a[nxt, hi - 1] = 1
    return jnp.asarray(a)


def star_adjacency(n: int) -> jnp.ndarray:
    """FedAvg's topology: node 0 is the server."""
    a = np.zeros((n, n), np.float32)
    a[0, 1:] = 1
    a[1:, 0] = 1
    return jnp.asarray(a)


def full_adjacency(n: int) -> jnp.ndarray:
    return jnp.ones((n, n), jnp.float32) - jnp.eye(n, dtype=jnp.float32)


def random_adjacency(key, n: int, degree: int) -> jnp.ndarray:
    """Time-varying random graph: each node draws ``degree`` distinct
    peers; symmetrized (paper: up to B random connections per round)."""
    # score-based sampling: peers = top-degree of random scores (excl. self)
    scores = jax.random.uniform(key, (n, n))
    scores = scores - 2.0 * jnp.eye(n)  # never pick self
    _, idx = jax.lax.top_k(scores, degree)
    a = jnp.zeros((n, n), jnp.float32)
    rows = jnp.repeat(jnp.arange(n), degree)
    a = a.at[rows, idx.reshape(-1)].set(1.0)
    return jnp.maximum(a, a.T)  # undirected


def static_adjacency(topology: str, n: int, cluster_size: int = 4) -> jnp.ndarray | None:
    if topology == "ring":
        return ring_adjacency(n)
    if topology == "cluster":
        return cluster_adjacency(n, cluster_size)
    if topology == "star":
        return star_adjacency(n)
    if topology == "full":
        return full_adjacency(n)
    if topology == "random":
        return None  # sampled per round
    raise KeyError(f"unknown topology {topology!r}")


def round_adjacency(
    topology: str, n: int, key, comm_batch: int, cluster_size: int = 4
) -> jnp.ndarray:
    """Adjacency for round t (jnp; random resamples, others constant)."""
    static = static_adjacency(topology, n, cluster_size)
    if static is not None:
        return static
    return random_adjacency(key, n, min(comm_batch, n - 1))


def mixing_matrix(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> jnp.ndarray:
    """Algorithm 1 lines 7-9 as a row-stochastic matrix.

    ``active``: (N,) {0,1}.  Only active nodes mix, and they only count
    *active* neighbours; each node keeps at most ``comm_batch`` neighbours.
    The cap is deterministic so the op stays jittable: the left-to-right
    cumulative count keeps the B LOWEST-index active neighbours of each
    row and drops the rest (``csum <= comm_batch`` admits a neighbour
    only while fewer than B active neighbours precede it) — pinned by
    ``tests/test_topology.py::test_mixing_matrix_cap_keeps_lowest_index``.
    """
    n = adjacency.shape[0]
    act = active.astype(jnp.float32)
    # neighbours that are active
    neigh = adjacency * act[None, :]
    # cap at comm_batch per row (keep the B lowest-index active neighbours)
    csum = jnp.cumsum(neigh, axis=1)
    neigh = neigh * (csum <= comm_batch)
    # self weight always included for active rows
    w = neigh + jnp.eye(n, dtype=jnp.float32)
    denom = jnp.sum(w, axis=1, keepdims=True)
    mix_active = w / denom
    eye = jnp.eye(n, dtype=jnp.float32)
    return act[:, None] * mix_active + (1 - act)[:, None] * eye


def stacked_adjacency(
    topologies, n: int, cluster_size: int = 4
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched adjacency builder for the sweep engine.

    Returns ``(adjacency, resample)`` with ``adjacency`` shaped
    ``(G, N, N)`` — one static adjacency per scenario — and ``resample``
    shaped ``(G,)`` in {0, 1}: scenarios whose topology re-draws its
    graph every round (``"random"``) get ``resample == 1`` and a zero
    adjacency placeholder; the round body then substitutes a fresh
    :func:`random_adjacency` draw from that round's key, so batched
    scenarios consume the identical key stream as a serial run of the
    same topology.
    """
    adjs, flags = [], []
    for topo in topologies:
        static = static_adjacency(topo, n, cluster_size)
        if static is None:  # "random": sampled per round from the key
            adjs.append(jnp.zeros((n, n), jnp.float32))
            flags.append(1.0)
        else:
            adjs.append(static)
            flags.append(0.0)
    return jnp.stack(adjs), jnp.asarray(flags, jnp.float32)


def mixing_matrix_stacked(
    adjacency: jnp.ndarray, active: jnp.ndarray, comm_batch: int
) -> jnp.ndarray:
    """Batched :func:`mixing_matrix`: ``(G, N, N)`` adjacencies and
    ``(G, N)`` active masks in, ``(G, N, N)`` row-stochastic mixing
    matrices out — one vmap, scenario ``g`` bitwise-identical to
    ``mixing_matrix(adjacency[g], active[g], comm_batch)``.

    Standalone grid-level builder (spectral-gap sweeps, schedule
    analyses); ``GluADFL.train_sweep`` itself batches plain
    ``mixing_matrix`` under its own vmap of the round body."""
    return jax.vmap(mixing_matrix, in_axes=(0, 0, None))(
        adjacency, active, comm_batch
    )


def spectral_gap(mix: jnp.ndarray) -> float:
    """1 - |lambda_2| of a (symmetric-ish) mixing matrix — the standard
    gossip convergence-rate proxy, reported by the topology benchmark."""
    lam = np.linalg.eigvals(np.asarray(mix, np.float64))
    lam = np.sort(np.abs(lam))[::-1]
    return float(1.0 - (lam[1] if len(lam) > 1 else 0.0))
