"""Shared chunked-``lax.scan`` machinery for the baseline trainers.

The GluADFL engine (``core/gluadfl.py``) runs whole chunks of rounds as
one donated scan program; this module is the small common core that
brings the dormant baselines — FedAvg, MAML/MetaSGD, pooled supervised —
onto the same engine without triplicating the plumbing:

  * :class:`StopState` + :func:`scan_rounds` — the per-run early-stopping
    state threaded through the scan carry.  With ``patience > 0`` every
    round body is wrapped in a ``lax.cond`` guarded by the carried
    ``done`` flag: once the val loss has failed to improve for
    ``patience`` consecutive evals, later rounds become identity
    (params frozen bitwise, NaN-sentinel aux) while the scan runs to its
    static length — the host reads ``stop_round`` once per chunk and
    stops dispatching.  With ``patience == 0`` (the default) the body
    scans unwrapped, so the compiled program is the exact loop-engine
    sequence and the loop-vs-scan parity tests compare identical
    semantics.
  * :func:`boundary_val` — the NaN-sentinel streaming-eval branch
    (``lax.cond`` on the round boundary), same convention as GluADFL's
    ``_eval_metrics``: off-boundary rounds pay only the predicate and
    report NaN.
  * :func:`drain_history` — the once-per-chunk host sync: turns the
    stacked ``(chunk,)`` losses/vals into per-round history records,
    truncating after an early stop.
  * :func:`dispatch_chunk` — the single chokepoint through which every
    baseline launches a compiled chunk program.  Tests monkeypatch this
    to COUNT compiled executions — the Table-4 "method grid in <= 4
    executions" budget is pinned through it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@jax.tree_util.register_dataclass
@dataclass
class StopState:
    """Early-stopping latch carried through the scan.

    ``done`` freezes the run; ``best_val``/``bad_evals`` implement
    patience; ``stop_round`` records the round the latch tripped
    (-1 = never) so the host can truncate the history exactly."""

    done: jnp.ndarray        # () bool
    best_val: jnp.ndarray    # () float32
    bad_evals: jnp.ndarray   # () int32
    stop_round: jnp.ndarray  # () int32


def init_stop() -> StopState:
    return StopState(
        done=jnp.zeros((), jnp.bool_),
        best_val=jnp.full((), jnp.inf, jnp.float32),
        bad_evals=jnp.zeros((), jnp.int32),
        stop_round=jnp.full((), -1, jnp.int32),
    )


def update_stop(stop: StopState, val, t, patience: int) -> StopState:
    """Fold one round's (possibly NaN-sentinel) val loss into the latch.

    NaN (off-boundary round, or a diverged eval) never improves and
    never counts against patience — only real evals move the state."""
    has_val = jnp.isfinite(val)
    improved = has_val & (val < stop.best_val)
    best = jnp.where(improved, val, stop.best_val)
    bad = jnp.where(
        has_val,
        jnp.where(improved, jnp.int32(0), stop.bad_evals + 1),
        stop.bad_evals,
    )
    trip = has_val & (bad >= patience) & jnp.logical_not(stop.done)
    return StopState(
        done=stop.done | trip,
        best_val=best,
        bad_evals=bad,
        stop_round=jnp.where(trip, jnp.int32(t), stop.stop_round),
    )


def boundary_val(val_fn: Callable, params, t, eval_every: int):
    """``val_fn(params)`` at ``(t+1) % eval_every == 0`` boundaries, NaN
    (the host-side sentinel) elsewhere; ``eval_every == 0`` disarms the
    branch entirely (a compile-time constant NaN)."""
    if not eval_every:
        return jnp.full((), jnp.nan, jnp.float32)
    return jax.lax.cond(
        (t + 1) % eval_every == 0,
        lambda p: val_fn(p).astype(jnp.float32),
        lambda p: jnp.full((), jnp.nan, jnp.float32),
        params,
    )


def scan_rounds(body: Callable, carry, ts, stop: StopState | None = None,
                *, patience: int = 0):
    """Scan ``body(carry, t) -> (carry, (loss, val))`` over the round
    indices ``ts``.

    Returns ``(carry, stop, (losses, vals))``.  With ``patience == 0``
    the body scans as-is and ``stop`` passes through as ``None`` — the
    compiled sequence is bitwise the per-round loop's.  With
    ``patience > 0`` the body is ``lax.cond``-guarded on the carried
    :class:`StopState`: stopped rounds return the carry unchanged and
    NaN aux, and :func:`update_stop` advances the latch from each
    round's val output."""
    if not patience:
        carry, aux = jax.lax.scan(body, carry, ts)
        return carry, stop, aux
    if stop is None:
        stop = init_stop()
    aux_shapes = jax.eval_shape(lambda c, t: body(c, t)[1], carry, ts[0])
    nan_aux = jax.tree.map(
        lambda s: jnp.full(s.shape, jnp.nan, s.dtype), aux_shapes
    )

    def wrapped(cs, t):
        def run(op):
            c0, s0 = op
            c1, aux = body(c0, t)
            _, val = aux
            return (c1, update_stop(s0, val, t, patience)), aux

        def skip(op):
            return op, nan_aux

        return jax.lax.cond(cs[1].done, skip, run, cs)

    (carry, stop), aux = jax.lax.scan(wrapped, (carry, stop), ts)
    return carry, stop, aux


def dispatch_chunk(chunk_fn: Callable, *args, **kwargs):
    """Launch one compiled chunk program.

    Every baseline trainer routes its jitted chunk calls through this
    single chokepoint, so a test can monkeypatch it with a counting
    wrapper and pin exactly how many compiled executions a workload
    dispatches (``tests/test_baseline_engines.py`` counts the Table-4
    method grid at <= 4)."""
    return chunk_fn(*args, **kwargs)


def drain_history(history: list, losses, vals, t0: int, *,
                  eval_every: int = 0, stop_round: int = -1,
                  round_key: str = "round", val_key: str = "val_loss") -> bool:
    """Append one chunk's records to ``history`` (host side, one sync
    per chunk).  ``losses``/``vals`` are the chunk's ``(c,)`` arrays
    (``vals`` may be ``None`` when eval is off); rounds after an early
    stop (``stop_round >= 0``) carry NaN sentinels and are dropped.
    Returns True once the stop round has been drained."""
    c = len(losses)
    for i in range(c):
        r = t0 + i
        if 0 <= stop_round < r:
            return True
        rec = {round_key: r, "loss": float(losses[i])}
        if vals is not None and eval_every and (r + 1) % eval_every == 0:
            rec[val_key] = float(vals[i])
        history.append(rec)
    return 0 <= stop_round < t0 + c
