"""'Personalized from population' (paper Fig 3): fine-tune the population
model on one patient's own CGM history — the cold-start path a newly
diagnosed patient takes before the population model has ever seen them.

Engine design (mirrors the trainer's scan story in ``core/gluadfl.py``):

  * :func:`personalize` runs the whole fine-tune as ONE compiled
    ``lax.scan`` program over the steps — no per-step jit dispatch, no
    per-step host sync.  The carried RNG key splits once per step, so
    the key stream is identical to the historical Python-loop
    implementation.
  * :func:`personalize_batch` is the serving-side engine: ``jax.vmap``
    of the same scanned body over a stacked batch of patients (padded
    windows + per-patient counts, exactly the ``data/pipeline.py``
    federation layout), so P cold-start patients fine-tune as ONE
    program.  Per-patient results are BITWISE the serial
    :func:`personalize` outputs under the same keys
    (``tests/test_personalize.py`` pins it; ``benchmarks/serve_latency``
    prices the speedup as ``personalize_batch_speedup_vs_serial``).
  * :func:`personalize_loop` keeps the original per-step Python loop as
    the explicit debug/reference twin (one jitted step per iteration) —
    same numerics, P·steps dispatches; it is what the bench baseline
    measures the batched engine against.

Minibatch semantics (the cold-start bugfix): draws are uniform WITH
replacement from the patient's ``count`` real windows.  When
``batch_size`` exceeds the available history — tiny new-patient
histories are exactly the serving case — the batch is CLAMPED to the
history length instead of silently oversampling duplicates; rows past
``count`` (padding) are never sampled.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


def _fine_tune_fn(
    model: Model,
    optimizer: Optimizer,
    steps: int,
    batch_size: int,
    n_rows: int,
) -> Callable:
    """The single-patient fine-tune body shared by every engine:
    ``fine_tune(p0, key, x, y, count) -> (params, (steps,) losses)``.

    ``batch_size`` is clamped to ``n_rows`` (the static row count of
    ``x``) at build time — shapes must be static — and each step draws
    uniform with-replacement indices from ``[0, min(count, n_rows))``,
    so padded rows beyond ``count`` are never touched.  One
    ``jax.random.split`` per step keeps the key stream identical to the
    historical Python loop.
    """
    bs = max(1, min(batch_size, n_rows))

    def loss_fn(p, bx, by):
        return jnp.mean(jnp.square(model.apply(p, bx) - by))

    def fine_tune(p0, key, x, y, count):
        hi = jnp.maximum(jnp.minimum(count, n_rows), 1)

        def step(carry, _):
            p, st, k = carry
            k, sub = jax.random.split(k)
            idx = jax.random.randint(sub, (bs,), 0, hi)
            loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
            p, st = optimizer.update(grads, st, p)
            return (p, st, k), loss

        st = optimizer.init(p0)
        (p, _, _), losses = jax.lax.scan(step, (p0, st, key), None, length=steps)
        return p, losses

    return fine_tune


def personalize(
    model: Model,
    optimizer: Optimizer,
    population_params: PyTree,
    key,
    x,
    y,
    *,
    steps: int = 100,
    batch_size: int = 32,
    count=None,
) -> PyTree:
    """Fine-tune population params on a single patient (paper: adjust γ)
    as one compiled ``lax.scan`` program.

    ``count`` (default: all of ``x``) marks how many leading rows of
    ``x``/``y`` are real — pass it when the history is padded (the
    serving layout); padded rows are never sampled.  ``batch_size`` is
    clamped to the available history (cold-start histories shorter than
    a batch train on everything they have, not on duplicated draws).
    """
    x, y = jnp.asarray(x), jnp.asarray(y)
    count = x.shape[0] if count is None else count
    fine_tune = _fine_tune_fn(model, optimizer, steps, batch_size, x.shape[0])
    params, _ = jax.jit(fine_tune)(population_params, key, x, y, count)
    return params


def personalize_batch(
    model: Model,
    optimizer: Optimizer,
    population_params: PyTree,
    keys,
    x,
    y,
    counts,
    *,
    steps: int = 100,
    batch_size: int = 32,
) -> PyTree:
    """Fine-tune P patients from the SAME population checkpoint as ONE
    compiled program: ``jax.vmap`` of the scanned single-patient body.

    Inputs follow the federation layout: ``keys (P, 2)``, padded windows
    ``x (P, M, L)``, targets ``y (P, M)``, real-row ``counts (P,)``.
    Returns the stacked personalized params (leaves ``(P, ...)``; index
    one patient out with ``utils.pytree.tree_index``).  Patient ``i``'s
    row is BITWISE ``personalize(..., keys[i], x[i], y[i],
    count=counts[i])`` — batching is a re-batching, not a
    re-definition, of the fine-tune.
    """
    x, y = jnp.asarray(x), jnp.asarray(y)
    fine_tune = _fine_tune_fn(model, optimizer, steps, batch_size, x.shape[1])
    batched = jax.vmap(fine_tune, in_axes=(None, 0, 0, 0, 0))
    params, _ = jax.jit(batched)(
        population_params, jnp.asarray(keys), x, y, jnp.asarray(counts)
    )
    return params


def personalize_batch_fn(
    model: Model,
    optimizer: Optimizer,
    *,
    steps: int = 100,
    batch_size: int = 32,
    n_rows: int,
) -> Callable:
    """The jitted batched fine-tune as a REUSABLE closure for serving:
    ``f(population_params, keys, x, y, counts) -> (stacked params,
    (P, steps) losses)``.  Unlike :func:`personalize_batch` (which jits
    per call) the returned function keeps one jit cache, so a service
    personalizing cohort after cohort compiles once per cohort size.
    ``n_rows`` is the padded history length M the closure is built for.
    """
    fine_tune = _fine_tune_fn(model, optimizer, steps, batch_size, n_rows)
    return jax.jit(jax.vmap(fine_tune, in_axes=(None, 0, 0, 0, 0)))


def personalize_loop(
    model: Model,
    optimizer: Optimizer,
    population_params: PyTree,
    key,
    x,
    y,
    *,
    steps: int = 100,
    batch_size: int = 32,
    count=None,
) -> PyTree:
    """The historical per-step Python loop (one jitted step + one host
    dispatch per iteration) — kept as the explicit debug/reference twin
    of :func:`personalize` and the baseline the serve bench measures
    :func:`personalize_batch` against.  Same numerics: clamp, count
    masking, and key stream match the scanned engine bitwise.
    """
    x, y = jnp.asarray(x), jnp.asarray(y)
    count = x.shape[0] if count is None else count
    n = x.shape[0]
    bs = max(1, min(batch_size, n))
    hi = jnp.maximum(jnp.minimum(jnp.asarray(count), n), 1)

    def loss_fn(p, bx, by):
        return jnp.mean(jnp.square(model.apply(p, bx) - by))

    @jax.jit
    def step(p, st, k):
        idx = jax.random.randint(k, (bs,), 0, hi)
        loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, st = optimizer.update(grads, st, p)
        return p, st, loss

    params = population_params
    st = optimizer.init(params)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        params, st, _ = step(params, st, sub)
    return params
