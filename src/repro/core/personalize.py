"""'Personalized from population' (paper Fig 3): fine-tune the population
model on one patient's own data, versus a from-scratch personalized model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


def personalize(
    model: Model,
    optimizer: Optimizer,
    population_params: PyTree,
    key,
    x,
    y,
    *,
    steps: int = 100,
    batch_size: int = 32,
) -> PyTree:
    """Fine-tune population params on a single patient (paper: adjust γ)."""
    x, y = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, bx, by):
        return jnp.mean(jnp.square(model.apply(p, bx) - by))

    @jax.jit
    def step(p, st, k):
        idx = jax.random.randint(k, (batch_size,), 0, x.shape[0])
        loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, st = optimizer.update(grads, st, p)
        return p, st, loss

    params = population_params
    st = optimizer.init(params)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        params, st, _ = step(params, st, sub)
    return params
