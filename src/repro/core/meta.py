"""Meta-learning baselines: MAML and MetaSGD (paper §4.4).

Tasks = patients.  MAML (Finn et al.) learns an initialization that
adapts in a few inner SGD steps; MetaSGD (Li et al.) additionally learns
a per-parameter inner learning rate.  The paper evaluates both WITHOUT
test-time fine-tuning (population-model setting), which we reproduce:
``population_params`` returns the meta-initialization directly.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


class MAML:
    learn_inner_lr = False

    def __init__(
        self,
        model: Model,
        meta_optimizer: Optimizer,
        *,
        inner_lr: float = 1e-2,
        inner_steps: int = 3,
        loss_fn: Callable | None = None,
    ):
        self.model = model
        self.meta_opt = meta_optimizer
        self.inner_lr = inner_lr
        self.inner_steps = inner_steps
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        self._step_jit = jax.jit(self._meta_step, static_argnames=("batch_size",))

    # -- inner adaptation ---------------------------------------------
    def _adapt(self, params, lrs, key, x, y, count, batch_size):
        def inner(carry, k):
            p = carry
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(count, 1))
            grads = jax.grad(self.loss_fn)(p, x[idx], y[idx])
            p = jax.tree.map(lambda pp, g, lr: pp - lr * g, p, grads, lrs)
            return p, None

        keys = jax.random.split(key, self.inner_steps)
        adapted, _ = jax.lax.scan(inner, params, keys)
        return adapted

    # -- one meta step over a batch of tasks (= all patients) ----------
    def _meta_step(self, key, params, lrs, meta_state, x, y, counts, *, batch_size: int):
        n = x.shape[0]
        keys = jax.random.split(key, 2 * n).reshape(n, 2, -1)

        def task_loss(meta_params, meta_lrs, tkeys, xt, yt, ct):
            adapted = self._adapt(meta_params, meta_lrs, tkeys[0], xt, yt, ct, batch_size)
            idx = jax.random.randint(tkeys[1], (batch_size,), 0, jnp.maximum(ct, 1))
            return self.loss_fn(adapted, xt[idx], yt[idx])

        def mean_loss(meta_params, meta_lrs):
            losses = jax.vmap(partial(task_loss, meta_params, meta_lrs))(
                keys, x, y, counts
            )
            return jnp.mean(losses)

        if self.learn_inner_lr:
            loss, (gp, gl) = jax.value_and_grad(mean_loss, argnums=(0, 1))(params, lrs)
            grads = {"params": gp, "lrs": gl}
            packed = {"params": params, "lrs": lrs}
            new_packed, meta_state = self.meta_opt.update(grads, meta_state, packed)
            return new_packed["params"], new_packed["lrs"], meta_state, loss
        loss, gp = jax.value_and_grad(mean_loss)(params, lrs)
        new_params, meta_state = self.meta_opt.update(gp, meta_state, params)
        return new_params, lrs, meta_state, loss

    # -- driver ---------------------------------------------------------
    def train(self, key, x, y, counts, *, batch_size: int = 64, steps: int = 100):
        x, y, counts = jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)
        key, k_init = jax.random.split(key)
        params = self.model.init(k_init)
        lrs = jax.tree.map(lambda l: jnp.full_like(l, self.inner_lr), params)
        meta_state = (
            self.meta_opt.init({"params": params, "lrs": lrs})
            if self.learn_inner_lr
            else self.meta_opt.init(params)
        )
        history = []
        for t in range(steps):
            key, sub = jax.random.split(key)
            params, lrs, meta_state, loss = self._step_jit(
                sub, params, lrs, meta_state, x, y, counts, batch_size=batch_size
            )
            history.append({"round": t, "loss": float(loss)})
        return params, lrs, history


class MetaSGD(MAML):
    """MAML + learnable per-parameter inner learning rates."""

    learn_inner_lr = True
