"""Meta-learning baselines: MAML and MetaSGD (paper §4.4).

Tasks = patients.  MAML (Finn et al.) learns an initialization that
adapts in a few inner SGD steps; MetaSGD (Li et al.) additionally learns
a per-parameter inner learning rate.  The paper evaluates both WITHOUT
test-time fine-tuning (population-model setting), which we reproduce:
``population_params`` returns the meta-initialization directly.

Engines: ``train(engine="scan")`` (default) runs chunks of meta-steps as
one donated ``lax.scan`` dispatched through ``chunked.dispatch_chunk``
(one host sync per chunk), with streaming eval and ``lax.cond``-guarded
early stopping; ``engine="loop"`` keeps the per-step jit loop as the
parity oracle (``tests/test_baseline_engines.py`` pins them bitwise).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked
from repro.core.fedavg import DEFAULT_CHUNK
from repro.models.base import Model
from repro.optim import Optimizer

PyTree = Any


class MAML:
    learn_inner_lr = False

    def __init__(
        self,
        model: Model,
        meta_optimizer: Optimizer,
        *,
        inner_lr: float = 1e-2,
        inner_steps: int = 3,
        loss_fn: Callable | None = None,
    ):
        self.model = model
        self.meta_opt = meta_optimizer
        self.inner_lr = inner_lr
        self.inner_steps = inner_steps
        self.loss_fn = loss_fn or (
            lambda p, x, y: jnp.mean(jnp.square(model.apply(p, x) - y))
        )
        self._step_jit = jax.jit(self._meta_step, static_argnames=("batch_size",))
        self._val_jit = jax.jit(self._val_loss)
        self._chunk_jit = jax.jit(
            self._train_chunk,
            static_argnames=("batch_size", "chunk", "eval_every", "patience"),
            donate_argnums=(0, 1),
        )

    # -- inner adaptation ---------------------------------------------
    def _adapt(self, params, lrs, key, x, y, count, batch_size):
        def inner(carry, k):
            p = carry
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(count, 1))
            grads = jax.grad(self.loss_fn)(p, x[idx], y[idx])
            p = jax.tree.map(lambda pp, g, lr: pp - lr * g, p, grads, lrs)
            return p, None

        keys = jax.random.split(key, self.inner_steps)
        adapted, _ = jax.lax.scan(inner, params, keys)
        return adapted

    # -- one meta step over a batch of tasks (= all patients) ----------
    def _meta_step(self, key, params, lrs, meta_state, x, y, counts, *, batch_size: int):
        n = x.shape[0]
        keys = jax.random.split(key, 2 * n).reshape(n, 2, -1)

        def task_loss(meta_params, meta_lrs, tkeys, xt, yt, ct):
            adapted = self._adapt(meta_params, meta_lrs, tkeys[0], xt, yt, ct, batch_size)
            idx = jax.random.randint(tkeys[1], (batch_size,), 0, jnp.maximum(ct, 1))
            return self.loss_fn(adapted, xt[idx], yt[idx])

        def mean_loss(meta_params, meta_lrs):
            losses = jax.vmap(partial(task_loss, meta_params, meta_lrs))(
                keys, x, y, counts
            )
            return jnp.mean(losses)

        if self.learn_inner_lr:
            loss, (gp, gl) = jax.value_and_grad(mean_loss, argnums=(0, 1))(params, lrs)
            grads = {"params": gp, "lrs": gl}
            packed = {"params": params, "lrs": lrs}
            new_packed, meta_state = self.meta_opt.update(grads, meta_state, packed)
            return new_packed["params"], new_packed["lrs"], meta_state, loss
        loss, gp = jax.value_and_grad(mean_loss)(params, lrs)
        new_params, meta_state = self.meta_opt.update(gp, meta_state, params)
        return new_params, lrs, meta_state, loss

    # -- scan engine ----------------------------------------------------
    def _val_loss(self, params, val_x, val_y):
        pred = self.model.apply(params, val_x)
        return jnp.mean(jnp.square(pred - val_y))

    def _train_chunk(self, carry, stop, x, y, counts, val_x, val_y, t0, *,
                     batch_size: int, chunk: int, eval_every: int,
                     patience: int):
        def body(c, t):
            key, params, lrs, meta_state = c
            key, sub = jax.random.split(key)
            params, lrs, meta_state, loss = self._meta_step(
                sub, params, lrs, meta_state, x, y, counts,
                batch_size=batch_size,
            )
            val = chunked.boundary_val(
                lambda p: self._val_loss(p, val_x, val_y), params, t, eval_every
            )
            return (key, params, lrs, meta_state), (loss, val)

        ts = t0 + jnp.arange(chunk, dtype=jnp.int32)
        return chunked.scan_rounds(body, carry, ts, stop, patience=patience)

    # -- driver ---------------------------------------------------------
    def train(self, key, x, y, counts, *, batch_size: int = 64,
              steps: int = 100, engine: str = "scan",
              chunk: int | None = None, val_data=None, eval_every: int = 0,
              early_stop_patience: int = 0):
        """Meta-train.  ``engine="scan"`` (default) dispatches compiled
        chunks through ``chunked.dispatch_chunk``; ``engine="loop"`` is
        the original per-step jit loop (the parity oracle)."""
        if engine not in ("scan", "loop"):
            raise ValueError(f"unknown engine {engine!r}")
        x, y, counts = jnp.asarray(x), jnp.asarray(y), jnp.asarray(counts)
        val_x = val_y = None
        if val_data is not None:
            val_x, val_y = (jnp.asarray(v) for v in val_data)
        do_eval = bool(eval_every) and val_data is not None
        if early_stop_patience and not do_eval:
            raise ValueError(
                "early_stop_patience requires val_data and eval_every"
            )
        key, k_init = jax.random.split(key)
        params = self.model.init(k_init)
        lrs = jax.tree.map(lambda l: jnp.full_like(l, self.inner_lr), params)
        meta_state = (
            self.meta_opt.init({"params": params, "lrs": lrs})
            if self.learn_inner_lr
            else self.meta_opt.init(params)
        )
        history = []
        if engine == "loop":
            for t in range(steps):
                key, sub = jax.random.split(key)
                params, lrs, meta_state, loss = self._step_jit(
                    sub, params, lrs, meta_state, x, y, counts,
                    batch_size=batch_size,
                )
                rec = {"round": t, "loss": float(loss)}
                if do_eval and (t + 1) % eval_every == 0:
                    rec["val_loss"] = float(self._val_jit(params, val_x, val_y))
                history.append(rec)
            return params, lrs, history
        chunk = max(1, min(chunk or DEFAULT_CHUNK, steps))
        carry = (key, params, lrs, meta_state)
        stop = chunked.init_stop() if early_stop_patience else None
        t = 0
        while t < steps:
            c = min(chunk, steps - t)
            carry, stop, (losses, vals) = chunked.dispatch_chunk(
                self._chunk_jit, carry, stop, x, y, counts, val_x, val_y,
                jnp.int32(t), batch_size=batch_size, chunk=c,
                eval_every=eval_every if do_eval else 0,
                patience=early_stop_patience,
            )
            sr = int(np.asarray(stop.stop_round)) if stop is not None else -1
            stopped = chunked.drain_history(
                history, np.asarray(losses),
                np.asarray(vals) if do_eval else None, t,
                eval_every=eval_every if do_eval else 0, stop_round=sr,
            )
            t += c
            if stopped:
                break
        _, params, lrs, _ = carry
        return params, lrs, history


class MetaSGD(MAML):
    """MAML + learnable per-parameter inner learning rates."""

    learn_inner_lr = True
