"""Gossip parameter mixing — the paper's Step 2+3 as one primitive.

Given stacked node parameters (leaves ``(N, ...)``) and the round's
row-stochastic mixing matrix ``M`` (from ``topology.mixing_matrix``),
compute ``W <- M @ W``.

Three interchangeable implementations:
  * ``gossip_mix_tree``    — pure jnp einsum per leaf (reference; CPU),
  * ``gossip_mix_kernel``  — Pallas blocked kernel (repro.kernels),
  * ``sharded_gossip_mix`` — shard_map over a node-sharded axis
                             (repro.core.distributed) for fleet scale.

Each has a ``*_sparse`` twin taking ``core.topology.neighbor_table``'s
(N, B+1) ``(idx, wgt)`` representation instead of the dense (N, N)
matrix — same math to float tolerance (bitwise for inactive rows, which
take a where-select copy), O(N·B·D) instead of O(N²·D).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.utils.pytree import tree_weighted_mix

PyTree = Any


def gossip_mix_tree(stacked_params: PyTree, mix: jnp.ndarray) -> PyTree:
    """Reference implementation (einsum per leaf)."""
    return tree_weighted_mix(stacked_params, mix)


def gossip_mix_kernel(stacked_params: PyTree, mix: jnp.ndarray, active=None) -> PyTree:
    """Pallas-kernel implementation; identical math, VMEM-blocked."""
    from repro.kernels.ops import gossip_mix as _kernel_mix

    import jax

    def mix_leaf(l):
        flat = l.reshape(l.shape[0], -1)
        out = _kernel_mix(mix, flat, active)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params)


def gossip_mix_dp_kernel(
    stacked_params: PyTree, noise: PyTree, mix: jnp.ndarray, active=None
) -> PyTree:
    """Fused local-DP gossip (Pallas): noise-broadcast + mix +
    clean-self-restore in ONE pass per leaf —
    ``out = mix @ (w + noise) - diag(mix) * noise`` — instead of the
    three tree_map passes of the composed path.  ``noise`` is a pytree
    shaped like ``stacked_params`` (already scaled by sigma)."""
    from repro.kernels.ops import gossip_mix_dp as _kernel_dp

    import jax

    def mix_leaf(l, z):
        flat = l.reshape(l.shape[0], -1)
        out = _kernel_dp(mix, flat, z.reshape(z.shape[0], -1), active)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params, noise)


def sharded_gossip_mix(stacked_params: PyTree, mix: jnp.ndarray, active=None, **kw) -> PyTree:
    """Device-parallel implementation (re-export; see
    :func:`repro.core.distributed.sharded_gossip_mix`)."""
    from repro.core.distributed import sharded_gossip_mix as _sharded

    return _sharded(stacked_params, mix, active, **kw)


def gossip_mix_sparse_tree(
    stacked_params: PyTree, idx: jnp.ndarray, wgt: jnp.ndarray, active=None
) -> PyTree:
    """Sparse reference implementation: gather the B+1 referenced rows
    per output row and weight-sum them — ``out[n] = Σ_b wgt[n,b] ·
    w[idx[n,b]]``.  With ``active`` given, inactive rows take a
    where-select copy (bit-exact even against NaN/Inf in active rows);
    without it the table's identity rows (wgt ``[1, 0, ...]``) already
    make them float-exact copies."""
    import jax

    def mix_leaf(l):
        flat = l.reshape(l.shape[0], -1).astype(jnp.float32)
        out = jnp.einsum("nb,nbd->nd", wgt.astype(jnp.float32), flat[idx])
        if active is not None:
            out = jnp.where((active > 0)[:, None], out, flat)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params)


def gossip_mix_sparse_kernel(
    stacked_params: PyTree, idx: jnp.ndarray, wgt: jnp.ndarray, active=None
) -> PyTree:
    """Pallas sparse gather-mix per leaf (repro.kernels.ops)."""
    from repro.kernels.ops import gossip_mix_sparse as _kernel_sparse

    import jax

    def mix_leaf(l):
        flat = l.reshape(l.shape[0], -1)
        out = _kernel_sparse(idx, wgt, flat, active)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params)


def gossip_mix_sparse_dp_kernel(
    stacked_params: PyTree,
    noise: PyTree,
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    active=None,
) -> PyTree:
    """Fused sparse local-DP gossip (Pallas): noised-neighbour gather +
    clean-self-restore in one pass per leaf — ``out[n] = Σ_b
    wgt[n,b]·(w+z)[idx[n,b]] − wgt[n,0]·z[n]`` (slot 0 is self, so
    ``wgt[:, 0]`` IS the densified diagonal)."""
    from repro.kernels.ops import gossip_mix_sparse_dp as _kernel_dp

    import jax

    def mix_leaf(l, z):
        flat = l.reshape(l.shape[0], -1)
        out = _kernel_dp(idx, wgt, flat, z.reshape(z.shape[0], -1), active)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params, noise)


def sharded_gossip_mix_sparse(
    stacked_params: PyTree, idx: jnp.ndarray, wgt: jnp.ndarray, active=None, **kw
) -> PyTree:
    """Device-parallel sparse implementation (re-export; see
    :func:`repro.core.distributed.sharded_gossip_mix_sparse`)."""
    from repro.core.distributed import sharded_gossip_mix_sparse as _sharded

    return _sharded(stacked_params, idx, wgt, active, **kw)


def sharded_gossip_mix_gather(
    stacked_params: PyTree, idx: jnp.ndarray, wgt: jnp.ndarray, active=None, **kw
) -> PyTree:
    """Fully sharded gather-table implementation (re-export; see
    :func:`repro.core.distributed.sharded_gossip_mix_gather`) — the
    ``gossip_impl="gather"`` schedule with no gathered (N, D) spike."""
    from repro.core.distributed import sharded_gossip_mix_gather as _sharded

    return _sharded(stacked_params, idx, wgt, active, **kw)


def gossip_mix_masked(mixed: PyTree, idx: jnp.ndarray, wgt: jnp.ndarray, key) -> PyTree:
    """Secure-aggregation wrapper (``gossip_impl="masked"``): add the
    pairwise-mask cancellation term of ``core.secure_agg`` to an
    already-mixed state.  The term is EXACTLY ``+0.0`` everywhere (the
    uniform-row-weight masks pair up as exact IEEE negations), so the
    result is bit-identical to ``mixed`` while the per-edge mask
    generation — the priced overhead — stays live in the program.
    ``(idx, wgt)`` is the round's ``(N, B+1)`` neighbor table and ``key``
    the round's mask stream key; works after ANY base mixer (tree /
    kernel / sharded, dense or sparse)."""
    import jax

    from repro.core.secure_agg import masked_mix_zero

    zero = masked_mix_zero(mixed, idx, wgt, key)
    return jax.tree.map(jnp.add, mixed, zero)
