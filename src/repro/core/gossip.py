"""Gossip parameter mixing — the paper's Step 2+3 as one primitive.

Given stacked node parameters (leaves ``(N, ...)``) and the round's
row-stochastic mixing matrix ``M`` (from ``topology.mixing_matrix``),
compute ``W <- M @ W``.

Three interchangeable implementations:
  * ``gossip_mix_tree``    — pure jnp einsum per leaf (reference; CPU),
  * ``gossip_mix_kernel``  — Pallas blocked kernel (repro.kernels),
  * ``sharded_gossip_mix`` — shard_map over a node-sharded axis
                             (repro.core.distributed) for fleet scale.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.utils.pytree import tree_weighted_mix

PyTree = Any


def gossip_mix_tree(stacked_params: PyTree, mix: jnp.ndarray) -> PyTree:
    """Reference implementation (einsum per leaf)."""
    return tree_weighted_mix(stacked_params, mix)


def gossip_mix_kernel(stacked_params: PyTree, mix: jnp.ndarray, active=None) -> PyTree:
    """Pallas-kernel implementation; identical math, VMEM-blocked."""
    from repro.kernels.ops import gossip_mix as _kernel_mix

    import jax

    def mix_leaf(l):
        flat = l.reshape(l.shape[0], -1)
        out = _kernel_mix(mix, flat, active)
        return out.reshape(l.shape).astype(l.dtype)

    return jax.tree.map(mix_leaf, stacked_params)
