"""Config system: typed dataclasses + a registry + dotted CLI overrides.

Every assigned architecture registers an :class:`ArchConfig` under its id
(``--arch <id>``); the paper's own glucose LSTM registers under
``glucose-lstm``.  ``apply_overrides`` supports ``key.subkey=value`` CLI
strings with type coercion from the dataclass annotation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


# --------------------------------------------------------------------------
# Architecture configs (assigned pool + the paper's model)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """One selectable architecture.

    ``family`` drives assembly:
      dense | moe | ssm | hybrid | encdec | vlm | lstm
    """

    name: str
    family: str
    citation: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    expert_capacity_factor: float = 1.25
    # attention flavour
    sliding_window: int = 0          # 0 = full attention
    attn_bias: bool = False          # qwen-style QKV bias
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    # hybrid (recurrentgemma): pattern of block kinds, e.g. ("rglru","rglru","attn")
    block_pattern: tuple = ()
    lru_width: int = 0
    local_attn_window: int = 2048
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0             # frames after the (stubbed) conv frontend
    # vlm
    vision_tokens: int = 0           # patch-embedding prefix length (stub frontend)
    # parallel attention+MLP residual branches (PaLM-style) — §Perf
    # beyond-paper variant: halves the per-layer activation all-reduces
    parallel_block: bool = False
    # numerics
    dtype: str = "bfloat16"
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def reduced(self) -> "ArchConfig":
        """A small same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_chunk=16 if self.ssm_state else 64,
            lru_width=min(self.lru_width, 256) if self.lru_width else 0,
            local_attn_window=64,
            sliding_window=64 if self.sliding_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_seq else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            block_pattern=self.block_pattern,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline 6ND)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "lstm":
            return emb  # unused for lstm family
        per_layer = 0
        # attention (dense/moe/vlm/encdec decoder)
        attn = (
            d * self.num_heads * self.head_dim
            + 2 * d * self.num_kv_heads * self.head_dim
            + self.num_heads * self.head_dim * d
        )
        if self.family in ("dense", "moe", "vlm"):
            per_layer += attn
            if self.num_experts:
                per_layer += self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            else:
                per_layer += 3 * d * self.d_ff
            per_layer += 2 * d  # norms
            return emb + L * per_layer
        if self.family == "ssm":
            d_inner = self.ssm_expand * d
            per_layer = (
                d * (2 * d_inner + 2 * self.ssm_state * self.ssm_heads)  # in_proj-ish
                + d_inner * d
                + 3 * self.ssm_heads
                + 2 * d
            )
            return emb + L * per_layer
        if self.family == "hybrid":
            w = self.lru_width or d
            rglru = d * 2 * w + w * d + 3 * w + 2 * d
            attn_l = attn + 2 * d
            mlp = 3 * d * self.d_ff
            n_attn = sum(1 for b in self.block_pattern for _ in [b] if b == "attn")
            pat = self.block_pattern or ("rglru", "rglru", "attn")
            n_att = sum(1 for b in pat if b == "attn")
            frac_att = n_att / len(pat)
            return emb + int(L * (frac_att * attn_l + (1 - frac_att) * rglru + mlp))
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + 2 * d * self.d_ff + 2 * d)
            dec = L * (2 * attn + 2 * d * self.d_ff + 3 * d)
            return emb + enc + dec
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Activated params per token (MoE discounts inactive experts)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        all_experts = L * self.num_experts * 3 * d * self.d_ff
        active = L * self.experts_per_token * 3 * d * self.d_ff
        return total - all_experts + active


_ARCH_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _ARCH_REGISTRY[name] = fn
        return fn

    return deco


def get_arch_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if name not in _ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_REGISTRY)}")
    return _ARCH_REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_ARCH_REGISTRY)


# --------------------------------------------------------------------------
# Federated-learning / data / training configs (the paper's side)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class FLConfig:
    topology: str = "random"          # ring | cluster | random | star | full
    num_nodes: int = 12
    comm_batch: int = 7               # B in Algorithm 1 (paper uses B=7)
    rounds: int = 100
    local_steps: int = 1
    inactive_ratio: float = 0.0       # fraction of nodes inactive per round
    schedule: str = "bernoulli"       # bernoulli | markov (sticky staleness)
    p_stay_active: float = 0.9        # markov: P(active -> active)
    p_stay_inactive: float = 0.7      # markov: P(inactive -> inactive)
    data_skew: float = 0.0            # non-IID per-node mg/dL shift strength
    cluster_size: int = 4
    seed: int = 0


@dataclass(frozen=True)
class SweepConfig:
    """The scenario grid :meth:`repro.core.GluADFL.train_sweep` batches
    into one compiled program — defaults are the paper's Fig-5 grid
    (3 topologies x 5 inactive ratios, seed 0).  ``seeds`` is a count:
    seeds ``0..seeds-1`` each become a scenario replica.

    The optional axes (``schedules``, ``skews``, ``dp_sigmas``) extend
    the cross product with Markov-sticky staleness, non-IID data skew,
    and DP noise levels; their defaults leave the grid exactly the
    classic 3-axis one (3-tuple labels, unchanged numerics)."""

    topologies: tuple = ("ring", "cluster", "random")
    inactive_ratios: tuple = (0.0, 0.3, 0.5, 0.7, 0.9)
    seeds: int = 1
    schedules: tuple = ()             # e.g. ("bernoulli", "markov")
    skews: tuple = ()                 # e.g. (0.0, 0.5, 1.0) — mg/dL-shift strengths
    dp_sigmas: tuple = ()             # e.g. (0.0, 0.01, 0.05) — gossip DP sigma

    def seed_list(self) -> tuple:
        return tuple(range(self.seeds))


@dataclass(frozen=True)
class DataConfig:
    dataset: str = "ohiot1dm"         # ohiot1dm | abc4d | ctr3 | replace-bg
    history_len: int = 12             # L = 12 (2 hours at 5-min sampling)
    horizon: int = 6                  # H = 6 (30 minutes)
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 1e-3
    batch_size: int = 64
    steps: int = 200
    optimizer: str = "adam"
    hidden_size: int = 128            # LSTM hidden (paper sweeps {128,256,512})
    seed: int = 0


@dataclass(frozen=True)
class ExperimentConfig:
    fl: FLConfig = field(default_factory=FLConfig)
    data: DataConfig = field(default_factory=DataConfig)
    train: TrainConfig = field(default_factory=TrainConfig)


def _coerce(val: str, typ: Any) -> Any:
    if typ is bool:
        return val.lower() in ("1", "true", "yes")
    if typ is int:
        return int(val)
    if typ is float:
        return float(val)
    return val


def apply_overrides(cfg: Any, overrides: list[str]) -> Any:
    """Apply ``a.b=c`` style overrides to (nested, frozen) dataclasses."""
    for ov in overrides:
        key, _, val = ov.partition("=")
        parts = key.split(".")
        cfg = _set_path(cfg, parts, val)
    return cfg


def _set_path(cfg: Any, parts: list[str], val: str) -> Any:
    name = parts[0]
    fields = {f.name: f for f in dataclasses.fields(cfg)}
    if name not in fields:
        raise KeyError(f"no config field {name!r} on {type(cfg).__name__}")
    if len(parts) == 1:
        typ = fields[name].type
        typ = {"int": int, "float": float, "str": str, "bool": bool}.get(typ, typ)
        return dataclasses.replace(cfg, **{name: _coerce(val, typ)})
    sub = getattr(cfg, name)
    return dataclasses.replace(cfg, **{name: _set_path(sub, parts[1:], val)})
