"""Typed configuration dataclasses + the architecture registry and
dotted-override CLI parsing (``a.b=c``) — see ``config/base.py``."""
from repro.config.base import (
    ArchConfig,
    FLConfig,
    SweepConfig,
    DataConfig,
    TrainConfig,
    ExperimentConfig,
    register_arch,
    get_arch_config,
    list_archs,
    apply_overrides,
)
