from repro.config.base import (
    ArchConfig,
    FLConfig,
    DataConfig,
    TrainConfig,
    ExperimentConfig,
    register_arch,
    get_arch_config,
    list_archs,
    apply_overrides,
)
