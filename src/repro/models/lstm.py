"""Single-layer LSTM glucose predictor (the paper's model, §3.2).

A univariate CGM history (B, L) is embedded per step, run through one
LSTM layer (lax.scan of a fused cell), and the last hidden state is
projected to the H-step-ahead glucose level.

The cell math lives in ``repro.kernels.lstm_cell``'s reference path so the
Pallas kernel and the model share one definition; the model defaults to
the pure-jnp path (CPU) and can be switched to the Pallas kernel with
``use_kernel=True`` (interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import Model


def lstm_cell_ref(x_t, h, c, wx, wh, b):
    """One LSTM step: gates ordered (i, f, g, o).  Shapes:
    x_t (B, I), h/c (B, H), wx (I, 4H), wh (H, 4H), b (4H,).
    """
    z = x_t @ wx + h @ wh + b
    i, f, g, o = jnp.split(z, 4, axis=-1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


@dataclass(frozen=True)
class LSTMModel:
    history_len: int = 12
    hidden: int = 128
    input_size: int = 1
    use_kernel: bool = False

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        H, I = self.hidden, self.input_size
        scale_x = 1.0 / jnp.sqrt(I)
        scale_h = 1.0 / jnp.sqrt(H)
        b = jnp.zeros((4 * H,))
        # forget-gate bias 1.0 (standard LSTM init)
        b = b.at[H : 2 * H].set(1.0)
        return {
            "wx": jax.random.normal(k1, (I, 4 * H)) * scale_x,
            "wh": jax.random.normal(k2, (H, 4 * H)) * scale_h,
            "b": b,
            "w_out": jax.random.normal(k3, (H, 1)) * scale_h,
            "b_out": jnp.zeros((1,)),
        }

    def apply(self, params, x):
        """x: (B, L) normalized glucose -> (B,) prediction."""
        B, L = x.shape
        xs = x[..., None]  # (B, L, 1) univariate input
        h = jnp.zeros((B, self.hidden), x.dtype)
        c = jnp.zeros((B, self.hidden), x.dtype)

        if self.use_kernel:
            from repro.kernels.ops import lstm_cell as cell_op

            def step(carry, x_t):
                h, c = carry
                h, c = cell_op(x_t, h, c, params["wx"], params["wh"], params["b"])
                return (h, c), None
        else:

            def step(carry, x_t):
                h, c = carry
                h, c = lstm_cell_ref(x_t, h, c, params["wx"], params["wh"], params["b"])
                return (h, c), None

        (h, c), _ = jax.lax.scan(step, (h, c), jnp.swapaxes(xs, 0, 1))
        out = h @ params["w_out"] + params["b_out"]
        return out[:, 0]

    def as_model(self) -> Model:
        return Model("lstm", self.init, self.apply)
