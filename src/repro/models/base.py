"""Uniform functional model interface for the glucose predictors.

A Model is a pair of pure functions:
  init(key)            -> params pytree
  apply(params, x)     -> (B,) prediction from (B, L) history

so every trainer (supervised, FedAvg, GluADFL, MAML...) is model-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class Model:
    name: str
    init: Callable[[Any], PyTree]
    apply: Callable[[PyTree, jnp.ndarray], jnp.ndarray]


def get_model(name: str, history_len: int = 12, hidden: int = 128, **kw) -> Model:
    from repro.models import MODEL_REGISTRY

    cls = MODEL_REGISTRY[name]
    return cls(history_len=history_len, hidden=hidden, **kw).as_model()
