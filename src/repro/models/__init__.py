"""Per-node forecasting models: the paper's LSTM plus the baseline pool
it is compared against (linear regression, N-BEATS, N-HiTS, gradient-
boosted trees).  All share the ``Model`` protocol (``init``/``apply``
on stacked params) so the FL engines can vmap them over the federation."""
from repro.models.lstm import LSTMModel
from repro.models.linear import LinearModel
from repro.models.nbeats import NBeatsModel
from repro.models.nhits import NHiTSModel
from repro.models.gbt import GradientBoostedTrees
from repro.models.base import Model, get_model

MODEL_REGISTRY = {
    "lstm": LSTMModel,
    "lr": LinearModel,
    "nbeats": NBeatsModel,
    "nhits": NHiTSModel,
}
