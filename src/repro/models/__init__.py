from repro.models.lstm import LSTMModel
from repro.models.linear import LinearModel
from repro.models.nbeats import NBeatsModel
from repro.models.nhits import NHiTSModel
from repro.models.gbt import GradientBoostedTrees
from repro.models.base import Model, get_model

MODEL_REGISTRY = {
    "lstm": LSTMModel,
    "lr": LinearModel,
    "nbeats": NBeatsModel,
    "nhits": NHiTSModel,
}
