"""NHiTS (Challu et al., AAAI'23) for single-point BGLP.

Hierarchical interpolation + multi-rate input pooling: each stack sees a
max-pooled (coarsened) view of the residual input, emits low-dimensional
backcast/forecast coefficients, and linearly interpolates them back to
full resolution.  Pool sizes decrease across stacks (coarse -> fine),
specializing stacks to frequency bands, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import Model
from repro.models.nbeats import _dense, _dense_init


def _maxpool1d(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """(B, L) -> (B, ceil(L/k)) max pooling with edge padding."""
    if k <= 1:
        return x
    B, L = x.shape
    pad = (-L) % k
    xp = jnp.pad(x, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return xp.reshape(B, -1, k).max(axis=-1)


def _interp1d(coef: jnp.ndarray, out_len: int) -> jnp.ndarray:
    """(B, C) -> (B, out_len) linear interpolation of knot values."""
    B, C = coef.shape
    if C == out_len:
        return coef
    pos = jnp.linspace(0.0, C - 1.0, out_len)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, C - 1)
    hi = jnp.clip(lo + 1, 0, C - 1)
    frac = pos - lo
    return coef[:, lo] * (1 - frac) + coef[:, hi] * frac


@dataclass(frozen=True)
class NHiTSModel:
    history_len: int = 12
    hidden: int = 128
    num_layers: int = 2
    pool_sizes: tuple = (4, 2, 1)      # coarse -> fine stacks
    backcast_knots: tuple = (4, 6, 12)  # interpolation knots per stack

    def init(self, key):
        stacks = []
        for pool, knots in zip(self.pool_sizes, self.backcast_knots):
            key, sub = jax.random.split(key)
            in_len = -(-self.history_len // pool)  # ceil
            ks = jax.random.split(sub, self.num_layers + 2)
            layers = [_dense_init(ks[0], in_len, self.hidden)] + [
                _dense_init(ks[i], self.hidden, self.hidden)
                for i in range(1, self.num_layers)
            ]
            stacks.append(
                {
                    "layers": layers,
                    "backcast": _dense_init(ks[-2], self.hidden, knots),
                    "forecast": _dense_init(ks[-1], self.hidden, 1),
                }
            )
        return {"stacks": stacks}

    def apply(self, params, x):
        residual = x
        forecast = jnp.zeros((x.shape[0], 1), x.dtype)
        for stack, pool in zip(params["stacks"], self.pool_sizes):
            h = _maxpool1d(residual, pool)
            for lyr in stack["layers"]:
                h = jax.nn.relu(_dense(lyr, h))
            back = _interp1d(_dense(stack["backcast"], h), self.history_len)
            residual = residual - back
            forecast = forecast + _dense(stack["forecast"], h)
        return forecast[:, 0]

    def as_model(self) -> Model:
        return Model("nhits", self.init, self.apply)
