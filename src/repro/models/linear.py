"""Linear-regression baseline (paper's LR).

Trainable by SGD like every other model, plus a closed-form ridge solve
(`fit_closed_form`) used by the supervised-baseline benchmark for speed.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import Model


@dataclass(frozen=True)
class LinearModel:
    history_len: int = 12
    hidden: int = 0  # unused; uniform ctor signature

    def init(self, key):
        return {
            "w": jnp.zeros((self.history_len,)),
            "b": jnp.zeros(()),
        }

    def apply(self, params, x):
        return x @ params["w"] + params["b"]

    def as_model(self) -> Model:
        return Model("lr", self.init, self.apply)


def fit_closed_form(x: jnp.ndarray, y: jnp.ndarray, l2: float = 1e-3):
    """Ridge regression: returns the LinearModel params pytree."""
    n, d = x.shape
    xb = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    gram = xb.T @ xb + l2 * jnp.eye(d + 1, dtype=x.dtype)
    coef = jnp.linalg.solve(gram, xb.T @ y)
    return {"w": coef[:d], "b": coef[d]}
