"""Gradient-boosted regression trees (the paper's XGBoost baseline), JAX.

Second-order boosting on squared error (grad = residual, hess = 1) with
depth-limited binary trees, candidate thresholds at feature quantiles,
lambda L2 leaf regularization and shrinkage — the XGBoost objective on a
12-feature input, built from scratch.

Trees are stored as dense arrays (feature id / threshold per internal
node, value per leaf), so prediction is a fully-vectorized jnp traversal
(no recursion) and jit/vmap friendly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass
class GBTParams:
    feats: jnp.ndarray    # (T, NInternal) int32
    thresh: jnp.ndarray   # (T, NInternal) float32
    leaves: jnp.ndarray   # (T, NLeaves) float32
    base: float
    lr: float
    depth: int


class GradientBoostedTrees:
    def __init__(
        self,
        history_len: int = 12,
        hidden: int = 0,  # unused; uniform ctor signature
        num_trees: int = 50,
        depth: int = 4,
        lr: float = 0.1,
        reg_lambda: float = 1.0,
        num_thresholds: int = 16,
    ):
        self.history_len = history_len
        self.num_trees = num_trees
        self.depth = depth
        self.lr = lr
        self.reg_lambda = reg_lambda
        self.num_thresholds = num_thresholds

    # -- fitting (host-side, vectorized gain search) ----------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> GBTParams:
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        n, d = x.shape
        base = float(y.mean())
        pred = np.full(n, base, np.float32)

        # candidate thresholds: per-feature quantiles
        qs = np.linspace(0.05, 0.95, self.num_thresholds)
        cand = np.quantile(x, qs, axis=0)  # (Q, d)

        n_internal = 2**self.depth - 1
        n_leaves = 2**self.depth
        feats = np.zeros((self.num_trees, n_internal), np.int32)
        thresh = np.zeros((self.num_trees, n_internal), np.float32)
        leaves = np.zeros((self.num_trees, n_leaves), np.float32)

        for t in range(self.num_trees):
            grad = pred - y  # d/dpred 0.5*(pred-y)^2
            node_of = np.zeros(n, np.int32)  # current node id per sample
            for level in range(self.depth):
                start = 2**level - 1
                for node in range(start, 2 ** (level + 1) - 1):
                    mask = node_of == node
                    if mask.sum() < 4:
                        feats[t, node] = 0
                        thresh[t, node] = -np.inf  # all go right
                        continue
                    xg, gg = x[mask], grad[mask]
                    gsum = gg.sum()
                    csum = mask.sum()
                    # gain for every (feature, threshold): vectorized
                    left = xg[:, None, :] <= cand[None, :, :]  # (m, Q, d)
                    gl = np.einsum("m,mqd->qd", gg, left)
                    cl = left.sum(axis=0)
                    gr = gsum - gl
                    cr = csum - cl
                    lam = self.reg_lambda
                    gain = gl**2 / (cl + lam) + gr**2 / (cr + lam) - gsum**2 / (csum + lam)
                    gain[(cl < 2) | (cr < 2)] = -np.inf
                    q_best, f_best = np.unravel_index(np.argmax(gain), gain.shape)
                    feats[t, node] = f_best
                    thresh[t, node] = cand[q_best, f_best]
                # descend all samples one level
                f = feats[t, node_of]
                th = thresh[t, node_of]
                go_left = x[np.arange(n), f] <= th
                node_of = 2 * node_of + np.where(go_left, 1, 2)
            leaf_ids = node_of - n_internal
            for leaf in range(n_leaves):
                mask = leaf_ids == leaf
                g = grad[mask]
                leaves[t, leaf] = (
                    0.0 if mask.sum() == 0 else -g.sum() / (mask.sum() + self.reg_lambda)
                )
            pred = pred + self.lr * leaves[t, leaf_ids]

        return GBTParams(
            jnp.asarray(feats), jnp.asarray(thresh), jnp.asarray(leaves),
            base, self.lr, self.depth,
        )

    # -- prediction (pure jnp) --------------------------------------------
    def predict(self, params: GBTParams, x: jnp.ndarray) -> jnp.ndarray:
        n = x.shape[0]
        n_internal = params.feats.shape[1]

        def one_tree(carry, tree):
            pred = carry
            feats, thresh, leaves = tree
            node = jnp.zeros(n, jnp.int32)
            for _ in range(params.depth):
                f = feats[node]
                th = thresh[node]
                go_left = x[jnp.arange(n), f] <= th
                node = 2 * node + jnp.where(go_left, 1, 2)
            pred = pred + params.lr * leaves[node - n_internal]
            return pred, None

        init = jnp.full(n, params.base, x.dtype)
        pred, _ = __import__("jax").lax.scan(
            one_tree, init, (params.feats, params.thresh, params.leaves)
        )
        return pred
