"""N-BEATS (Oreshkin et al., ICLR'20) for single-point BGLP.

Generic-basis N-BEATS: a stack of fully-connected blocks; each block
emits a *backcast* (subtracted from the residual input) and a *forecast*
(accumulated).  We use the generic block form (no interpretable basis)
with a 1-point forecast head, matching the paper's use of N-BEATS as a
point-prediction baseline.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import Model


def _dense_init(key, n_in, n_out):
    k1, k2 = jax.random.split(key)
    lim = 1.0 / jnp.sqrt(n_in)
    return {
        "w": jax.random.uniform(k1, (n_in, n_out), minval=-lim, maxval=lim),
        "b": jnp.zeros((n_out,)),
    }


def _dense(p, x):
    return x @ p["w"] + p["b"]


@dataclass(frozen=True)
class NBeatsModel:
    history_len: int = 12
    hidden: int = 128
    num_blocks: int = 3
    num_layers: int = 3  # FC layers per block

    def init(self, key):
        blocks = []
        for b in range(self.num_blocks):
            key, sub = jax.random.split(key)
            ks = jax.random.split(sub, self.num_layers + 2)
            layers = [
                _dense_init(ks[0], self.history_len, self.hidden)
            ] + [
                _dense_init(ks[i], self.hidden, self.hidden)
                for i in range(1, self.num_layers)
            ]
            blocks.append(
                {
                    "layers": layers,
                    "backcast": _dense_init(ks[-2], self.hidden, self.history_len),
                    "forecast": _dense_init(ks[-1], self.hidden, 1),
                }
            )
        return {"blocks": blocks}

    def apply(self, params, x):
        residual = x
        forecast = jnp.zeros((x.shape[0], 1), x.dtype)
        for blk in params["blocks"]:
            h = residual
            for lyr in blk["layers"]:
                h = jax.nn.relu(_dense(lyr, h))
            residual = residual - _dense(blk["backcast"], h)
            forecast = forecast + _dense(blk["forecast"], h)
        return forecast[:, 0]

    def as_model(self) -> Model:
        return Model("nbeats", self.init, self.apply)
