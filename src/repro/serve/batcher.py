"""Request micro-batching for the BG-forecast service: a host-side
queue that turns an asynchronous request stream into padded-bucket
batches the per-bucket-compiled ``GlucoseServable.forecast`` method can
run without recompiling.

Policy (saxml-style):

  * **pad-to-bucket** — a formed batch is sized to the smallest
    configured bucket that fits it (:func:`bucket_for`); the servable
    pads the remainder, so XLA only ever sees ``len(buckets)`` shapes;
  * **formation** — a batch forms as soon as the queue can fill the
    LARGEST bucket (throughput), or when the oldest queued request has
    waited ``flush_timeout`` seconds (latency floor for trickle
    traffic);
  * **admission** — at most ``max_live_batches`` formed-but-unfinished
    batches exist at once; :meth:`MicroBatcher.ready` returns ``None``
    while the service is saturated, bounding queue->device inflight
    memory;
  * **failure** — a batch whose execution raised must be handed back via
    :meth:`MicroBatcher.fail` (the ``except`` twin of
    :meth:`MicroBatcher.complete`): it frees the admission slot and
    either requeues the requests at the FRONT of the queue (transient
    errors) or drops them with accounting.  Without it an exception
    between formation and completion leaks the slot forever and
    admission permanently saturates;
  * **accounting** — every request is stamped at submit / batch-start /
    completion, and :meth:`MicroBatcher.stats` reduces the finished
    stream to p50/p99 latency, mean queue wait, and throughput (plus
    failed/dropped counts; non-finite stamps are excluded so a stray
    never-completed request cannot NaN the percentiles).

Everything here is plain Python on the host — no jax — and the clock is
injectable (``clock=``), so the whole policy is unit-testable with a
fake clock (``tests/test_serve.py``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= ``n``, or the largest bucket when ``n``
    overflows every one (the caller then splits the batch).  ``buckets``
    must be sorted ascending (the :class:`MicroBatcher`/servable
    constructors normalize this)."""
    if n < 1:
        raise ValueError(f"batch of {n} requests has no bucket")
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class Request:
    """One CGM-window -> BG-forecast request.

    ``patient`` names a row of the servable's param store (0 is always
    the population model — the brand-new-patient default; personalized
    patients get their own row).  Timestamps are stamped by the batcher:
    ``t_submit`` at :meth:`MicroBatcher.submit`, ``t_start`` when its
    batch forms, ``t_done`` at :meth:`MicroBatcher.complete`.
    """

    rid: int
    patient: int
    window: np.ndarray  # (L,) normalized CGM history
    t_submit: float = field(default=float("nan"))
    t_start: float = field(default=float("nan"))
    t_done: float = field(default=float("nan"))

    @property
    def latency(self) -> float:
        """Submit-to-completion seconds (queue wait + execution)."""
        return self.t_done - self.t_submit

    @property
    def queue_wait(self) -> float:
        """Submit-to-batch-formation seconds."""
        return self.t_start - self.t_submit


class MicroBatcher:
    """The admission/formation policy around a ``deque`` of requests.

    The caller drives it:  ``submit()`` incoming requests, poll
    ``ready()`` for the next formed batch (``None`` = keep waiting),
    run the batch, then ``complete()`` it so its admission slot frees
    and its requests' latencies are recorded.  ``flush()`` force-forms
    the tail at shutdown/drain time regardless of the timeout (but
    still honoring admission).
    """

    def __init__(
        self,
        buckets: tuple[int, ...] = (1, 4, 16, 64),
        *,
        max_live_batches: int = 4,
        flush_timeout: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
    ):
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"need >= 1 positive bucket size, got {buckets!r}")
        if max_live_batches < 1:
            raise ValueError("max_live_batches must be >= 1")
        self.buckets = buckets
        self.max_live_batches = max_live_batches
        self.flush_timeout = flush_timeout
        self._clock = clock
        self._queue: deque[Request] = deque()
        self._live = 0
        self._finished: list[Request] = []
        self._failed_batches = 0
        self._dropped = 0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        """Enqueue a request (stamps its arrival time)."""
        req.t_submit = self._clock()
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def live_batches(self) -> int:
        return self._live

    # --------------------------------------------------------- formation
    def _form(self, k: int) -> list[Request]:
        now = self._clock()
        batch = [self._queue.popleft() for _ in range(k)]
        for r in batch:
            r.t_start = now
        self._live += 1
        return batch

    def ready(self) -> Optional[list[Request]]:
        """The next batch to run, or ``None`` (queue empty, timeout not
        reached, or admission saturated).  A full largest bucket forms
        immediately; otherwise the queue waits out ``flush_timeout``
        from the OLDEST request's submit time, then ships everything
        queued (capped at the largest bucket)."""
        if self._live >= self.max_live_batches or not self._queue:
            return None
        cap = self.buckets[-1]
        if len(self._queue) >= cap:
            return self._form(cap)
        if self._clock() - self._queue[0].t_submit >= self.flush_timeout:
            return self._form(len(self._queue))
        return None

    def flush(self) -> Optional[list[Request]]:
        """Force-form the queued tail (drain path) — admission still
        applies, so call :meth:`complete` between flushes."""
        if self._live >= self.max_live_batches or not self._queue:
            return None
        return self._form(min(len(self._queue), self.buckets[-1]))

    # -------------------------------------------------------- accounting
    def complete(self, batch: list[Request]) -> None:
        """Record a run batch: frees its admission slot and stamps +
        collects per-request completion times."""
        now = self._clock()
        self._live -= 1
        assert self._live >= 0, "complete() without a matching ready()/flush()"
        for r in batch:
            r.t_done = now
        self._finished.extend(batch)

    def fail(self, batch: list[Request], *, requeue: bool = False) -> None:
        """Hand back a batch whose execution RAISED — the ``except``-path
        twin of :meth:`complete`.  Frees the admission slot (without it
        the slot leaks and ``ready()`` saturates forever), then either
        requeues the requests at the front of the queue in their original
        order (``requeue=True`` — transient failures; their submit stamps
        survive, so the flush timeout still honors true arrival time and
        an eventual completion reports true end-to-end latency) or drops
        them with accounting (``requeue=False`` — the default: a batch
        that crashed the model is usually poisoned input)."""
        self._live -= 1
        assert self._live >= 0, "fail() without a matching ready()/flush()"
        self._failed_batches += 1
        if requeue:
            for r in batch:
                r.t_start = float("nan")  # re-stamped when it re-forms
            self._queue.extendleft(reversed(batch))
        else:
            self._dropped += len(batch)

    def stats(self) -> dict:
        """Latency/throughput summary of every completed request:
        p50/p99 latency (ms), mean queue wait (ms), requests completed,
        forecasts/sec over the completed span, and failure accounting
        (``failed_batches``, ``dropped``).  Requests that never ran to
        completion carry NaN stamps — they are excluded from every
        reduction, so the percentiles stay finite no matter what the
        caller mixed into the stream."""
        base = {"failed_batches": self._failed_batches, "dropped": self._dropped}
        done = [
            r for r in self._finished
            if np.isfinite(r.t_submit) and np.isfinite(r.t_done)
        ]
        if not done:
            return {"completed": 0, **base}
        lat = np.asarray([r.latency for r in done])
        wait = np.asarray([r.queue_wait for r in done])
        wait = wait[np.isfinite(wait)]
        span = max(r.t_done for r in done) - min(r.t_submit for r in done)
        return {
            "completed": len(done),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_queue_wait_ms": (
                float(wait.mean() * 1e3) if wait.size else float("nan")
            ),
            "forecasts_per_sec": (
                len(done) / span if span > 0 else float("inf")
            ),
            **base,
        }
