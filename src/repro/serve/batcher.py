"""Request micro-batching for the BG-forecast service: a host-side
queue that turns an asynchronous request stream into padded-bucket
batches the per-bucket-compiled ``GlucoseServable.forecast`` method can
run without recompiling.

Policy (saxml-style):

  * **pad-to-bucket** — a formed batch is sized to the smallest
    configured bucket that fits it (:func:`bucket_for`); the servable
    pads the remainder, so XLA only ever sees ``len(buckets)`` shapes;
  * **formation** — a batch forms as soon as the queue can fill the
    LARGEST bucket (throughput), or when the oldest queued request has
    waited ``flush_timeout`` seconds (latency floor for trickle
    traffic);
  * **admission** — at most ``max_live_batches`` formed-but-unfinished
    batches exist at once; :meth:`MicroBatcher.ready` returns ``None``
    while the service is saturated, bounding queue->device inflight
    memory;
  * **accounting** — every request is stamped at submit / batch-start /
    completion, and :meth:`MicroBatcher.stats` reduces the finished
    stream to p50/p99 latency, mean queue wait, and throughput.

Everything here is plain Python on the host — no jax — and the clock is
injectable (``clock=``), so the whole policy is unit-testable with a
fake clock (``tests/test_serve.py``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= ``n``, or the largest bucket when ``n``
    overflows every one (the caller then splits the batch).  ``buckets``
    must be sorted ascending (the :class:`MicroBatcher`/servable
    constructors normalize this)."""
    if n < 1:
        raise ValueError(f"batch of {n} requests has no bucket")
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class Request:
    """One CGM-window -> BG-forecast request.

    ``patient`` names a row of the servable's param store (0 is always
    the population model — the brand-new-patient default; personalized
    patients get their own row).  Timestamps are stamped by the batcher:
    ``t_submit`` at :meth:`MicroBatcher.submit`, ``t_start`` when its
    batch forms, ``t_done`` at :meth:`MicroBatcher.complete`.
    """

    rid: int
    patient: int
    window: np.ndarray  # (L,) normalized CGM history
    t_submit: float = field(default=float("nan"))
    t_start: float = field(default=float("nan"))
    t_done: float = field(default=float("nan"))

    @property
    def latency(self) -> float:
        """Submit-to-completion seconds (queue wait + execution)."""
        return self.t_done - self.t_submit

    @property
    def queue_wait(self) -> float:
        """Submit-to-batch-formation seconds."""
        return self.t_start - self.t_submit


class MicroBatcher:
    """The admission/formation policy around a ``deque`` of requests.

    The caller drives it:  ``submit()`` incoming requests, poll
    ``ready()`` for the next formed batch (``None`` = keep waiting),
    run the batch, then ``complete()`` it so its admission slot frees
    and its requests' latencies are recorded.  ``flush()`` force-forms
    the tail at shutdown/drain time regardless of the timeout (but
    still honoring admission).
    """

    def __init__(
        self,
        buckets: tuple[int, ...] = (1, 4, 16, 64),
        *,
        max_live_batches: int = 4,
        flush_timeout: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
    ):
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"need >= 1 positive bucket size, got {buckets!r}")
        if max_live_batches < 1:
            raise ValueError("max_live_batches must be >= 1")
        self.buckets = buckets
        self.max_live_batches = max_live_batches
        self.flush_timeout = flush_timeout
        self._clock = clock
        self._queue: deque[Request] = deque()
        self._live = 0
        self._finished: list[Request] = []

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        """Enqueue a request (stamps its arrival time)."""
        req.t_submit = self._clock()
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def live_batches(self) -> int:
        return self._live

    # --------------------------------------------------------- formation
    def _form(self, k: int) -> list[Request]:
        now = self._clock()
        batch = [self._queue.popleft() for _ in range(k)]
        for r in batch:
            r.t_start = now
        self._live += 1
        return batch

    def ready(self) -> Optional[list[Request]]:
        """The next batch to run, or ``None`` (queue empty, timeout not
        reached, or admission saturated).  A full largest bucket forms
        immediately; otherwise the queue waits out ``flush_timeout``
        from the OLDEST request's submit time, then ships everything
        queued (capped at the largest bucket)."""
        if self._live >= self.max_live_batches or not self._queue:
            return None
        cap = self.buckets[-1]
        if len(self._queue) >= cap:
            return self._form(cap)
        if self._clock() - self._queue[0].t_submit >= self.flush_timeout:
            return self._form(len(self._queue))
        return None

    def flush(self) -> Optional[list[Request]]:
        """Force-form the queued tail (drain path) — admission still
        applies, so call :meth:`complete` between flushes."""
        if self._live >= self.max_live_batches or not self._queue:
            return None
        return self._form(min(len(self._queue), self.buckets[-1]))

    # -------------------------------------------------------- accounting
    def complete(self, batch: list[Request]) -> None:
        """Record a run batch: frees its admission slot and stamps +
        collects per-request completion times."""
        now = self._clock()
        self._live -= 1
        assert self._live >= 0, "complete() without a matching ready()/flush()"
        for r in batch:
            r.t_done = now
        self._finished.extend(batch)

    def stats(self) -> dict:
        """Latency/throughput summary of every completed request:
        p50/p99 latency (ms), mean queue wait (ms), requests completed,
        and forecasts/sec over the completed span."""
        if not self._finished:
            return {"completed": 0}
        lat = np.asarray([r.latency for r in self._finished])
        wait = np.asarray([r.queue_wait for r in self._finished])
        span = max(r.t_done for r in self._finished) - min(
            r.t_submit for r in self._finished
        )
        return {
            "completed": len(self._finished),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_queue_wait_ms": float(wait.mean() * 1e3),
            "forecasts_per_sec": (
                len(self._finished) / span if span > 0 else float("inf")
            ),
        }
