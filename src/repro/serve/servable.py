"""The glucose servable: checkpoint -> personalize -> forecast, with
every device-side method compiled once per padded batch-size bucket.

:class:`GlucoseServable` owns

  * the **population model** (row 0 of the param store) loaded from a
    federation checkpoint (:func:`load_population` infers the LSTM
    width from the flat parameter count, same recovery the checkpoint
    tests use);
  * the **param store** — a stacked pytree of per-patient parameter
    rows.  Cold-start patients are added by
    :meth:`GlucoseServable.personalize`, which runs
    ``core.personalize.personalize_batch`` (one ``lax.scan``-compiled,
    ``vmap``-batched program for the whole cohort) and appends the
    personalized rows;
  * the **forecast method** — ONE ``jax.jit`` whose cache holds exactly
    one executable per configured bucket: requests are padded to the
    smallest fitting bucket (windows with zeros, param rows with the
    last real row) before entering the compiled program, and sliced
    back after.  Rows are independent, so padding never changes a real
    row's forecast — bitwise, pinned by ``tests/test_serve.py`` and the
    launcher's ``--selfcheck``.

The compiled batch runs as ``lax.map`` of the EXACT single-request
program by default (``batch_mode="map"``): XLA lowers a ``vmap``-batched
LSTM differently (batched matmuls, ~1e-8 drift vs a B=1 apply), and the
serving contract here is bit-reproducibility — a forecast must not
depend on who else happened to share the batch.  ``batch_mode="vmap"``
trades that guarantee for row-parallel throughput.

The batching POLICY (queueing, admission, timeouts) lives in
``serve.batcher``; :func:`replay` is the deterministic driver that
marries the two for the selfcheck, the latency bench, and the CLI demo.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import Model
from repro.optim import Optimizer, adam
from repro.serve.batcher import MicroBatcher, Request, bucket_for
from repro.utils.pytree import tree_to_vector, vector_to_tree

PyTree = Any

# widths the checkpoint loader tries when recovering the LSTM hidden
# size from a flat parameter count (matches tests/test_checkpoint.py)
KNOWN_HIDDEN = (4, 8, 16, 32, 64, 128, 256)

DEFAULT_BUCKETS = (1, 4, 16, 64)


def load_population(
    path, *, hidden: int | None = None, history_len: int = 12
) -> tuple[Model, PyTree]:
    """Load a federation checkpoint (``launch/train.py`` .npz format:
    flat ``vec`` + shape ``meta``) into ``(model, population_params)``.

    With ``hidden=None`` the LSTM width is recovered from the flat
    parameter count (the checkpoint stores only shapes, not configs) by
    trying :data:`KNOWN_HIDDEN`; a count matching no known width raises
    instead of guessing.
    """
    from repro.models import LSTMModel

    vec = np.load(Path(path), allow_pickle=False)["vec"]
    if hidden is None:
        for h in KNOWN_HIDDEN:
            m = LSTMModel(history_len=history_len, hidden=h).as_model()
            like = m.init(jax.random.PRNGKey(0))
            if int(tree_to_vector(like).shape[0]) == len(vec):
                return m, vector_to_tree(jnp.asarray(vec), like)
        raise ValueError(
            f"{path}: {len(vec)} params match no LSTM width in "
            f"{KNOWN_HIDDEN} — pass hidden= explicitly"
        )
    model = LSTMModel(history_len=history_len, hidden=hidden).as_model()
    like = model.init(jax.random.PRNGKey(0))
    if int(tree_to_vector(like).shape[0]) != len(vec):
        raise ValueError(
            f"{path}: {len(vec)} params but LSTMModel(hidden={hidden}) "
            f"has {int(tree_to_vector(like).shape[0])}"
        )
    return model, vector_to_tree(jnp.asarray(vec), like)


class GlucoseServable:
    """A loaded population model served through padded-bucket batching.

    ``buckets`` are the ONLY batch shapes the jitted forecast method
    ever compiles: a request batch of size n runs at the smallest
    bucket >= n (padded), and batches beyond the largest bucket are
    split.  ``personalize_steps``/``personalize_batch_size`` configure
    the cold-start fine-tune (``core.personalize`` semantics: uniform
    with-replacement draws from the patient's real windows, batch
    clamped to short histories).  ``batch_mode`` picks the batch
    lowering: ``"map"`` (default) is bitwise the single-request apply,
    ``"vmap"`` is the row-parallel throughput variant (~1e-8 drift).
    """

    def __init__(
        self,
        model: Model,
        population_params: PyTree,
        *,
        optimizer: Optimizer | None = None,
        buckets: tuple[int, ...] = DEFAULT_BUCKETS,
        personalize_steps: int = 100,
        personalize_batch_size: int = 32,
        batch_mode: str = "map",
    ):
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"need >= 1 positive bucket size, got {buckets!r}")
        if batch_mode not in ("map", "vmap"):
            raise ValueError(f"batch_mode must be 'map' or 'vmap', got {batch_mode!r}")
        self.batch_mode = batch_mode
        self.model = model
        self.buckets = buckets
        self.optimizer = optimizer or adam(5e-4)
        self.personalize_steps = personalize_steps
        self.personalize_batch_size = personalize_batch_size
        # param store: row 0 is ALWAYS the population model (the
        # brand-new-patient fallback); personalize() appends rows
        self._store: PyTree = jax.tree.map(lambda l: l[None], population_params)
        self._names: dict[Any, int] = {"population": 0}
        # one jit object = one cache; it compiles exactly once per
        # (bucket,) padded shape.  compiled_buckets tracks which padded
        # shapes have entered the cache (introspection for tests/ops).
        self._forecast_jit = jax.jit(self._forecast_impl)
        self.compiled_buckets: set[int] = set()
        self._personalize_fns: dict[int, Callable] = {}

    # --------------------------------------------------------- params
    @property
    def population(self) -> PyTree:
        return jax.tree.map(lambda l: l[0], self._store)

    @property
    def num_rows(self) -> int:
        return int(jax.tree.leaves(self._store)[0].shape[0])

    def row_of(self, name) -> int:
        """Param-store row of a personalized patient (KeyError if the
        patient was never personalized — callers wanting the population
        fallback use ``.get``-style ``row_of_or_population``)."""
        return self._names[name]

    def row_of_or_population(self, name) -> int:
        return self._names.get(name, 0)

    def params_rows(self, rows) -> PyTree:
        """Gather (B,)-indexed param rows from the store — the eager
        pre-processing step; the gathered stack is what enters the
        compiled forecast."""
        rows = jnp.asarray(rows)
        return jax.tree.map(lambda l: l[rows], self._store)

    # ----------------------------------------------------- personalize
    def personalize(self, names, keys, x, y, counts) -> PyTree:
        """Cold-start a cohort: fine-tune the population model on each
        patient's own (padded) history as ONE compiled batched program,
        append the personalized rows to the param store, and return the
        stacked params.

        ``names`` label the cohort for :meth:`row_of`; ``keys (P, 2)``,
        ``x (P, M, L)``, ``y (P, M)``, ``counts (P,)`` follow the
        federation layout.  The per-(M, P) jitted program is cached, so
        cohort after cohort of the same shape compiles once.
        """
        from repro.core.personalize import personalize_batch_fn

        x = jnp.asarray(x)
        m = x.shape[1]
        if m not in self._personalize_fns:
            self._personalize_fns[m] = personalize_batch_fn(
                self.model,
                self.optimizer,
                steps=self.personalize_steps,
                batch_size=self.personalize_batch_size,
                n_rows=m,
            )
        params, _ = self._personalize_fns[m](
            self.population, jnp.asarray(keys), x, jnp.asarray(y),
            jnp.asarray(counts),
        )
        base = self.num_rows
        self._store = jax.tree.map(
            lambda s, p: jnp.concatenate([s, p], axis=0), self._store, params
        )
        for i, name in enumerate(names):
            self._names[name] = base + i
        return params

    # -------------------------------------------------------- forecast
    def _forecast_impl(self, params_batch: PyTree, windows: jnp.ndarray):
        """(B, ...) per-request params x (B, L) windows -> (B,) BG
        forecasts; rows are independent, which is what makes pad rows
        inert.  ``batch_mode="map"`` lowers each row as the EXACT B=1
        apply (bitwise the direct call); ``"vmap"`` lowers one batched
        program (faster, ~1e-8 drift on the LSTM matmuls)."""

        def one(p, w):
            return self.model.apply(p, w[None, :])[0]

        if self.batch_mode == "vmap":
            return jax.vmap(one)(params_batch, windows)
        return jax.lax.map(lambda pw: one(*pw), (params_batch, windows))

    def _pad_forecast(self, params_batch: PyTree, windows: jnp.ndarray, n: int):
        b = bucket_for(n, self.buckets)
        if n < b:
            pad = b - n
            windows = jnp.concatenate(
                [windows, jnp.zeros((pad,) + windows.shape[1:], windows.dtype)]
            )
            params_batch = jax.tree.map(
                lambda l: jnp.concatenate(
                    [l, jnp.broadcast_to(l[-1:], (pad,) + l.shape[1:])]
                ),
                params_batch,
            )
        self.compiled_buckets.add(b)
        return self._forecast_jit(params_batch, windows)[:n]

    def forecast(self, params_batch: PyTree, windows) -> jnp.ndarray:
        """BG forecasts for a batch of (per-request params row, CGM
        window) pairs, padded to the smallest fitting bucket; batches
        larger than the biggest bucket are split into full-bucket
        chunks.  Returns the (B,) normalized forecasts (denormalize
        with the dataset's mean/sd for mg/dL)."""
        windows = jnp.asarray(windows)
        if windows.ndim != 2:
            raise ValueError(f"windows must be (B, L), got {windows.shape}")
        n = windows.shape[0]
        cap = self.buckets[-1]
        if n <= cap:
            return self._pad_forecast(params_batch, windows, n)
        outs = []
        for lo in range(0, n, cap):
            hi = min(lo + cap, n)
            chunk = jax.tree.map(lambda l: l[lo:hi], params_batch)
            outs.append(self._pad_forecast(chunk, windows[lo:hi], hi - lo))
        return jnp.concatenate(outs)

    def forecast_rows(self, rows, windows) -> jnp.ndarray:
        """Convenience: gather store rows, then :meth:`forecast`."""
        return self.forecast(self.params_rows(rows), jnp.asarray(windows))

    def warmup(self, history_len: int = 12) -> None:
        """Pre-compile the forecast executable for EVERY bucket so the
        first real request never pays a trace (saxml-style).  The LSTM
        scans any window length, but the compiled SHAPE is per-L — pass
        the history length real requests will carry."""
        for b in self.buckets:
            rows = jnp.zeros((b,), jnp.int32)
            self._pad_forecast(
                self.params_rows(rows), jnp.zeros((b, history_len), jnp.float32), b
            )


def replay(
    servable: GlucoseServable,
    batcher: MicroBatcher,
    requests: Iterable[Request],
    *,
    drain: bool = True,
) -> dict[int, float]:
    """Deterministic serving loop: submit the request stream in order,
    run every batch the batcher forms (pad-to-bucket inside
    ``servable.forecast``), and return ``{rid: forecast}``.

    Batches execute synchronously as they form, so ``max_live_batches``
    never blocks here — this driver exercises formation, padding, and
    accounting (the admission edge cases are unit-tested with a fake
    clock instead).  With ``drain=True`` the queued tail is flushed
    after the stream ends, timeout or not.
    """
    preds: dict[int, float] = {}

    def run(batch):
        rows = [r.patient for r in batch]
        windows = np.stack([r.window for r in batch])
        out = np.asarray(servable.forecast_rows(rows, windows))
        batcher.complete(batch)
        for r, p in zip(batch, out):
            preds[r.rid] = float(p)

    for req in requests:
        batcher.submit(req)
        while (batch := batcher.ready()) is not None:
            run(batch)
    while drain and (batch := batcher.flush()) is not None:
        run(batch)
    return preds
