"""BG-forecast prediction service (the deployment half of the paper's
cold-start story): take a federation checkpoint, personalize it on new
patients' short CGM histories as one batched program
(``core.personalize.personalize_batch``), and answer CGM-window ->
BG-forecast requests through a padded-bucket micro-batching queue.

Layout (saxml-servable style — sorted batch-size buckets, bounded live
batches, pre/post-processing split from the compiled method):

  * ``servable.py`` — :class:`GlucoseServable`: checkpoint loading, the
    per-bucket-compiled jitted ``forecast`` method, the patient param
    store, and the batched cold-start personalization entry point;
  * ``batcher.py``  — :class:`MicroBatcher`: the request queue
    (pad-to-bucket sizing, max-live-batches admission, timeout flush,
    per-request latency accounting), pure host-side Python with an
    injectable clock so policy is unit-testable with a fake clock.

``launch/serve.py`` is the CLI entry point; ``benchmarks/serve_latency``
prices p50/p99 latency and forecasts/sec per bucket against the
committed ``BENCH_serve.json`` baseline; ``docs/SERVING.md`` is the
operator runbook.
"""
from repro.serve.batcher import MicroBatcher, Request, bucket_for
from repro.serve.servable import GlucoseServable, load_population, replay

__all__ = [
    "GlucoseServable",
    "MicroBatcher",
    "Request",
    "bucket_for",
    "load_population",
    "replay",
]
