"""repro — GluADFL (asynchronous decentralized federated learning) in
JAX, plus the multi-pod framework for the assigned architecture pool.

Public surface:
    repro.core      — GluADFL, FedAvg, topologies, gossip, meta-learning
    repro.models    — LSTM + population-model baselines
    repro.data      — synthetic CGM dataset twins + pipeline
    repro.metrics   — clinical BGLP metrics
    repro.arch      — the 10 assigned architectures (build_arch)
    repro.kernels   — Pallas TPU kernels (gossip_mix, lstm_cell, swa_attention)
    repro.launch    — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
