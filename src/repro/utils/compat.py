"""JAX version-compat shims.

The container pins an older jax; newer code in this repo is written
against the current API.  Everything that moved between versions is
funneled through here so call sites stay clean.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5 exports it at top level
    _shard_map_impl = jax.shard_map
except AttributeError:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_KW = set(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across versions (``check_vma`` was ``check_rep``)."""
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if check_vma is not None:
        flag = "check_vma" if "check_vma" in _SHARD_MAP_KW else "check_rep"
        kw[flag] = check_vma
    return _shard_map_impl(f, **kw)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` across versions: 0.4.x returns a
    one-element list of dicts, newer jax a plain dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
