"""Small shared utilities: pytree algebra (stack/index/mean/mix — the
federation's stacked-leaf operations), RNG helpers, and version-compat
shims (``utils.compat.shard_map``)."""
from repro.utils.pytree import (
    tree_vector_size,
    tree_to_vector,
    vector_to_tree,
    tree_stack,
    tree_unstack,
    tree_index,
    tree_scale,
    tree_add,
    tree_sub,
    tree_zeros_like,
    tree_l2_norm,
    tree_mean,
    tree_weighted_mix,
    tree_map_with_path_names,
)
from repro.utils.rng import key_iter, split_like
