"""Pytree utilities used throughout the framework.

The federated core treats model parameters as either
  * a pytree of arrays (one node), or
  * a *stacked* pytree whose leaves carry a leading node axis ``(N, ...)``.

Everything here is pure JAX and jit/vmap friendly.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def tree_vector_size(tree: PyTree) -> int:
    """Total number of scalar parameters in the tree."""
    return int(sum(math.prod(l.shape) for l in jax.tree.leaves(tree)))


def tree_to_vector(tree: PyTree) -> jnp.ndarray:
    """Flatten a pytree of arrays into a single 1-D vector (row-major)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(l) for l in leaves]) if leaves else jnp.zeros((0,))


def vector_to_tree(vec: jnp.ndarray, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_to_vector` given a template tree."""
    leaves, treedef = jax.tree.flatten(like)
    out, pos = [], 0
    for l in leaves:
        n = math.prod(l.shape)
        out.append(jnp.reshape(vec[pos : pos + n], l.shape).astype(l.dtype))
        pos += n
    return jax.tree.unflatten(treedef, out)


def tree_stack(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *trees)


def tree_unstack(tree: PyTree) -> list[PyTree]:
    """Inverse of :func:`tree_stack`."""
    n = jax.tree.leaves(tree)[0].shape[0]
    return [jax.tree.map(lambda l, i=i: l[i], tree) for i in range(n)]


def tree_index(tree: PyTree, i) -> PyTree:
    """Index the leading (node) axis of a stacked pytree."""
    return jax.tree.map(lambda l: l[i], tree)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda l: l * s, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_l2_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(tree)))


def tree_mean(tree: PyTree, axis: int = 0) -> PyTree:
    """Mean over the leading (node) axis of a stacked pytree."""
    return jax.tree.map(lambda l: jnp.mean(l, axis=axis), tree)


def tree_weighted_mix(stacked: PyTree, mix: jnp.ndarray) -> PyTree:
    """Apply a row-stochastic mixing matrix to a stacked pytree.

    ``stacked`` leaves have shape ``(N, ...)``; ``mix`` is ``(N, N)`` with
    row n holding node n's averaging weights.  Returns the mixed stacked
    tree: ``out[n] = sum_m mix[n, m] * stacked[m]``.

    This is the reference (pure-jnp) implementation of the paper's gossip
    step; the Pallas kernel in ``repro.kernels.gossip_mix`` computes the
    same contraction blocked for VMEM.
    """

    def mix_leaf(l: jnp.ndarray) -> jnp.ndarray:
        flat = l.reshape(l.shape[0], -1)
        mixed = jnp.einsum(
            "nm,md->nd", mix.astype(jnp.float32), flat.astype(jnp.float32)
        )
        return mixed.astype(l.dtype).reshape(l.shape)

    return jax.tree.map(mix_leaf, stacked)


def tree_map_with_path_names(fn: Callable[[str, jnp.ndarray], Any], tree: PyTree) -> PyTree:
    """tree.map with a '/'-joined string path as first argument."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
