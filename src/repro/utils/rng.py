"""Small RNG helpers."""
from __future__ import annotations

import jax


def key_iter(seed: int):
    """Infinite iterator of fresh PRNG keys."""
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield sub


def split_like(key, tree):
    """Split a key into one key per leaf of ``tree`` (same structure)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))
