"""Basic transformer layers (pure functions, params-as-pytrees).

Conventions:
  * master params float32, compute dtype per call (usually bf16),
  * activations (B, S, D), attention heads laid out (B, S, H, head_dim),
  * all vocab-sized dims are padded to a multiple of 128 so they shard
    evenly on any mesh axis (``pad_vocab``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_vocab(v: int, multiple: int = 128) -> int:
    return -(-v // multiple) * multiple


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def embed(tokens: jnp.ndarray, table: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [
            x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
            x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


def init_dense(key, n_in: int, n_out: int, *, bias: bool = False, scale: float | None = None):
    s = scale if scale is not None else (n_in**-0.5)
    p = {"w": jax.random.normal(key, (n_in, n_out), jnp.float32) * s}
    if bias:
        p["b"] = jnp.zeros((n_out,), jnp.float32)
    return p


def swiglu_ffn(x, p):
    """Gated MLP: (gate, up, down) — llama/mistral style."""
    g = dense(x, p["w_gate"])
    u = dense(x, p["w_up"])
    return dense(jax.nn.silu(g) * u, p["w_down"])


def gelu_ffn(x, p):
    """Plain 2-matrix MLP (whisper style)."""
    h = jax.nn.gelu(dense(x, p["w_in"], p.get("b_in")), approximate=True)
    return dense(h, p["w_out"], p.get("b_out"))


def init_swiglu(key, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, ff), jnp.float32) * d**-0.5,
        "w_up": jax.random.normal(k2, (d, ff), jnp.float32) * d**-0.5,
        "w_down": jax.random.normal(k3, (ff, d), jnp.float32) * ff**-0.5,
    }


def init_gelu_ffn(key, d: int, ff: int, *, bias: bool = True):
    k1, k2 = jax.random.split(key)
    p = {
        "w_in": jax.random.normal(k1, (d, ff), jnp.float32) * d**-0.5,
        "w_out": jax.random.normal(k2, (ff, d), jnp.float32) * ff**-0.5,
    }
    if bias:
        p["b_in"] = jnp.zeros((ff,), jnp.float32)
        p["b_out"] = jnp.zeros((d,), jnp.float32)
    return p
