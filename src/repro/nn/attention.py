"""GQA attention: plain, blocked-flash (long-context), and decode paths.

  * ``gqa_attention`` — self-attention over a full sequence (train /
    prefill).  For short sequences a plain masked softmax; above
    ``flash_threshold`` a pure-JAX blocked flash attention (lax.scan over
    KV blocks with an online softmax) so 32k+ prefill never materializes
    (S, S) scores.  Supports causal masking and sliding windows; with a
    window, KV blocks entirely outside every query's window are skipped
    structurally (banded iteration), which is what makes long-context
    sliding-window prefill sub-quadratic.
  * ``decode_attention`` — one-token query against a KV cache.
  * ``KVCache`` — append-only cache for full attention, ring buffer for
    sliding windows (so a 500k-context SWA decode stores only the window).

The Pallas kernel in ``repro.kernels.swa_attention`` implements the same
blocked computation with explicit VMEM BlockSpecs; this module is the
lowering-friendly XLA reference.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """(B, S, K, hd) -> (B, S, K*q_per_kv, hd) by repetition."""
    if q_per_kv == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, q_per_kv, hd)).reshape(
        b, s, kh * q_per_kv, hd
    )


def plain_attention(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0):
    """Reference masked attention.  q: (B,Sq,H,hd), k/v: (B,Skv,K,hd)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (hd**-0.5)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention(q, k, v, *, causal: bool, window: int = 0, block: int = 1024):
    """Blocked flash attention (online softmax), scan over KV blocks.

    Never materializes (Sq, Skv); peak extra memory is (B, H, Sq, block).
    With ``window > 0`` the scan body still visits every block index but
    fully-masked blocks contribute zero; the *banded* variant (used for
    very long SWA prefill) instead restricts the scan to the diagonal
    band — see ``banded_flash_attention``.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    skv = k.shape[1]
    assert skv % block == 0 or skv < block, (skv, block)
    block = min(block, skv)
    nblocks = -(-skv // block)
    qpk = h // kh

    from repro.arch.sharding import constrain_attn

    qf = q.astype(jnp.float32) * (hd**-0.5)
    # (B, H, Sq, hd) layout for the scan
    qf = constrain_attn(qf.transpose(0, 2, 1, 3), "bhsd")

    def body(carry, blk_idx):
        acc, m_prev, l_prev = carry
        start = blk_idx * block
        kb = jax.lax.dynamic_slice_in_dim(k, start, block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, block, axis=1)
        kb = _repeat_kv(kb, qpk).transpose(0, 2, 1, 3).astype(jnp.float32)
        vb = _repeat_kv(vb, qpk).transpose(0, 2, 1, 3).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)  # (B,H,Sq,block)
        qpos = jnp.arange(sq)[:, None]
        kpos = start + jnp.arange(block)[None, :]
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale + p.sum(axis=-1)
        acc = acc * scale[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        acc = constrain_attn(acc, "bhsd")
        return (acc, constrain_attn(m_new, "bhs"), constrain_attn(l_new, "bhs")), None

    from repro.nn.unroll import unroll_enabled

    acc0 = constrain_attn(jnp.zeros((b, h, sq, hd), jnp.float32), "bhsd")
    m0 = constrain_attn(jnp.full((b, h, sq), NEG_INF, jnp.float32), "bhs")
    l0 = constrain_attn(jnp.zeros((b, h, sq), jnp.float32), "bhs")
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(nblocks),
        unroll=nblocks if unroll_enabled() else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def banded_flash_attention(q, k, v, *, window: int, block: int = 1024):
    """Sliding-window causal attention visiting ONLY the diagonal band.

    Queries are processed in blocks of ``block``; each query block
    attends to ceil(window/block)+1 KV blocks.  Cost O(S * window), the
    sub-quadratic path for long_500k-class prefill.
    """
    b, sq, h, hd = q.shape
    assert sq % block == 0, (sq, block)
    nq = sq // block
    kv_blocks = -(-window // block) + 1

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * block, block, axis=1)
        lo_block = jnp.maximum(qi - kv_blocks + 1, 0)
        start = lo_block * block
        span = kv_blocks * block
        kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        out = plain_attention(
            qb, kb, vb, causal=True, window=window, q_offset=(qi - lo_block) * block
        )
        return out

    from repro.nn.unroll import unroll_enabled

    if unroll_enabled():
        outs = jnp.stack([q_block(jnp.asarray(i)) for i in range(nq)])
    else:
        outs = jax.lax.map(q_block, jnp.arange(nq))  # (nq, B, block, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def gqa_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    flash_threshold: int = 2048,
    block: int = 1024,
):
    """Dispatch to the right self-attention path (see module docstring)."""
    skv, sq = k.shape[1], q.shape[1]
    if skv <= flash_threshold:
        return plain_attention(q, k, v, causal=causal, window=window)
    band_span = (-(-window // block) + 1) * block if window > 0 else 0
    if window > 0 and sq == skv and sq % block == 0 and block <= window and band_span < sq:
        return banded_flash_attention(q, k, v, window=window, block=block)
    return flash_attention(q, k, v, causal=causal, window=window, block=block)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class KVCache:
    """KV cache for decode.  Full attention: append-only of length
    max_len.  Sliding window: ring buffer of length window."""

    k: jnp.ndarray          # (B, C, K, hd)
    v: jnp.ndarray          # (B, C, K, hd)
    pos: jnp.ndarray        # scalar int32 — tokens decoded so far

    @staticmethod
    def init(batch: int, capacity: int, kv_heads: int, head_dim: int, dtype) -> "KVCache":
        shape = (batch, capacity, kv_heads, head_dim)
        return KVCache(
            k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
            pos=jnp.zeros((), jnp.int32),
        )

    def append(self, k_new, v_new) -> "KVCache":
        """Append one token's K/V (B, 1, K, hd); ring semantics when full."""
        cap = self.k.shape[1]
        slot = self.pos % cap
        k = jax.lax.dynamic_update_slice_in_dim(self.k, k_new.astype(self.k.dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(self.v, v_new.astype(self.v.dtype), slot, axis=1)
        return KVCache(k=k, v=v, pos=self.pos + 1)


def decode_attention(q, cache: KVCache, *, window: int = 0):
    """One-step attention: q (B, 1, H, hd) against the cache (post-append).

    Masks out unwritten slots; for ring caches every written slot is in
    the window by construction.
    """
    b, one, h, hd = q.shape
    cap = cache.k.shape[1]
    kh = cache.k.shape[2]
    k = _repeat_kv(cache.k, h // kh)
    v = _repeat_kv(cache.v, h // kh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (hd**-0.5)
    slots = jnp.arange(cap)
    valid = slots < cache.pos  # pos already includes the appended token
    if window > 0:
        # ring buffer: every retained slot is within the window — only
        # unwritten slots are invalid (cap == window)
        pass
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
