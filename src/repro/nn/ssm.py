"""Mamba-2 SSD (state-space duality) layer — chunked matmul form.

The chunked algorithm (Dao & Gu, 2024) turns the linear recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ,   y_t = C_t h_t + D x_t

into MXU-friendly blocks: within-chunk attention-like matmuls (masked by
cumulative decays) + an inter-chunk state recurrence (lax.scan over
chunks).  This is the TPU-native adaptation: the original CUDA kernel's
warp-level scan becomes chunk matmuls sized to the MXU, with the O(S)
scan only over S/chunk steps.

Shapes: x (B,S,H,P) heads x headdim, dt (B,S,H), A (H,) (negative),
Bm/Cm (B,S,G,N) with G groups broadcast over heads, D (H,).
Decode keeps h (B,H,P,N) and costs O(1) per token — this is why the SSM
arch runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Causal segment sums: out[..., i, j] = sum_{k=j+1..i} a[..., k],
    -inf above the diagonal.  a: (..., Q) -> (..., Q, Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(x, dt, a_log, bm, cm, d_skip, *, chunk: int = 64):
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    rep = h // g

    dt = jax.nn.softplus(dt.astype(jnp.float32))           # (B,S,H) > 0
    a = dt * a_log.astype(jnp.float32)[None, None, :]      # log decay, < 0
    xdt = x.astype(jnp.float32) * dt[..., None]            # pre-scale x by dt

    # chunked views
    xc = xdt.reshape(b, nc, q, h, p)
    ac = a.reshape(b, nc, q, h).transpose(0, 3, 1, 2)      # (B,H,NC,Q)
    bc = bm.reshape(b, nc, q, g, n).astype(jnp.float32)
    cc = cm.reshape(b, nc, q, g, n).astype(jnp.float32)
    bch = jnp.repeat(bc, rep, axis=3)                      # broadcast groups->heads
    cch = jnp.repeat(cc, rep, axis=3)

    # 1. within-chunk (attention-like) term
    L = jnp.exp(_segsum(ac))                               # (B,H,NC,Q,Q)
    y_diag = jnp.einsum("bcqhn,bckhn,bhcqk,bckhp->bcqhp", cch, bch, L, xc)

    # 2. per-chunk input states
    a_cum = jnp.cumsum(ac, axis=-1)                        # (B,H,NC,Q)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)        # (B,H,NC,Q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", bch, decay_states, xc)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                  # (B,H,NC)

    def step(hprev, inp):
        dec, st = inp  # dec (B,H), st (B,H,P,N)
        hnew = dec[..., None, None] * hprev + st
        return hnew, hprev  # emit the state BEFORE this chunk

    from repro.nn.unroll import unroll_enabled

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, prev_states = jax.lax.scan(
        step,
        h0,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)),
        unroll=nc if unroll_enabled() else 1,
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,NC,H,P,N)

    # 4. state -> output term
    state_decay = jnp.exp(a_cum)                           # (B,H,NC,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", cch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hlast


def ssd_decode_step(x_t, dt_t, a_log, b_t, c_t, d_skip, h_state):
    """One decode step.  x_t (B,H,P), dt_t (B,H), b_t/c_t (B,G,N),
    h_state (B,H,P,N) -> (y_t (B,H,P), new_state)."""
    bsz, h, p = x_t.shape
    g = b_t.shape[1]
    rep = h // g
    dt = jax.nn.softplus(dt_t.astype(jnp.float32))
    decay = jnp.exp(dt * a_log.astype(jnp.float32)[None, :])   # (B,H)
    bh = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)      # (B,H,N)
    ch = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    xdt = x_t.astype(jnp.float32) * dt[..., None]
    h_new = decay[..., None, None] * h_state + jnp.einsum("bhp,bhn->bhpn", xdt, bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch)
    y = y + x_t.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# Full Mamba-2 block (projections + short causal conv + SSD + gate)
# ---------------------------------------------------------------------------

CONV_K = 4


def init_mamba2_block(key, d: int, *, expand: int, nheads: int, dstate: int, ngroups: int = 1):
    d_inner = expand * d
    p_dim = d_inner // nheads
    keys = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * ngroups * dstate
    return {
        "in_proj": jax.random.normal(
            keys[0], (d, 2 * d_inner + 2 * ngroups * dstate + nheads), jnp.float32
        ) * d**-0.5,
        "conv_w": jax.random.normal(keys[1], (CONV_K, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": -jnp.exp(jax.random.uniform(keys[2], (nheads,), minval=-1.0, maxval=1.0)),
        "dt_bias": jax.random.normal(keys[3], (nheads,)) * 0.1,
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": jax.random.normal(keys[4], (d_inner, d), jnp.float32) * d_inner**-0.5,
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, kernel CONV_K.  u: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + u.shape[1], :] * w[i][None, None, :].astype(u.dtype)
        for i in range(k)
    )
    return jax.nn.silu(out + b.astype(u.dtype))


def mamba2_block(x, p, *, expand: int, nheads: int, dstate: int, ngroups: int = 1, chunk: int = 64):
    """Full block forward (train/prefill).  x: (B,S,d) -> (B,S,d)."""
    from repro.nn.layers import rms_norm

    b, s, d = x.shape
    d_inner = expand * d
    p_dim = d_inner // nheads
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * ngroups * dstate], axis=-1
    )
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + ngroups * dstate], axis=-1)
    xs = xs.reshape(b, s, nheads, p_dim)
    bm = bm.reshape(b, s, ngroups, dstate)
    cm = cm.reshape(b, s, ngroups, dstate)
    dt = dt + p["dt_bias"].astype(dt.dtype)[None, None, :]
    y, _ = ssd_forward(xs, dt, p["a_log"], bm, cm, p["d_skip"], chunk=chunk)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_decode(x_t, p, state, *, expand: int, nheads: int, dstate: int, ngroups: int = 1):
    """One-token decode.  x_t: (B,d); state = {"conv": (B,K-1,C), "ssm": (B,H,P,N)}."""
    from repro.nn.layers import rms_norm

    b, d = x_t.shape
    d_inner = expand * d
    p_dim = d_inner // nheads
    zxbcdt = x_t @ p["in_proj"].astype(x_t.dtype)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * ngroups * dstate], axis=-1
    )
    # rolling conv state
    conv_in = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x_t.dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_in, w) + p["conv_b"].astype(x_t.dtype)
    )
    new_conv = conv_in[:, 1:, :]
    xs, bm, cm = jnp.split(conv_out, [d_inner, d_inner + ngroups * dstate], axis=-1)
    xs = xs.reshape(b, nheads, p_dim)
    bm = bm.reshape(b, ngroups, dstate)
    cm = cm.reshape(b, ngroups, dstate)
    dt = dt + p["dt_bias"].astype(dt.dtype)[None, :]
    y, new_ssm = ssd_decode_step(xs, dt, p["a_log"], bm, cm, p["d_skip"], state["ssm"])
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ p["out_proj"].astype(x_t.dtype)
    return out, {"conv": new_conv, "ssm": new_ssm}


def init_mamba2_state(batch: int, d: int, *, expand: int, nheads: int, dstate: int, ngroups: int = 1, dtype=jnp.float32):
    d_inner = expand * d
    conv_dim = d_inner + 2 * ngroups * dstate
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nheads, d_inner // nheads, dstate), jnp.float32),
    }
