"""Neural-net building blocks for the assigned architecture pool:
norms/dense/embeddings/RoPE, GQA attention with KV cache, MoE FFN,
Mamba-2 SSD, RG-LRU, and sequence unrolling helpers."""
from repro.nn.layers import rms_norm, layer_norm, dense, embed, rope, pad_vocab
from repro.nn.attention import gqa_attention, decode_attention, KVCache
from repro.nn.moe import moe_ffn
from repro.nn.ssm import ssd_forward, ssd_decode_step
from repro.nn.rglru import rglru_forward, rglru_decode_step
