"""Unroll context for cost extraction.

XLA's HloCostAnalysis visits while-loop bodies ONCE, so FLOPs/bytes of
scanned inner loops (flash-attention KV blocks, SSD chunk recurrence)
are undercounted in compiled cost analysis.  The roofline harness lowers
single layers inside ``unroll_scans()`` so every inner iteration is
present in the HLO and the per-layer numbers are exact; production
lowering keeps rolled loops (compact HLO).
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar

_UNROLL: ContextVar = ContextVar("unroll_scans", default=False)


@contextmanager
def unroll_scans():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def unroll_enabled() -> bool:
    return bool(_UNROLL.get())
