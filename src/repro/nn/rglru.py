"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = a ^ (c * r_t),  a = sigmoid(Lambda)   (per-channel decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the sequential recurrence is a first-order linear scan
h_t = a_t h_{t-1} + b_t, computed with ``jax.lax.associative_scan``
(log-depth, vectorized over (B, W)) rather than a CUDA per-thread loop.
Decode is the O(1) single-step update, so the hybrid arch runs long_500k.

The full Griffin recurrent block wraps the RG-LRU with input/gate
branches and a short depthwise causal conv, mirroring the paper's block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

C_EXP = 8.0


def rglru_forward(x, p, *, h0=None):
    """x: (B, S, W) -> (y (B,S,W), h_last (B,W)).  Associative scan over S."""
    b, s, w = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"] + p["b_x"])
    log_a = -C_EXP * r * jax.nn.softplus(p["lam"])[None, None, :]  # log a_t < 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    if h0 is not None:
        gated = gated.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_decode_step(x_t, p, h_prev):
    """x_t: (B, W); h_prev: (B, W) -> (y_t, h_new)."""
    xf = x_t.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"] + p["b_x"])
    log_a = -C_EXP * r * jax.nn.softplus(p["lam"])[None, :]
    a = jnp.exp(log_a)
    h_new = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return h_new.astype(x_t.dtype), h_new


def init_rglru(key, width: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = width**-0.5
    return {
        "w_a": jax.random.normal(k1, (width, width), jnp.float32) * s,
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_x": jax.random.normal(k2, (width, width), jnp.float32) * s,
        "b_x": jnp.zeros((width,), jnp.float32),
        # init decay a in ~(0.9, 0.999): lam via softplus^-1
        "lam": jax.random.uniform(k3, (width,), minval=0.3, maxval=0.8),
    }


# ---------------------------------------------------------------------------
# Griffin recurrent block: conv + RG-LRU + gated merge
# ---------------------------------------------------------------------------

CONV_K = 4


def init_recurrent_block(key, d: int, width: int):
    ks = jax.random.split(key, 5)
    return {
        "w_in_x": jax.random.normal(ks[0], (d, width), jnp.float32) * d**-0.5,
        "w_in_gate": jax.random.normal(ks[1], (d, width), jnp.float32) * d**-0.5,
        "conv_w": jax.random.normal(ks[2], (CONV_K, width), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((width,), jnp.float32),
        "rglru": init_rglru(ks[3], width),
        "w_out": jax.random.normal(ks[4], (width, d), jnp.float32) * width**-0.5,
    }


def recurrent_block(x, p):
    """Griffin recurrent block forward.  x: (B,S,d) -> (B,S,d)."""
    from repro.nn.ssm import _causal_conv

    xb = x @ p["w_in_x"].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["w_in_gate"].astype(x.dtype), approximate=True)
    xb = _causal_conv(xb, p["conv_w"], p["conv_b"])
    y, _ = rglru_forward(xb, p["rglru"])
    return (y * gate) @ p["w_out"].astype(x.dtype)


def recurrent_block_decode(x_t, p, state):
    """One-step decode.  state = {"conv": (B,K-1,W), "h": (B,W)}."""
    xb = x_t @ p["w_in_x"].astype(x_t.dtype)
    gate = jax.nn.gelu(x_t @ p["w_in_gate"].astype(x_t.dtype), approximate=True)
    conv_in = jnp.concatenate([state["conv"], xb[:, None, :]], axis=1)
    w = p["conv_w"].astype(x_t.dtype)
    xb = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w) + p["conv_b"].astype(x_t.dtype))
    y, h_new = rglru_decode_step(xb, p["rglru"], state["h"])
    out = (y * gate) @ p["w_out"].astype(x_t.dtype)
    return out, {"conv": conv_in[:, 1:, :], "h": h_new}


def init_recurrent_state(batch: int, width: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, width), dtype),
        "h": jnp.zeros((batch, width), jnp.float32),
    }
