"""Mixture-of-Experts FFN with capacity-based expert-side dispatch.

Per batch row: router softmax over E experts, token top-k selection, then
each expert gathers its top-C tokens by router priority (C = S*k/E *
capacity_factor), computes the gated-MLP, and results are scatter-added
back with combine weights.  Over-capacity tokens are dropped (GShard
semantics).  FLOPs scale with activated experts (k/E), not E — the honest
MoE roofline.

Expert weights are stacked (E, ...) so the expert dim can be sharded on
the mesh "model" axis when divisible (granite: 32 experts / 16-way), and
the hidden dim sharded otherwise (mixtral: 8 experts -> shard d_ff).
Aux losses: load-balance (Switch) + router z-loss, returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe(key, d: int, ff: int, num_experts: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e = num_experts
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * d**-0.5,
        "w_gate": jax.random.normal(k2, (e, d, ff), jnp.float32) * d**-0.5,
        "w_up": jax.random.normal(k3, (e, d, ff), jnp.float32) * d**-0.5,
        "w_down": jax.random.normal(k4, (e, ff, d), jnp.float32) * ff**-0.5,
    }


def moe_ffn(
    x: jnp.ndarray,
    p,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (out (B, S, d), aux losses dict)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # token-side top-k: keep only the k largest expert probs per token
    top_vals, _ = jax.lax.top_k(probs, top_k)
    kth = top_vals[..., -1:]
    routed = jnp.where(probs >= kth, probs, 0.0)  # (B,S,E) sparse combine weights
    routed = routed / jnp.maximum(routed.sum(-1, keepdims=True), 1e-9)

    # expert-side capacity: each expert takes its top-C tokens per row
    cap = max(1, int(s * top_k / e * capacity_factor))
    cap = min(cap, s)
    prio = jnp.swapaxes(routed, 1, 2)  # (B, E, S)
    gate_vals, token_idx = jax.lax.top_k(prio, cap)  # (B, E, C)

    # gather expert inputs: (B, E, C, d)
    xin = jnp.take_along_axis(
        x[:, None, :, :], token_idx[..., None].astype(jnp.int32), axis=2
    )

    # expert gated MLP (batched over E): einsum keeps the expert dim explicit
    xg = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(x.dtype))
    xu = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(xg) * xu
    xo = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))

    # combine: weight by gate value, scatter-add back to token positions
    xo = xo * gate_vals[..., None].astype(x.dtype)
    out = jnp.zeros_like(x)
    bidx = jnp.arange(b)[:, None, None]
    out = out.at[bidx, token_idx].add(xo, mode="drop")

    # aux losses
    me = probs.mean(axis=(0, 1))                      # mean router prob per expert
    dispatch = (routed > 0).astype(jnp.float32)
    ce = dispatch.mean(axis=(0, 1)) * e / top_k       # fraction routed per expert
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out, {"load_balance": load_balance, "router_z": z_loss}
