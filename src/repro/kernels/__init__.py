"""Pallas TPU kernels for the perf-critical compute layers:
  gossip_mix    — the paper's gossip parameter-mixing contraction
  lstm_cell     — fused LSTM cell (the per-node model's hot loop)
  swa_attention — banded sliding-window flash attention (long-context
                  shapes of the assigned Mistral-family/hybrid archs)
Each kernel: <name>.py (pl.pallas_call + BlockSpec), ref.py oracle,
ops.py jit'd wrapper (padding + CPU-interpret/TPU dispatch)."""
from repro.kernels.ops import gossip_mix, gossip_mix_dp, lstm_cell, swa_attention
