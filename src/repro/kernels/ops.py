"""Jitted public wrappers for the Pallas kernels: padding to tile
multiples, dtype plumbing, and CPU (interpret) / TPU (compiled) dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gossip_mix import (
    TILE_D,
    gossip_mix_dp_pallas,
    gossip_mix_pallas,
    gossip_mix_sparse_dp_pallas,
    gossip_mix_sparse_pallas,
)
from repro.kernels.lstm_cell import TILE_B, TILE_H, lstm_cell_pallas
from repro.kernels.swa_attention import TILE_Q, swa_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gossip_mix(mix: jnp.ndarray, w: jnp.ndarray, active=None) -> jnp.ndarray:
    """Row-stochastic gossip mix ``out = mix @ w`` with active-mask fuse.

    mix (N, N), w (N, D) any float dtype, active optional (N,).
    Pads N to the 8-sublane multiple and D to TILE_D; interpret mode on
    CPU (bit-correctness tests), compiled on TPU.
    """
    n, d = w.shape
    if active is None:
        active = jnp.ones((n,), jnp.float32)
    n_pad = (-n) % 8
    wp = _pad_to(w, 0, 8)
    mp = _pad_to(_pad_to(mix, 0, 8), 1, 8)
    ap = _pad_to(active.astype(jnp.float32), 0, 8)
    wp = _pad_to(wp, 1, TILE_D)
    out = gossip_mix_pallas(mp, wp, ap, interpret=not _on_tpu())
    return out[:n, :d]


def gossip_mix_dp(mix: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray, active=None) -> jnp.ndarray:
    """Fused local-DP gossip ``out = mix @ (w + noise) - diag(mix) * noise``
    with the active-mask select (inactive rows bit-exact copies of ``w``).

    mix (N, N), w/noise (N, D), active optional (N,).  Same padding and
    interpret/compiled dispatch as :func:`gossip_mix`.
    """
    n, d = w.shape
    if active is None:
        active = jnp.ones((n,), jnp.float32)
    wp = _pad_to(_pad_to(w, 0, 8), 1, TILE_D)
    zp = _pad_to(_pad_to(noise, 0, 8), 1, TILE_D)
    mp = _pad_to(_pad_to(mix, 0, 8), 1, 8)
    ap = _pad_to(active.astype(jnp.float32), 0, 8)
    out = gossip_mix_dp_pallas(mp, wp, zp, ap, interpret=not _on_tpu())
    return out[:n, :d]


def gossip_mix_sparse(
    idx: jnp.ndarray, wgt: jnp.ndarray, w: jnp.ndarray, active=None
) -> jnp.ndarray:
    """Sparse gather-mix ``out[n] = Σ_b wgt[n,b] · w[idx[n,b]]`` from a
    ``core.topology.neighbor_table`` — O(N·B·D) instead of the dense
    kernel's O(N²·D).

    idx/wgt (N, B+1), w (N, D), active optional (N,).  Pads N to the
    8-sublane multiple (padded table rows gather row 0 with weight 0 and
    an inactive mask, so they copy their zero padding through) and D to
    TILE_D; interpret on CPU, compiled on TPU.
    """
    n, d = w.shape
    if active is None:
        active = jnp.ones((n,), jnp.float32)
    wp = _pad_to(_pad_to(w, 0, 8), 1, TILE_D)
    ip = _pad_to(idx.astype(jnp.int32), 0, 8)  # pad rows: idx 0 (in bounds)
    gp = _pad_to(wgt.astype(jnp.float32), 0, 8)  # pad rows: weight 0
    ap = _pad_to(active.astype(jnp.float32), 0, 8)  # pad rows: inactive
    out = gossip_mix_sparse_pallas(ip, gp, wp, ap, interpret=not _on_tpu())
    return out[:n, :d]


def gossip_mix_sparse_dp(
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    w: jnp.ndarray,
    noise: jnp.ndarray,
    active=None,
) -> jnp.ndarray:
    """Fused sparse local-DP gossip
    ``out[n] = Σ_b wgt[n,b]·(w+z)[idx[n,b]] − wgt[n,0]·z[n]`` with the
    active-mask select (inactive rows bit-exact copies of ``w``).  Same
    shapes/padding/dispatch as :func:`gossip_mix_sparse` plus noise (N, D).
    """
    n, d = w.shape
    if active is None:
        active = jnp.ones((n,), jnp.float32)
    wp = _pad_to(_pad_to(w, 0, 8), 1, TILE_D)
    zp = _pad_to(_pad_to(noise, 0, 8), 1, TILE_D)
    ip = _pad_to(idx.astype(jnp.int32), 0, 8)
    gp = _pad_to(wgt.astype(jnp.float32), 0, 8)
    ap = _pad_to(active.astype(jnp.float32), 0, 8)
    out = gossip_mix_sparse_dp_pallas(ip, gp, wp, zp, ap, interpret=not _on_tpu())
    return out[:n, :d]


def lstm_cell(x_t, h, c, wx, wh, b):
    """Fused LSTM cell step (see kernels/lstm_cell.py)."""
    bsz, hsz = h.shape
    xb = _pad_to(x_t, 0, TILE_B)
    hb = _pad_to(h, 0, TILE_B)
    cb = _pad_to(c, 0, TILE_B)
    if hsz % TILE_H:
        # hidden padding changes gate block layout; fall back to reference
        from repro.kernels.ref import lstm_cell_ref

        return lstm_cell_ref(x_t, h, c, wx, wh, b)
    h_new, c_new = lstm_cell_pallas(xb, hb, cb, wx, wh, b, interpret=not _on_tpu())
    return h_new[:bsz], c_new[:bsz]


def swa_attention(q, k, v, *, window: int) -> jnp.ndarray:
    """Banded sliding-window flash attention.  q/k/v (B, S, H, hd) with
    kv heads pre-repeated; S must divide by TILE_Q (128)."""
    assert q.shape[1] % TILE_Q == 0, q.shape
    return swa_attention_pallas(q, k, v, window=window, interpret=not _on_tpu())
