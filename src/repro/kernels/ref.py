"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix_ref(mix: jnp.ndarray, w: jnp.ndarray, active=None) -> jnp.ndarray:
    """Row-stochastic gossip mix: out = mix @ w, inactive rows copied.

    mix: (N, N) f32; w: (N, D); active: optional (N,) {0,1} — when given,
    inactive rows bypass the contraction entirely (pure copy).
    """
    out = jnp.einsum("nm,md->nd", mix.astype(jnp.float32), w.astype(jnp.float32))
    if active is not None:
        a = active.astype(jnp.float32)[:, None]
        out = a * out + (1 - a) * w.astype(jnp.float32)
    return out.astype(w.dtype)


def gossip_mix_dp_ref(mix: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray, active=None) -> jnp.ndarray:
    """Fused local-DP gossip oracle: every node broadcasts a noised view
    but re-adds its own clean self-contribution —
    ``out = mix @ (w + noise) - diag(mix) * noise``."""
    shared = w.astype(jnp.float32) + noise.astype(jnp.float32)
    mixed = jnp.einsum("nm,md->nd", mix.astype(jnp.float32), shared)
    out = mixed - jnp.diagonal(mix).astype(jnp.float32)[:, None] * noise.astype(jnp.float32)
    if active is not None:
        a = active.astype(jnp.float32)[:, None]
        out = a * out + (1 - a) * w.astype(jnp.float32)
    return out.astype(w.dtype)


def _densify(idx: jnp.ndarray, wgt: jnp.ndarray) -> jnp.ndarray:
    """Neighbor table (N, B+1) -> dense (N, N) mixing matrix (padding
    slots scatter-add 0.0, a no-op)."""
    n = idx.shape[0]
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], idx.shape)
    return jnp.zeros((n, n), jnp.float32).at[rows, idx].add(
        wgt.astype(jnp.float32)
    )


def gossip_mix_sparse_ref(
    idx: jnp.ndarray, wgt: jnp.ndarray, w: jnp.ndarray, active=None
) -> jnp.ndarray:
    """Dense oracle for the sparse gather-mix kernel: densify the
    neighbor table and run :func:`gossip_mix_ref` — the sparse kernel is
    correct iff it matches this on every table the builders emit.

    idx/wgt: (N, B+1) neighbor table (slot 0 self, padding idx=self
    wgt=0); w: (N, D); active: optional (N,) {0,1}.
    """
    return gossip_mix_ref(_densify(idx, wgt), w, active)


def gossip_mix_sparse_dp_ref(
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    w: jnp.ndarray,
    noise: jnp.ndarray,
    active=None,
) -> jnp.ndarray:
    """Dense oracle for the fused sparse DP gather-mix:
    ``out[n] = Σ_b wgt[n,b]·(w[idx[n,b]] + z[idx[n,b]]) − wgt[n,0]·z[n]``
    via densify + :func:`gossip_mix_dp_ref` (the densified diagonal IS
    the slot-0 self weight)."""
    return gossip_mix_dp_ref(_densify(idx, wgt), w, noise, active)


def lstm_cell_ref(x_t, h, c, wx, wh, b):
    """Fused LSTM cell (gates i, f, g, o).  Shapes:
    x_t (B, I), h/c (B, H), wx (I, 4H), wh (H, 4H), b (4H,)."""
    z = (
        x_t.astype(jnp.float32) @ wx.astype(jnp.float32)
        + h.astype(jnp.float32) @ wh.astype(jnp.float32)
        + b.astype(jnp.float32)
    )
    i, f, g, o = jnp.split(z, 4, axis=-1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)


def swa_attention_ref(q, k, v, *, window: int) -> jnp.ndarray:
    """Causal sliding-window attention oracle.  q/k/v: (B, S, H, hd)
    (kv heads already repeated to H).  Positions attend to
    (pos-window, pos]."""
    b, s, h, hd = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * (hd**-0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
