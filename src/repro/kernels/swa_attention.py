"""Pallas TPU kernel: sliding-window flash attention (banded, online
softmax) — the long-context serving hot spot for the Mistral-family and
hybrid architectures (long_500k / prefill_32k shapes).

TPU adaptation: FlashAttention's CUDA thread-block tiling becomes a
Pallas grid over (batch*heads, q tiles, band kv tiles) with VMEM
scratch accumulators.  The sliding window is enforced STRUCTURALLY: each
q tile only visits the ceil(window/TILE_K)+1 kv tiles of its diagonal
band (index_map clamps at 0), so cost is O(S * window), not O(S^2) —
the same banding as the pure-JAX path, but with explicit VMEM residency
and no (S, TILE_K) score round-trips to HBM.

Softmax statistics (m, l) and the output accumulator live in VMEM
scratch across the innermost (kv) grid dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_Q = 128
TILE_K = 128
NEG_INF = -1e30


def _band_blocks(window: int) -> int:
    return -(-window // TILE_K) + 1


def _kv_index(qi, j, nband):
    return jnp.maximum(qi - nband + 1 + j, 0)


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, window: int, nband: int):
    j = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (TILE_Q, hd)
    k = k_ref[0].astype(jnp.float32)  # (TILE_K, hd)
    v = v_ref[0].astype(jnp.float32)

    hd = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd**-0.5)

    kidx = _kv_index(qi, j, nband)
    q_pos = qi * TILE_Q + jax.lax.broadcasted_iota(jnp.int32, (TILE_Q, TILE_K), 0)
    k_pos = kidx * TILE_K + jax.lax.broadcasted_iota(jnp.int32, (TILE_Q, TILE_K), 1)
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
    # clamped duplicate visits (qi < nband-1 revisit kv block 0): drop them
    first_j = jnp.maximum(nband - 1 - qi, 0)
    mask = mask & (j >= first_j)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                     # (TILE_Q, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_new = l_prev * scale + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nband - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_attention_pallas(q, k, v, *, window: int, interpret: bool = True):
    """q/k/v: (B, S, H, hd) (kv already repeated to H heads).
    S % TILE_Q == 0; causal sliding-window attention."""
    b, s, h, hd = q.shape
    assert s % TILE_Q == 0 and s % TILE_K == 0, s
    nband = _band_blocks(window)
    nq = s // TILE_Q

    # (B*H, S, hd) layout: heads fold into the grid's leading dim
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    kernel = functools.partial(_kernel, window=window, nband=nband)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nband),
        in_specs=[
            pl.BlockSpec((1, TILE_Q, hd), lambda bh, qi, j: (bh, qi, 0)),
            pl.BlockSpec(
                (1, TILE_K, hd),
                lambda bh, qi, j: (bh, _kv_index(qi, j, nband), 0),
            ),
            pl.BlockSpec(
                (1, TILE_K, hd),
                lambda bh, qi, j: (bh, _kv_index(qi, j, nband), 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, TILE_Q, hd), lambda bh, qi, j: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((TILE_Q, hd), jnp.float32),
            pltpu.VMEM((TILE_Q, 1), jnp.float32),
            pltpu.VMEM((TILE_Q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
