"""Pallas TPU kernel: fused LSTM cell — the paper's per-node compute
hot spot (§3.2; the model every patient device trains).

Fuses the two matmuls (x@Wx + h@Wh), bias add, and the 4-gate
nonlinearity + state update into one kernel, so per step the gate
pre-activations never round-trip to HBM.  The sequential time loop stays
a ``jax.lax.scan`` at the JAX level (TPU idiom: scan-of-fused-cell, see
DESIGN.md §3).

Grid: (B tiles, H tiles).  The 4H gate dim is tiled per H-tile: each
program computes its (TILE_B, TILE_H) slice of all four gates, reading
the (I, 4H) / (H, 4H) weight columns for its gate slice.  Weights are
laid out gate-major as (I, 4, H) so a gate slice is contiguous.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 128
TILE_H = 128


def _kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    x = x_ref[...].astype(jnp.float32)        # (TB, I)
    h = h_ref[...].astype(jnp.float32)        # (TB, H) — full H for the matmul
    c = c_ref[...].astype(jnp.float32)        # (TB, TH)
    wx = wx_ref[...].astype(jnp.float32)      # (I, 4, TH)
    wh = wh_ref[...].astype(jnp.float32)      # (H, 4, TH)
    b = b_ref[...].astype(jnp.float32)        # (4, TH)

    i_, f_, g_, o_ = [
        jnp.dot(x, wx[:, gate, :], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh[:, gate, :], preferred_element_type=jnp.float32)
        + b[gate]
        for gate in range(4)
    ]
    i = jax.nn.sigmoid(i_)
    f = jax.nn.sigmoid(f_)
    g = jnp.tanh(g_)
    o = jax.nn.sigmoid(o_)
    c_new = f * c + i * g
    h_out_ref[...] = (o * jnp.tanh(c_new)).astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell_pallas(x_t, h, c, wx, wh, b, *, interpret: bool = True):
    """x_t (B, I), h/c (B, H), wx (I, 4H), wh (H, 4H), b (4H,).
    B % TILE_B == 0 and H % TILE_H == 0 (ops.py pads)."""
    bsz, isz = x_t.shape
    hsz = h.shape[1]
    assert bsz % TILE_B == 0 and hsz % TILE_H == 0, (bsz, hsz)
    wx4 = wx.reshape(isz, 4, hsz)
    wh4 = wh.reshape(hsz, 4, hsz)
    b4 = b.reshape(4, hsz)
    grid = (bsz // TILE_B, hsz // TILE_H)
    h_new, c_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, isz), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((TILE_B, hsz), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((TILE_B, TILE_H), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((isz, 4, TILE_H), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((hsz, 4, TILE_H), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((4, TILE_H), lambda bi, hi: (0, hi)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, TILE_H), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((TILE_B, TILE_H), lambda bi, hi: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hsz), h.dtype),
            jax.ShapeDtypeStruct((bsz, hsz), c.dtype),
        ],
        interpret=interpret,
    )(x_t, h, c, wx4, wh4, b4)
    return h_new, c_new
