"""Pallas TPU kernel: blocked gossip parameter mixing (the paper's
Step 2+3 — ``W <- M @ W`` with a row-stochastic mixing matrix).

TPU adaptation (DESIGN.md §3): gossip is expressed as a dense mixing
contraction rather than point-to-point sends.  The contraction is
memory-bound (N is the federation size, tiny against D, the flattened
parameter size), so the kernel's job is to stream the (N, D) parameter
matrix through VMEM exactly once in MXU-aligned D-tiles while the (N, N)
mixing matrix stays VMEM-resident, and to fuse the active-mask select so
inactive nodes' rows are copies rather than flops.

Grid: one program per D-tile.  BlockSpecs:
  mix    (N, N)        — replicated to every program (index_map -> (0, 0)),
  w      (N, TILE_D)   — the program's slice of the parameter matrix,
  active (N, 1)        — replicated,
  out    (N, TILE_D).

N is padded to the 8-lane sublane multiple by the wrapper (ops.py).

The local-DP variant (``gossip_mix_dp_pallas``) fuses the whole DP
broadcast — noise-add, mix, clean-self-restore — into the same single
pass: ``out = M @ (W + Z) - diag(M) * Z`` (each node shares a noised
view but re-adds its own clean self-contribution), so the (N, D) matrix
is still streamed through VMEM exactly once instead of the three
tree_map passes the unfused path takes.

Sparse (neighbor-table) twins: ``gossip_mix_sparse_pallas`` /
``gossip_mix_sparse_dp_pallas`` take the (N, B+1) ``(idx, wgt)`` table
from ``core.topology.neighbor_table`` instead of the dense matrix and
compute ``out[n] = Σ_b wgt[n,b] · w[idx[n,b]]`` (DP:
``Σ_b wgt[n,b]·(w+z)[idx[n,b]] − wgt[n,0]·z[n]``) — O(N·B·D) flops on
the same one-pass TILE_D streaming layout, with the tiny idx/wgt tables
replicated to every program like the mix matrix was.  The row gather is
expressed as ``jnp.take`` inside the kernel body, which the CPU
interpreter (this repo's test/bench path) executes directly; a compiled
TPU lowering would route ``idx`` through scalar prefetch
(``PrefetchScalarGridSpec``) and DMA the rows instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 512  # lane-dim tile: multiple of 128 (MXU), 4 regs deep


def _kernel(mix_ref, w_ref, act_ref, out_ref):
    mix = mix_ref[...]          # (N, N) f32, VMEM-resident
    w = w_ref[...]              # (N, TILE_D)
    act = act_ref[...]          # (N, 1)
    mixed = jnp.dot(
        mix, w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    out = act * mixed + (1.0 - act) * w.astype(jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_pallas(
    mix: jnp.ndarray,
    w: jnp.ndarray,
    active: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """mix (N,N) f32, w (N,D), active (N,) -> (N,D).  D % TILE_D == 0
    (ops.py pads)."""
    n, d = w.shape
    assert d % TILE_D == 0, d
    grid = (d // TILE_D,)
    act2 = active.astype(jnp.float32).reshape(n, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), w.dtype),
        interpret=interpret,
    )(mix.astype(jnp.float32), w, act2)


def _sparse_kernel(idx_ref, wgt_ref, w_ref, act_ref, out_ref):
    idx = idx_ref[...]                              # (N, B1) i32, replicated
    wgt = wgt_ref[...]                              # (N, B1) f32, replicated
    w = w_ref[...].astype(jnp.float32)              # (N, TILE_D)
    act = act_ref[...]                              # (N, 1)
    n, b1 = idx.shape
    rows = jnp.take(w, idx.reshape(-1), axis=0).reshape(n, b1, -1)
    mixed = jnp.einsum("nb,nbd->nd", wgt, rows)
    out = jnp.where(act > 0, mixed, w)  # bit-exact inactive copies
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_sparse_pallas(
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    w: jnp.ndarray,
    active: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Sparse gather-mix: idx/wgt (N, B+1) neighbor table, w (N, D),
    active (N,) -> (N, D).  D % TILE_D == 0 (ops.py pads)."""
    n, d = w.shape
    b1 = idx.shape[1]
    assert d % TILE_D == 0, d
    assert idx.shape == wgt.shape == (n, b1), (idx.shape, wgt.shape, w.shape)
    grid = (d // TILE_D,)
    act2 = active.astype(jnp.float32).reshape(n, 1)
    return pl.pallas_call(
        _sparse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, b1), lambda j: (0, 0)),
            pl.BlockSpec((n, b1), lambda j: (0, 0)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), w.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), wgt.astype(jnp.float32), w, act2)


def _sparse_dp_kernel(idx_ref, wgt_ref, w_ref, noise_ref, act_ref, out_ref):
    idx = idx_ref[...]                              # (N, B1) i32, replicated
    wgt = wgt_ref[...]                              # (N, B1) f32, replicated
    w = w_ref[...].astype(jnp.float32)              # (N, TILE_D)
    noise = noise_ref[...].astype(jnp.float32)      # (N, TILE_D)
    act = act_ref[...]                              # (N, 1)
    n, b1 = idx.shape
    shared = w + noise
    rows = jnp.take(shared, idx.reshape(-1), axis=0).reshape(n, b1, -1)
    mixed = jnp.einsum("nb,nbd->nd", wgt, rows)
    # slot 0 is always self: wgt[:, :1] is the densified diagonal
    out = mixed - wgt[:, :1] * noise                # clean-self-restore
    out = jnp.where(act > 0, out, w)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_sparse_dp_pallas(
    idx: jnp.ndarray,
    wgt: jnp.ndarray,
    w: jnp.ndarray,
    noise: jnp.ndarray,
    active: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused sparse local-DP gossip:
    ``out[n] = Σ_b wgt[n,b]·(w+z)[idx[n,b]] − wgt[n,0]·z[n]`` with the
    active-mask select, one VMEM pass.  Shapes as
    ``gossip_mix_sparse_pallas`` plus noise (N, D)."""
    n, d = w.shape
    b1 = idx.shape[1]
    assert d % TILE_D == 0, d
    assert noise.shape == w.shape, (noise.shape, w.shape)
    assert idx.shape == wgt.shape == (n, b1), (idx.shape, wgt.shape, w.shape)
    grid = (d // TILE_D,)
    act2 = active.astype(jnp.float32).reshape(n, 1)
    return pl.pallas_call(
        _sparse_dp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, b1), lambda j: (0, 0)),
            pl.BlockSpec((n, b1), lambda j: (0, 0)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), w.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), wgt.astype(jnp.float32), w, noise.astype(w.dtype), act2)


def _dp_kernel(mix_ref, w_ref, noise_ref, self_w_ref, act_ref, out_ref):
    mix = mix_ref[...]                              # (N, N) f32, VMEM-resident
    w = w_ref[...].astype(jnp.float32)              # (N, TILE_D)
    noise = noise_ref[...].astype(jnp.float32)      # (N, TILE_D)
    self_w = self_w_ref[...]                        # (N, 1) = diag(mix), grid-
    act = act_ref[...]                              # (N, 1)   invariant, hoisted
    mixed = jnp.dot(mix, w + noise, preferred_element_type=jnp.float32)
    out = mixed - self_w * noise                    # clean-self-restore
    # where-select, not arithmetic blend: inactive rows stay bit-exact
    # copies even when active rows hold NaN/Inf (diverging runs)
    out = jnp.where(act > 0, out, w)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_dp_pallas(
    mix: jnp.ndarray,
    w: jnp.ndarray,
    noise: jnp.ndarray,
    active: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused local-DP gossip: ``M @ (W + Z) - diag(M) * Z`` with the
    active-mask select, one VMEM pass.  Shapes as gossip_mix_pallas plus
    noise (N, D); D % TILE_D == 0 (ops.py pads)."""
    n, d = w.shape
    assert d % TILE_D == 0, d
    assert noise.shape == w.shape, (noise.shape, w.shape)
    grid = (d // TILE_D,)
    act2 = active.astype(jnp.float32).reshape(n, 1)
    mix32 = mix.astype(jnp.float32)
    self_w = jnp.diagonal(mix32).reshape(n, 1)  # grid-invariant: once, not per tile
    return pl.pallas_call(
        _dp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, TILE_D), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), w.dtype),
        interpret=interpret,
    )(mix32, w, noise.astype(w.dtype), self_w, act2)
