"""BG-forecast serving entrypoint — the deployment half of the paper's
cold-start story, end to end on one box:

    PYTHONPATH=src python -m repro.launch.serve \
        [--checkpoint experiments/checkpoints/gluadfl_ohiot1dm_ring.npz] \
        [--buckets 1,4,16,64] [--personalize 3 --steps 50] \
        [--requests 256] [--selfcheck]

Lifecycle (see ``docs/SERVING.md`` for the operator runbook):

  1. **load** — the federation checkpoint (population params; the LSTM
     width is inferred from the flat parameter count) becomes row 0 of
     the servable's param store;
  2. **personalize** — the LAST ``--personalize`` patients of the
     dataset twin play newly diagnosed arrivals: their short histories
     (first ``--history-windows`` training windows — the cold-start
     case, shorter than a training batch) fine-tune the population
     model as ONE ``lax.scan``-compiled, vmap-batched program
     (``core.personalize.personalize_batch``); the personalized rows
     join the store;
  3. **serve** — a synthetic request stream (random patient, random
     test window) flows through the ``MicroBatcher`` (pad-to-bucket,
     max-live-batches admission, timeout flush) into the per-bucket
     compiled ``forecast`` method; per-request latency stats print at
     the end.

``--selfcheck`` additionally asserts that EVERY served forecast
bitwise-matches a direct ``model.apply(params_row, window)`` call —
padding, bucketing, and batching must be invisible to the numbers —
and exits 1 on the first mismatch (CI runs this in the ``serve`` job).

The LM-architecture decode demo that used to live at this path moved
intact to ``repro.launch.arch_demo``.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import load_federated_dataset
from repro.serve import GlucoseServable, MicroBatcher, Request, load_population

DEFAULT_CKPT = "experiments/checkpoints/gluadfl_ohiot1dm_ring.npz"


def build_request_stream(fed, servable, n_requests: int, seed: int):
    """A deterministic synthetic stream: each request picks a patient
    (personalized patients by name when present, else the population
    row) and one of that patient's test windows."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_requests):
        pi = int(rng.integers(0, fed.num_nodes))
        p = fed.patients[pi]
        wi = int(rng.integers(0, len(p.test_x)))
        reqs.append(
            Request(
                rid=rid,
                patient=servable.row_of_or_population(pi),
                window=np.asarray(p.test_x[wi], np.float32),
            )
        )
    return reqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--checkpoint", default=DEFAULT_CKPT,
                    help="federation checkpoint (.npz from launch/train.py); "
                         "the LSTM width is inferred from the param count")
    ap.add_argument("--hidden", type=int, default=None,
                    help="override the inferred LSTM width")
    ap.add_argument("--dataset", default="ohiot1dm",
                    choices=["ohiot1dm", "abc4d", "ctr3", "replace-bg"])
    ap.add_argument("--full-data", action="store_true",
                    help="full-length synthetic series (default is the "
                         "6-day fast twin — CI scale)")
    ap.add_argument("--buckets", default="1,4,16,64",
                    help="comma-separated padded batch-size buckets; the "
                         "forecast method compiles once per bucket")
    ap.add_argument("--max-live-batches", type=int, default=4,
                    help="admission cap: formed-but-unfinished batches")
    ap.add_argument("--flush-timeout-ms", type=float, default=5.0,
                    help="oldest-request wait before a partial batch ships")
    ap.add_argument("--personalize", type=int, default=3,
                    help="how many patients play cold-start arrivals "
                         "(personalized as one batched program; 0 = "
                         "population-only serving)")
    ap.add_argument("--history-windows", type=int, default=24,
                    help="windows of own history each cold-start patient "
                         "brings (small on purpose — newly diagnosed)")
    ap.add_argument("--steps", type=int, default=50,
                    help="fine-tune steps per cold-start patient")
    ap.add_argument("--requests", type=int, default=256,
                    help="synthetic request-stream length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-mode", default="map", choices=["map", "vmap"],
                    help="'map' lowers each batch row as the exact "
                         "single-request program (bitwise; the selfcheck "
                         "contract); 'vmap' is the row-parallel "
                         "throughput variant (~1e-8 drift)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="assert every served forecast bitwise-matches "
                         "direct model.apply; exit 1 on mismatch")
    args = ap.parse_args(argv)

    buckets = tuple(int(b) for b in args.buckets.split(",") if b)
    model, pop = load_population(args.checkpoint, hidden=args.hidden)
    n_params = int(sum(np.prod(l.shape) for l in jax.tree.leaves(pop)))
    print(f"checkpoint {args.checkpoint}: {n_params} params")

    fed = load_federated_dataset(args.dataset, fast=not args.full_data)
    servable = GlucoseServable(
        model, pop, buckets=buckets, personalize_steps=args.steps,
        batch_mode=args.batch_mode,
    )

    # -- cold-start personalization: the LAST K patients arrive new ----
    if args.personalize:
        k = min(args.personalize, fed.num_nodes)
        cohort = list(range(fed.num_nodes - k, fed.num_nodes))
        m = args.history_windows
        x = np.zeros((k, m, fed.x.shape[-1]), np.float32)
        y = np.zeros((k, m), np.float32)
        counts = np.zeros((k,), np.int32)
        for i, pi in enumerate(cohort):
            p = fed.patients[pi]
            c = min(m, len(p.train_x))
            x[i, :c], y[i, :c], counts[i] = p.train_x[:c], p.train_y[:c], c
        keys = jax.random.split(jax.random.PRNGKey(args.seed), k)
        t0 = time.perf_counter()
        servable.personalize(cohort, keys, x, y, counts)
        dt = time.perf_counter() - t0
        print(f"personalized {k} cold-start patients "
              f"({args.steps} steps on <= {m} windows each) as one "
              f"batched program in {dt:.2f}s")

    # -- serve a synthetic stream through the micro-batcher ------------
    servable.warmup(history_len=fed.x.shape[-1])
    print(f"warmed {len(servable.compiled_buckets)} bucket executables: "
          f"{sorted(servable.compiled_buckets)}")
    batcher = MicroBatcher(
        buckets,
        max_live_batches=args.max_live_batches,
        flush_timeout=args.flush_timeout_ms / 1e3,
    )
    reqs = build_request_stream(fed, servable, args.requests, args.seed)
    from repro.serve import replay

    preds = replay(servable, batcher, reqs)
    stats = batcher.stats()
    print(f"served {stats['completed']} forecasts: "
          f"p50 {stats['p50_latency_ms']:.2f}ms  "
          f"p99 {stats['p99_latency_ms']:.2f}ms  "
          f"{stats['forecasts_per_sec']:.0f} forecasts/sec "
          f"(queue wait {stats['mean_queue_wait_ms']:.2f}ms mean)")
    sample = [round(preds[r] * fed.sd + fed.mean, 1) for r in range(min(4, len(preds)))]
    print(f"first forecasts (mg/dL): {sample}")

    if args.selfcheck:
        bad = 0
        for r in reqs:
            params = jax.tree.map(lambda l: l[r.patient], servable._store)
            direct = float(model.apply(params, jnp.asarray(r.window)[None, :])[0])
            if not (direct == preds[r.rid]):
                bad += 1
                print(f"SELFCHECK MISMATCH rid={r.rid} patient-row={r.patient}: "
                      f"served {preds[r.rid]!r} != direct {direct!r}",
                      file=sys.stderr)
        if bad:
            print(f"selfcheck FAILED: {bad}/{len(reqs)} forecasts drifted "
                  f"from direct model.apply", file=sys.stderr)
            return 1
        print(f"selfcheck: {len(reqs)}/{len(reqs)} served forecasts "
              f"bitwise-match direct model.apply")
    return 0


if __name__ == "__main__":
    sys.exit(main())
