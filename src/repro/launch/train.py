"""Federated training launcher — the paper's experiment, end to end.

    PYTHONPATH=src python -m repro.launch.train \
        --dataset ohiot1dm --topology random --rounds 200 \
        [--eval-every 8] [--mixer sharded --gossip-impl psum] \
        [fl.comm_batch=7 train.lr=1e-3 ...]

Loads the synthetic-twin dataset, runs GluADFL, reports clinical metrics
of the population model per patient + aggregate, and writes a checkpoint
(.npz of the population params).

Engine selection
----------------
The compiled ``lax.scan`` chunk engine is the ONE production path — it
runs every configuration, including streaming eval:

  * default              — scan engine, ``--chunk`` rounds per compiled
                           program (one host sync per chunk);
  * ``--eval-every K``   — STAYS on the scan engine: val RMSE of the
                           population model is computed INSIDE the
                           scanned round body (lax.cond on
                           ``round % K``) against a pre-batched
                           validation set, so eval costs no per-round
                           host sync.  Records land in the history at
                           each boundary;
  * ``--engine loop`` or ``--chunk 0``
                         — explicit per-round Python-loop DEBUG
                           fallback (host callback eval, pdb between
                           rounds).  Never selected automatically.

Scenario sweeps (``--sweep-ratios`` / ``--sweep-seeds``)
--------------------------------------------------------
``--sweep-ratios 0,0.3,0.7 --sweep-seeds 3`` trains the whole
(ratio x seed) grid of the chosen topology as ONE batched device
program (``GluADFL.train_sweep``): per-scenario inactive ratios and
seed keys are vmapped over the compiled chunk scan, so the grid costs
one compile per chunk shape instead of G serial runs.  Streaming eval
(``--eval-every``) stays in-scan and returns a (grid, chunk) record
stack.  With ``--mixer sharded`` the grid becomes a real mesh axis: the
stacked (G, N, ...) state is placed on a 2-D ("grid", "node") mesh
(``launch.mesh.make_sweep_mesh``) where scenarios batch over "grid"
and the gossip collectives (``--gossip-impl allgather|psum|auto``)
stay scoped to "node" — the memory-scaled way to sweep paper-scale
federations.  ``--sweep-schedules bernoulli,markov``,
``--sweep-skews 0,0.5,1`` and ``--sweep-dp-sigmas 0,0.01,0.05`` extend
the cross product with the Markov-sticky staleness, non-IID data-skew
and DP-noise-level axes (each a traced ``(G,)`` array; every scenario
keeps exact serial key-stream parity).  Sweeps are single-process and
scan-engine only
(``--mixer kernel``/``--use-kernel``, ``--engine loop`` and multi-host
flags refuse); instead of a checkpoint, the launcher writes a
per-scenario summary JSON to ``--out``.

Gossip impl
-----------
  * ``--gossip-impl allgather`` (default) — gather the federation's node
    axis per device and contract locally: fastest on ICI while the
    gathered (N, D) block fits per-device memory (``--mixer sharded``;
    ignored by tree/kernel);
  * ``--gossip-impl psum``      — psum-of-local-contributions
    (reduce-scatter): per-device memory O(N/shards · D), the multi-host
    / big-model schedule (``--mixer sharded`` only);
  * ``--gossip-impl masked``    — pairwise-masked secure aggregation
    (``core.secure_agg``): per-round per-edge PRNG masks whose weighted
    sum cancels exactly, so neighbors never see raw parameters and the
    trained state is BITWISE the unmasked run's.  Composes with every
    mixer and representation (sharded rides the allgather schedule);
  * ``--gossip-impl auto``      — pick by the per-device memory the
    gathered federation would need (``launch.mesh.choose_gossip_impl``).

Gossip representation (any mixer)
---------------------------------
``--gossip-repr dense|sparse|auto`` picks the mixing operator's storage:
the dense (N, N) ``mixing_matrix`` or the (N, B+1) neighbor table
(O(N·B·D) contraction, no (N, N) array for static topologies — the
population-scale path).  ``auto`` (default) goes sparse once
``B+1 ≪ N`` (``launch.mesh.choose_gossip_repr``): sparse at the paper's
N=226, dense on small smoke runs.

Multi-host bootstrap (``--num-processes > 1``)
----------------------------------------------
Launch the SAME command on every host, varying only the process id::

    REPRO_COORDINATOR=host0:12345 REPRO_NUM_PROCESSES=4 \
    REPRO_PROCESS_ID=$RANK PYTHONPATH=src python -m repro.launch.train \
        --dataset replace-bg --mixer sharded --gossip-impl psum ...

(or the equivalent ``--coordinator/--num-processes/--process-id`` flags;
flags win over the environment).  Process/data-placement rules:

  * ``launch.multihost.initialize`` joins the ``jax.distributed``
    cluster FIRST — before any device query — and on CPU selects the
    gloo cross-process collectives.  The local device count is whatever
    the backend exposes (force with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` for CPU runs);
  * the node-axis mesh is GLOBAL (``launch.mesh.make_federation_mesh``
    prefers widths giving every process the same whole number of
    shards), so the gossip collective spans hosts;
  * every process loads the same deterministic dataset host-side but
    materializes ON DEVICE only its own node rows
    (``launch.multihost.place_federation``); the validation set is
    replicated;
  * multi-host implies ``--mixer sharded`` (auto-selected with a note if
    the flag disagrees) and the scan engine (``--engine loop`` refuses);
  * per-patient clinical metrics + the checkpoint are gathered to and
    written by PROCESS 0 only; all processes join a final barrier so the
    cluster tears down cleanly.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ExperimentConfig, apply_overrides
from repro.core import GluADFL
from repro.data import load_federated_dataset
from repro.metrics import all_metrics
from repro.models import LSTMModel
from repro.optim import get_optimizer
from repro.utils.pytree import tree_to_vector, vector_to_tree


def _patient_predictions(model, pop, fed):
    """Yield ``(patient, mg/dL predictions)`` of a population model over
    each patient's test split — shared by the single-run and sweep
    summaries."""
    for p in fed.patients:
        pred = np.asarray(
            model.apply(pop, jnp.asarray(p.test_x))
        ) * fed.sd + fed.mean
        yield p, pred


def save_checkpoint(path: Path, params) -> None:
    vec = np.asarray(tree_to_vector(params))
    leaves, treedef = jax.tree.flatten(params)
    meta = [(str(i), list(l.shape), str(l.dtype)) for i, l in enumerate(leaves)]
    np.savez(path, vec=vec, meta=json.dumps(meta))


def load_checkpoint(path: Path, like):
    data = np.load(path, allow_pickle=False)
    return vector_to_tree(jnp.asarray(data["vec"]), like)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="ohiot1dm",
                    choices=["ohiot1dm", "abc4d", "ctr3", "replace-bg"])
    ap.add_argument("--topology", default="random",
                    choices=["ring", "cluster", "random", "star", "full"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--inactive-ratio", type=float, default=0.0)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--fast-data", action="store_true",
                    help="6-day synthetic series (CI scale)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="DEPRECATED: pass --mixer kernel instead (this "
                         "flag maps through, with a DeprecationWarning; it "
                         "also still selects the Pallas LSTM-cell kernel)")
    ap.add_argument("--mixer", default=None, choices=["tree", "kernel", "sharded"],
                    help="gossip mixer: tree (einsum), kernel (Pallas), "
                         "sharded (node-sharded mesh collective); default "
                         "tree")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rounds per compiled lax.scan chunk (host syncs "
                         "once per chunk); 0 = per-round python loop; "
                         "default: gluadfl.DEFAULT_CHUNK")
    ap.add_argument("--engine", default="scan", choices=["scan", "loop"],
                    help="scan (default; the production path, incl. "
                         "streaming eval) or loop (per-round debug "
                         "fallback; also selected by --chunk 0)")
    ap.add_argument("--sweep-ratios", default=None,
                    help="comma-separated inactive ratios, e.g. "
                         "'0,0.3,0.7': train the whole (ratio x seed) "
                         "grid of --topology as ONE batched program "
                         "(GluADFL.train_sweep) instead of a single run")
    ap.add_argument("--sweep-seeds", type=int, default=1,
                    help="seeds per sweep scenario (0..K-1); only with "
                         "--sweep-ratios")
    ap.add_argument("--sweep-schedules", default=None,
                    help="comma-separated participation schedules to "
                         "sweep, from {bernoulli, markov}: adds the "
                         "Markov-sticky staleness axis to the grid; only "
                         "with --sweep-ratios")
    ap.add_argument("--sweep-skews", default=None,
                    help="comma-separated non-IID data-skew strengths, "
                         "e.g. '0,0.5,1': node i trains on batches "
                         "shifted by skew*node_skew_offsets(N)[i]; only "
                         "with --sweep-ratios")
    ap.add_argument("--sweep-dp-sigmas", default=None,
                    help="comma-separated local-DP gossip noise sigmas "
                         "swept as a traced axis, e.g. '0,0.01,0.05'; "
                         "only with --sweep-ratios")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="compute population val-RMSE every K rounds "
                         "INSIDE the scanned chunk (0 = off); no "
                         "per-round host sync")
    ap.add_argument("--gossip-impl", default="allgather",
                    choices=["allgather", "psum", "masked", "gather", "auto"],
                    help="gossip schedule: allgather (per-device O(N*D) "
                         "gather), psum (reduce-scatter, per-device "
                         "O(N/shards*D)), masked (pairwise-masked secure "
                         "aggregation — any mixer; bitwise the allgather "
                         "result), gather (sharded gather tables: ppermute "
                         "halo rotation, per-device O(N/shards*D) with NO "
                         "gathered federation — needs --mixer sharded and "
                         "the sparse repr; the 100k-node schedule), or "
                         "auto (memory-based choice)")
    ap.add_argument("--gossip-repr", default="auto",
                    choices=["dense", "sparse", "auto"],
                    help="mixing-operator representation: dense (N, N) "
                         "matrix, sparse (N, B+1) neighbor table "
                         "(O(N*B) mixing — population scale), or auto "
                         "(sparse once B+1 << N)")
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator host:port (or env "
                         "REPRO_COORDINATOR); only with --num-processes > 1")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total processes in the multi-host federation "
                         "(or env REPRO_NUM_PROCESSES); unset/1 = "
                         "single-process")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's id in [0, num-processes) (or env "
                         "REPRO_PROCESS_ID)")
    ap.add_argument("--out", default="experiments/checkpoints")
    ap.add_argument("overrides", nargs="*", help="cfg overrides a.b=c")
    args = ap.parse_args()

    if args.use_kernel:
        import warnings

        warnings.warn(
            "--use-kernel is deprecated; pass --mixer kernel instead "
            "(the flag maps through for now and will be removed)",
            DeprecationWarning,
            stacklevel=2,
        )
        if args.mixer is None:
            args.mixer = "kernel"
        elif args.mixer != "kernel":
            raise SystemExit(
                f"--use-kernel contradicts --mixer {args.mixer}; "
                f"pass one or the other"
            )

    from repro.launch import multihost

    # must precede every device query (mesh building, auto gossip-impl)
    distributed = multihost.initialize(
        args.coordinator, args.num_processes, args.process_id
    )
    sweep_ratios = None
    sweep_axes = {}
    if args.sweep_ratios is not None:
        sweep_ratios = [float(r) for r in args.sweep_ratios.split(",") if r]
        if not sweep_ratios:
            raise SystemExit("--sweep-ratios parsed to an empty list")
        if args.sweep_seeds < 1:
            raise SystemExit("--sweep-seeds must be >= 1")
        if args.sweep_schedules:
            sweep_axes["schedules"] = tuple(
                s.strip() for s in args.sweep_schedules.split(",") if s.strip()
            )
        if args.sweep_skews:
            sweep_axes["skews"] = tuple(
                float(v) for v in args.sweep_skews.split(",") if v
            )
        if args.sweep_dp_sigmas:
            sweep_axes["dp_sigmas"] = tuple(
                float(v) for v in args.sweep_dp_sigmas.split(",") if v
            )
        if distributed:
            raise SystemExit("scenario sweeps are single-process "
                             "(drop --num-processes or --sweep-ratios)")
        if args.mixer == "kernel":
            raise SystemExit("scenario sweeps batch the tree or sharded "
                             "mixer; the Pallas kernel is per-scenario "
                             "(drop --mixer kernel/--use-kernel)")
        if args.engine == "loop" or args.chunk == 0:
            raise SystemExit("scenario sweeps need the scan engine "
                             "(drop --engine loop / --chunk 0)")
    elif args.sweep_schedules or args.sweep_skews or args.sweep_dp_sigmas:
        raise SystemExit("--sweep-schedules/--sweep-skews/--sweep-dp-sigmas "
                         "extend the scenario grid and need --sweep-ratios")
    if distributed:
        print(f"multihost: process {jax.process_index()}/{jax.process_count()} "
              f"local_devices={jax.local_device_count()} "
              f"global_devices={jax.device_count()}")
        if args.mixer not in (None, "sharded"):
            print(f"multihost: overriding --mixer {args.mixer} -> sharded "
                  f"(the node axis must span processes)")
        args.mixer = "sharded"
        if args.engine == "loop" or args.chunk == 0:
            raise SystemExit("multihost runs need the scan engine "
                             "(drop --engine loop / --chunk 0)")

    cfg = apply_overrides(ExperimentConfig(), args.overrides)
    fed = load_federated_dataset(args.dataset, fast=args.fast_data,
                                 history_len=cfg.data.history_len,
                                 horizon=cfg.data.horizon)
    print(f"dataset={args.dataset} nodes={fed.num_nodes} "
          f"windows/node~{int(fed.counts.mean())}")

    model = LSTMModel(hidden=args.hidden, use_kernel=args.use_kernel).as_model()
    from dataclasses import replace

    fl_cfg = replace(
        cfg.fl, topology=args.topology, num_nodes=fed.num_nodes,
        rounds=args.rounds, inactive_ratio=args.inactive_ratio,
    )
    # the scenario grid and its mesh come FIRST: the auto gossip-impl
    # choice must budget for the swept working set, and the trainer gets
    # the one mesh train_sweep will actually run on
    sweep_grid = sweep_mesh = None
    if sweep_ratios is not None:
        from repro.core import SweepGrid

        sweep_grid = SweepGrid.build(
            [args.topology], sweep_ratios, range(args.sweep_seeds),
            num_nodes=fed.num_nodes, cluster_size=fl_cfg.cluster_size,
            **sweep_axes,
        )
        if args.mixer == "sharded":
            from repro.launch.mesh import make_sweep_mesh

            sweep_mesh = make_sweep_mesh(sweep_grid.size, fed.num_nodes)

    gossip_impl = args.gossip_impl
    if gossip_impl == "auto":
        from repro.launch.mesh import choose_gossip_impl

        p0 = model.init(jax.random.PRNGKey(0))
        node_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(p0))
        if sweep_mesh is not None:
            # swept-sharded: the allgather schedule gathers the node axis
            # of EVERY locally-held scenario block, so the per-device
            # working set is (G/grid_width) x the serial estimate — and
            # psum's shard count is the SWEEP mesh's node width, not the
            # 1-D federation mesh's
            g_local = sweep_grid.size // sweep_mesh.shape["grid"]
            gossip_impl = choose_gossip_impl(
                fed.num_nodes, node_bytes * g_local,
                shards=sweep_mesh.shape["node"],
            )
        else:
            gossip_impl = choose_gossip_impl(fed.num_nodes, node_bytes)
        print(f"gossip-impl auto -> {gossip_impl}")

    gossip_repr = args.gossip_repr
    if gossip_repr == "auto":
        from repro.launch.mesh import choose_gossip_repr

        gossip_repr = choose_gossip_repr(fed.num_nodes, fl_cfg.comm_batch,
                                         mesh=sweep_mesh)
        print(f"gossip-repr auto -> {gossip_repr}")

    # args.mixer is already "kernel" when --use-kernel was passed (the
    # deprecation shim above), so the flag itself stays out of the plan
    trainer = GluADFL(model, get_optimizer(cfg.train.optimizer, cfg.train.lr),
                      fl_cfg, mixer=args.mixer,
                      gossip_impl=gossip_impl, gossip_repr=gossip_repr,
                      mesh=sweep_mesh)

    # pre-batched validation set for the in-scan streaming eval: a capped
    # slice of every patient's val windows (one fixed array -> scan const)
    val_data = None
    if args.eval_every:
        cap = max(1, 2048 // fed.num_nodes)
        val_x = np.concatenate([p.val_x[:cap] for p in fed.patients])
        val_y = np.concatenate([p.val_y[:cap] for p in fed.patients])
        val_data = (val_x, val_y)
        print(f"streaming eval: every {args.eval_every} rounds on "
              f"{len(val_x)} val windows (in-scan)")

    if sweep_ratios is not None:
        from repro.utils.pytree import tree_index

        grid = sweep_grid
        axes_note = "".join(
            f" x {k} {list(v)}" for k, v in sweep_axes.items()
        )
        print(f"sweep: {grid.size} scenarios "
              f"({args.topology} x {sweep_ratios}{axes_note} x "
              f"{args.sweep_seeds} seeds) as one batched program")
        if sweep_mesh is not None:
            # the trainer holds this exact mesh — train_sweep runs on it
            print(f"sweep mesh: {dict(sweep_mesh.shape)} over "
                  f"{len(jax.devices())} devices "
                  f"(grid batches, node carries the gossip collectives)")
        pops, hists, _ = trainer.train_sweep(
            fed.x, fed.y, fed.counts, grid=grid,
            batch_size=cfg.train.batch_size, chunk=args.chunk or None,
            eval_every=args.eval_every, val_data=val_data,
        )
        summary = []
        for g in range(grid.size):
            lab = grid.label_dict(g)
            hist = hists[g]
            pop_g = tree_index(pops, g)
            preds, ys = [], []
            for p, pred in _patient_predictions(model, pop_g, fed):
                preds.append(pred)
                ys.append(p.test_y_raw)
            agg = all_metrics(np.concatenate(ys), np.concatenate(preds))
            rec = {**lab, "final_loss": hist[-1]["loss"], **agg}
            evals = [h["val_rmse"] for h in hist if "val_rmse" in h]
            if evals:
                rec["final_val_rmse"] = evals[-1]
            summary.append(rec)
            extra = ""
            if sweep_axes:
                extra = (f" sched={lab['schedule']} skew={lab['skew']:g} "
                         f"dp={lab['dp_sigma']:g}")
            print(f"  [{lab['topology']:8s} "
                  f"inactive={lab['inactive_ratio']:.0%} "
                  f"seed={lab['seed']}{extra}] "
                  f"loss {rec['final_loss']:.4f}  test RMSE {agg['rmse']:6.2f}  "
                  f"MARD {agg['mard']:5.2f}%")
        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        sweep_path = out / f"sweep_{args.dataset}_{args.topology}.json"
        sweep_path.write_text(json.dumps(summary, indent=2))
        print(f"sweep summary -> {sweep_path}")
        return

    pop, hist, state = trainer.train(
        jax.random.PRNGKey(cfg.fl.seed), fed.x, fed.y, fed.counts,
        batch_size=cfg.train.batch_size,
        engine="loop" if args.chunk == 0 else args.engine,
        chunk=args.chunk or None,
        eval_every=args.eval_every,
        val_data=val_data,
    )
    if multihost.is_primary():  # every process holds the same history
        print(f"round 0 loss {hist[0]['loss']:.4f} -> round {args.rounds-1} "
              f"loss {hist[-1]['loss']:.4f}")
        evals = [h for h in hist if "val_rmse" in h]
        if evals:
            print("val RMSE (normalized): " + "  ".join(
                f"r{h['round']}={h['val_rmse']:.4f}" for h in evals[-5:]))

    # the population model is replicated across every process; the
    # host-side gather makes it plain numpy so clinical metrics and the
    # checkpoint run local-only — then PROCESS 0 is the single writer
    pop = multihost.fetch_replicated(pop)
    if multihost.is_primary():
        # per-patient + aggregate clinical metrics
        preds, ys = [], []
        for i, (p, pred) in enumerate(_patient_predictions(model, pop, fed)):
            m = all_metrics(p.test_y_raw, pred)
            print(f"  patient {i:3d}: RMSE {m['rmse']:6.2f}  MARD {m['mard']:5.2f}%  "
                  f"gRMSE {m['grmse']:6.2f}  lag {m['time_lag']:4.1f}min")
            preds.append(pred)
            ys.append(p.test_y_raw)
        agg = all_metrics(np.concatenate(ys), np.concatenate(preds))
        print("population:", {k: round(v, 2) for k, v in agg.items()})

        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        ckpt = out / f"gluadfl_{args.dataset}_{args.topology}.npz"
        save_checkpoint(ckpt, pop)
        print(f"checkpoint -> {ckpt}")
    multihost.barrier("train_done")


if __name__ == "__main__":
    main()
