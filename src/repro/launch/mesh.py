"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run with
the real single device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model).  Multi-pod: 2 pods of
    256 (pod x data x model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = devices or len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))


def make_federation_mesh(num_nodes: int, *, devices: int | None = None):
    """Node-sharded 1-axis mesh for device-parallel gossip: the stacked
    federation axis N is split over the largest available device count
    that divides it (shard_map needs N % devices == 0).  Falls back to a
    single-device mesh, which degenerates to the local contraction.

    Multi-host aware: after ``launch.multihost.initialize`` the device
    pool is GLOBAL (``jax.devices()`` spans every process, ordered by
    process index), so the node axis spans hosts and the gossip
    collectives lower to real cross-host transfers.  The mesh is built
    from an explicitly BALANCED device list — width/processes devices
    drawn from every process — because ``jax.make_mesh`` alone takes the
    FIRST ``width`` global devices, which for width < device count would
    strand the later processes with zero shards (and no federation
    rows to place).  Degenerate node counts that no balanced width
    divides fall back to the first-k mesh; placement then fails loudly
    on the stranded processes (``multihost.process_row_slice``)."""
    avail = devices or len(jax.devices())
    procs = jax.process_count()
    divisors = [k for k in range(1, avail + 1) if num_nodes % k == 0]
    if procs > 1 and devices is None:
        by_proc: dict[int, list] = {}
        for d in jax.devices():
            by_proc.setdefault(d.process_index, []).append(d)
        per_proc = min(len(v) for v in by_proc.values())
        aligned = [k for k in divisors if k % procs == 0 and k // procs <= per_proc]
        if aligned:
            width = max(aligned)
            picked = [
                d for p in sorted(by_proc) for d in by_proc[p][: width // procs]
            ]
            return jax.make_mesh((width,), ("node",), devices=picked)
    return jax.make_mesh((max(divisors),), ("node",))


def _sweep_mesh_widths(num_scenarios: int, num_nodes: int, avail: int) -> tuple[int, int]:
    """(grid_width, node_width) for :func:`make_sweep_mesh`'s default
    search: both must divide their extents; maximize devices used, then
    prefer the wider node axis (the memory-scaled one)."""
    best = (1, 1)
    for gw in (d for d in range(1, avail + 1) if num_scenarios % d == 0):
        for nw in (d for d in range(1, avail // gw + 1) if num_nodes % d == 0):
            if (gw * nw, nw) > (best[0] * best[1], best[1]):
                best = (gw, nw)
    return best


def make_sweep_mesh(
    num_scenarios: int,
    num_nodes: int,
    *,
    devices: int | None = None,
    grid_width: int | None = None,
    node_width: int | None = None,
):
    """2-D ``("grid", "node")`` mesh for the swept-sharded engine: the
    grid axis BATCHES scenarios (pure data parallelism — no gossip
    collective ever crosses it) while the node axis carries the
    federation collectives, exactly as on the 1-D federation mesh.

    Widths must divide their global extents (shard_map blocks are
    whole): ``grid_width | num_scenarios`` and ``node_width | num_nodes``.
    The default search maximizes devices used, tie-breaking toward the
    node axis — that is the memory-scaled one (psum keeps per-device
    state at O(G/grid · N/node · D), so widening "node" shrinks what a
    single device must hold of each scenario).  Degenerate extents fall
    back gracefully: a (1, 1) mesh on one device is the local
    contraction, batched.

    Single-process only (scenario sweeps are — multi-host runs sweep
    via serial ``train()`` per scenario); pass explicit widths to pin a
    layout in tests."""
    avail = devices or len(jax.devices())
    if grid_width is None or node_width is None:
        grid_width, node_width = _sweep_mesh_widths(num_scenarios, num_nodes, avail)
    if num_scenarios % grid_width or num_nodes % node_width:
        raise ValueError(
            f"sweep mesh widths must divide the grid: "
            f"G={num_scenarios} % grid_width={grid_width} and "
            f"N={num_nodes} % node_width={node_width} must both be 0"
        )
    return jax.make_mesh((grid_width, node_width), ("grid", "node"))


# the auto-knob policies (choose_gossip_impl / choose_gossip_repr and
# their budget constants) are plan-resolution policies and live with the
# plan in core.gossip_plan; re-exported here for call-site back-compat
from repro.core.gossip_plan import (  # noqa: E402,F401
    DEFAULT_GATHER_BUDGET_BYTES,
    SPARSE_GOSSIP_FACTOR,
    choose_gossip_impl,
    choose_gossip_repr,
)


def make_gossip_dp_mesh(*, nodes: int = 4, multi_pod: bool = False):
    """Mesh view for gossip data-parallelism (DESIGN.md §4): the data
    axis is split into (node, data) so each federated node is a
    ``data/node``-way data-parallel group.  Same device order as the
    production mesh."""
    if multi_pod:
        # nodes = 2 pods x (nodes // 2) groups
        per_pod = max(nodes // 2, 1)
        return jax.make_mesh(
            (2, per_pod, 16 // per_pod, 16), ("pod", "node", "data", "model")
        )
    return jax.make_mesh((nodes, 16 // nodes, 16), ("node", "data", "model"))
