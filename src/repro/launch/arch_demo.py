"""Decode demo for the assigned architectures (reduced configs on
CPU; the full configs lower via launch.dryrun).  Unrelated to the
glucose service — that is ``repro.launch.serve`` — this drives the
LM-family KV-cache/state decode path.

    PYTHONPATH=src python -m repro.launch.arch_demo --arch yi-6b --tokens 16

Builds the reduced variant of ``--arch``, prefills a prompt, then
greedy-decodes ``--tokens`` tokens through the KV-cache/state decode
path — the same code the decode_32k / long_500k dry-runs lower at
production shape.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import build_arch
from repro.config import get_arch_config, list_archs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) config — needs a big host")
    args = ap.parse_args()

    cfg = get_arch_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    arch = build_arch(cfg)
    print(f"arch={cfg.name} family={cfg.family} L={cfg.num_layers} d={cfg.d_model}")

    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    B = args.batch
    state = arch.init_decode_state(params, B, args.prompt_len + args.tokens + 8)
    decode = jax.jit(arch.decode_fn)

    # feed the prompt token by token (prefill-by-decode keeps the example
    # uniform across cache/state families)
    tok = jnp.ones((B, 1), jnp.int32)
    t0 = time.perf_counter()
    out_tokens = []
    for pos in range(args.prompt_len + args.tokens):
        logits, state = decode(params, state,
                               {"token": tok, "pos": jnp.asarray(pos, jnp.int32)})
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        if pos >= args.prompt_len:
            out_tokens.append(np.asarray(tok[:, 0]))
    dt = time.perf_counter() - t0
    steps = args.prompt_len + args.tokens
    print(f"decoded {args.tokens} tokens (batch {B}) in {dt:.2f}s "
          f"({steps / dt:.1f} steps/s incl. compile)")
    print("sampled token ids:", np.stack(out_tokens, 1).tolist())


if __name__ == "__main__":
    main()
