"""Multi-host federation bootstrap — ``jax.distributed`` + per-host data.

The paper's federation lives on devices scattered across a real network
(§1, Fig 4); inside this repo that means the node axis of the stacked
federation must span *processes*, not just one process's devices.  This
module is the whole host-side story:

  * :func:`initialize` — wrap ``jax.distributed.initialize`` with
    coordinator address / process id / process count taken from explicit
    arguments or the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
    ``REPRO_PROCESS_ID`` environment (so launchers and the subprocess
    test harness share one code path).  On the CPU backend it first
    selects the gloo cross-process collectives implementation — without
    it the psum/all-gather lowering deadlocks across hosts.
  * :func:`place_federation` — per-host data placement: every process
    computes the same host-side numpy federation (the synthetic twins
    are deterministic), but only materializes ON DEVICE the rows its
    addressable shards own (``jax.make_array_from_process_local_data``).
    No host ever holds another host's node shard in device memory.
  * :func:`replicate` — scan constants (validation set, counts when the
    mesh can't split them) placed fully-replicated on the global mesh.
  * :func:`fetch_replicated` — bring a fully-replicated global array
    (population params, losses) back to host numpy on EVERY process, via
    its first addressable shard; the checkpoint gather to process 0 is
    this plus an ``is_primary()`` guard.

Single-process runs degrade gracefully: ``initialize`` is a no-op when
``num_processes`` resolves to 1, and the placement helpers fall back to
plain ``device_put`` so all call sites stay unconditional.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# re-exported: the sharding math lives in core (layering: launch -> core)
from repro.core.distributed import process_row_slice  # noqa: F401

PyTree = Any

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_initialized = False


def _env(name: str, cast=str):
    v = os.environ.get(name)
    return cast(v) if v not in (None, "") else None


def initialize(
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> bool:
    """Join (or skip) the ``jax.distributed`` cluster.

    Arguments default to the ``REPRO_*`` environment.  Returns True when
    a multi-process cluster was actually formed; False for the
    single-process no-op (``num_processes`` unset/0/1).  Must run before
    any jax backend use (device queries count); the caller forces local
    device count via ``XLA_FLAGS=--xla_force_host_platform_device_count``
    in the environment, not here, because that flag only binds before the
    first jax import.
    """
    global _initialized
    coordinator = coordinator or _env(ENV_COORDINATOR)
    num_processes = num_processes if num_processes is not None else _env(ENV_NUM_PROCESSES, int)
    process_id = process_id if process_id is not None else _env(ENV_PROCESS_ID, int)
    if not num_processes or num_processes <= 1:
        return False
    if _initialized:
        return True
    if coordinator is None or process_id is None:
        raise ValueError(
            "multi-process run needs coordinator + process_id "
            f"(got coordinator={coordinator!r}, process_id={process_id!r})"
        )
    # CPU backend: cross-process collectives need gloo (the default
    # in-process implementation deadlocks across hosts). Harmless on
    # TPU/GPU where the flag is ignored by the non-CPU backends.
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # older/newer jax without the knob
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    _initialized = True
    return True


def is_primary() -> bool:
    """True on the process that owns host-side side effects (checkpoint
    writes, report printing) — process 0, or the only process."""
    return jax.process_index() == 0


def _mesh_is_local(mesh: Mesh) -> bool:
    """True when every mesh device belongs to this process (the
    single-host case, where plain ``device_put`` placement suffices)."""
    pid = jax.process_index()
    return all(d.process_index == pid for d in mesh.devices.flat)


def shard_rows(mesh: Mesh, arr: np.ndarray, *, axis_name: str = "node"):
    """Place a host-replicated array node-sharded over ``mesh``: each
    process device-puts ONLY its own global rows (the per-host placement
    rule).  Falls back to a plain ``device_put`` on a local mesh."""
    sh = NamedSharding(mesh, P(axis_name))
    if _mesh_is_local(mesh):
        return jax.device_put(arr, sh)
    local = arr[process_row_slice(sh, arr.shape)]
    return jax.make_array_from_process_local_data(sh, local, arr.shape)


def replicate(mesh: Mesh, arr: np.ndarray):
    """Fully-replicated placement on the global mesh (scan constants:
    validation sets, anything every shard reads whole)."""
    sh = NamedSharding(mesh, P())
    if _mesh_is_local(mesh):
        return jax.device_put(arr, sh)
    return jax.make_array_from_process_local_data(sh, np.asarray(arr), np.shape(arr))


def place_federation(mesh: Mesh, x, y, counts, val_data=None):
    """Per-host placement of the whole federation: node-sharded training
    tensors (each process materializes only its shard's CGM windows) and
    a replicated validation set.  Returns ``(x, y, counts, val_data)``
    as global arrays ready for the jitted engine."""
    x = shard_rows(mesh, np.asarray(x))
    y = shard_rows(mesh, np.asarray(y))
    counts = shard_rows(mesh, np.asarray(counts))
    if val_data is not None:
        val_data = tuple(replicate(mesh, np.asarray(v)) for v in val_data)
    return x, y, counts, val_data


def fetch_replicated(tree: PyTree) -> PyTree:
    """Host numpy copy of a tree of fully-replicated (or local) arrays.

    Multi-process global arrays are not fully addressable, so plain
    ``np.asarray`` refuses them even when every process holds a complete
    copy; read the first addressable shard instead.  Every process gets
    the value (cheap — it is local by construction); callers that only
    want one writer guard with :func:`is_primary`.
    """

    def leaf(l):
        if isinstance(l, jax.Array) and not l.is_fully_addressable:
            if not l.sharding.is_fully_replicated:
                raise ValueError(
                    "fetch_replicated needs fully-replicated arrays; got "
                    f"sharding {l.sharding}"
                )
            return np.asarray(l.addressable_shards[0].data)
        return np.asarray(l)

    return jax.tree.map(leaf, tree)


def barrier(name: str = "repro_barrier") -> None:
    """Sync all processes (e.g. before process 0 reads files others
    write, or before teardown).  No-op single-process."""
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(name)
