"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent
without hardware (DESIGN.md §6).

For each combo this:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. constructs the step function for the shape kind:
       train_4k    -> gradient-accumulated train_step
       prefill_32k -> prefill_fn
       decode_*    -> decode_fn (1 token + seq_len-deep state)
  3. jits with explicit in_shardings from the partition rules,
  4. ``.lower(**ShapeDtypeStruct inputs).compile()`` — any sharding
     mismatch / unsupported collective / compile-OOM fails here,
  5. records memory_analysis (fit proof), cost_analysis, and the
     collective schedule parsed from the optimized HLO.

NOTE on loop accounting: XLA's cost analysis visits while bodies ONCE;
layer scans and microbatch scans are therefore undercounted in the RAW
numbers recorded here.  The roofline harness (benchmarks/roofline.py)
lowers the per-layer body separately and applies exact trip counts —
those are the §Roofline numbers.  The raw full-step numbers are kept for
the memory-fit proof and the collective schedule.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import os

# must precede the first jax import: the dry-run fakes a 512-chip pod
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.arch import build_arch
from repro.arch.api import SHAPES, Arch
from repro.arch.common import init_train_state, make_train_step
from repro.arch.sharding import data_axes, param_pspecs
from repro.config import get_arch_config, list_archs
from repro.launch.mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\(([^)]*)\)|((?:\w+)\[[\d,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# bytes-on-the-wire model per result byte (ring algorithms, large N limit)
_WIRE_FACTOR = {
    "all-gather": 1.0,        # result is the gathered tensor
    "all-reduce": 2.0,        # reduce-scatter + all-gather of operand size
    "reduce-scatter": 1.0,    # operand passes once (result is 1/N)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_schedule(hlo_text: str) -> dict:
    """Parse optimized (post-SPMD, per-device) HLO for collectives.

    Returns {kind: {"count": int, "bytes": int}} plus "total_bytes" using
    the wire model above.  ``-done`` ops are skipped (their ``-start``
    carries the shape); reduce-scatter wire bytes use operand size =
    result * N, approximated by result bytes * wire factor (documented).
    """
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(4)
        type_str = m.group(2) or m.group(3) or ""
        nbytes = _shape_bytes(type_str)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["wire_bytes"] += nbytes * _WIRE_FACTOR[kind]
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_specs) -> dict:
    """Batch-dim-on-data shardings, divisibility aware."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def leaf(spec):
        if spec.ndim == 0:
            return NamedSharding(mesh, P())
        if spec.shape[0] % dp_size == 0 and spec.shape[0] >= dp_size:
            return NamedSharding(mesh, P(dp, *([None] * (spec.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * spec.ndim)))

    return jax.tree.map(leaf, batch_specs)


def decode_state_shardings(mesh: Mesh, state_specs):
    """Generic decode-state policy: dim0 = layer stack (replicated),
    dim1 = batch on data axes if divisible, largest remaining divisible
    dim on "model" (KV caches shard their seq dim; SSM states their
    state dim) — DESIGN.md §6."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    m_size = mesh.shape["model"]

    def leaf(spec):
        nd = spec.ndim
        entries: list = [None] * nd
        if nd >= 2 and spec.shape[1] % dp_size == 0 and spec.shape[1] >= dp_size:
            entries[1] = dp
        if nd >= 3:
            dims = sorted(range(2, nd), key=lambda i: -spec.shape[i])
            for dim in dims:
                if spec.shape[dim] % m_size == 0 and spec.shape[dim] >= m_size:
                    entries[dim] = "model"
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(leaf, state_specs)


# ---------------------------------------------------------------------------
# dry-run per combo
# ---------------------------------------------------------------------------


def build_step(arch: Arch, shape_name: str, mesh: Mesh, *, num_microbatches: int = 16):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    cfg = arch.cfg
    sh = SHAPES[shape_name]
    params_spec = jax.eval_shape(arch.init_params, jax.random.PRNGKey(0))
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if sh.kind == "train":
        # ZeRO/FSDP: when params+adam (16 bytes/param) exceed the HBM
        # budget under pure tensor parallelism, additionally shard the
        # train state over the data axes (weights all-gather per layer,
        # grads reduce-scatter — GSPMD derives both from the specs).
        state_bytes_tp = cfg.param_count() * 16 / mesh.shape["model"]
        if state_bytes_tp > 8e9:
            pspecs = param_pspecs(params_spec, axis_size=mesh.shape["model"],
                                  fsdp_axes=dp, fsdp_size=dp_size)
        else:
            pspecs = param_pspecs(params_spec, axis_size=mesh.shape["model"])
    else:
        # serving: bf16 weights + FSDP over the data axes (weights
        # all-gather on use; the data axis otherwise only carries batch)
        params_spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
            ),
            params_spec,
        )
        pspecs = param_pspecs(
            params_spec, axis_size=mesh.shape["model"],
            fsdp_axes=dp, fsdp_size=dp_size,
        )
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    batch_specs = arch.input_specs(shape_name)
    batch_sh = batch_shardings(mesh, batch_specs)

    if sh.kind == "train":
        # rows per microbatch must stay divisible by the data-axis size
        mb = min(num_microbatches, max(sh.global_batch // dp_size, 1))
        while sh.global_batch % mb:
            mb //= 2
        step = make_train_step(arch.loss_fn, num_microbatches=mb, lr=1e-4,
                               data_axes=dp)
        state_spec = jax.eval_shape(init_train_state, params_spec)
        state_sh = jax.tree.map(
            lambda leaf_spec: None, state_spec)  # placeholder, built below
        state_sh = {
            "params": param_sh, "m": param_sh, "v": param_sh,
            "step": NamedSharding(mesh, P()),
        }
        from repro.arch.common import TrainState

        state_sharding = TrainState(
            params=param_sh, m=param_sh, v=param_sh, step=NamedSharding(mesh, P())
        )
        fn = jax.jit(step, in_shardings=(state_sharding, batch_sh), donate_argnums=0)
        return fn, (state_spec, batch_specs)

    if sh.kind == "prefill":
        fn = jax.jit(arch.prefill_fn, in_shardings=(param_sh, batch_sh))
        return fn, (params_spec, batch_specs)

    # decode
    state_specs = jax.eval_shape(
        lambda p: arch.init_decode_state(p, sh.global_batch, sh.seq_len), params_spec
    )
    state_sh = decode_state_shardings(mesh, state_specs)
    fn = jax.jit(arch.decode_fn, in_shardings=(param_sh, state_sh, batch_sh),
                 donate_argnums=1)
    return fn, (params_spec, state_specs, batch_specs)


def dryrun_one(arch_name: str, shape_name: str, *, multi_pod: bool = False,
               num_microbatches: int = 16, save: bool = True, verbose: bool = True) -> dict:
    cfg = get_arch_config(arch_name)
    arch = build_arch(cfg)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "family": cfg.family, "status": "skipped",
    }
    if not arch.supports(shape_name):
        rec["reason"] = "full-attention arch; long_500k requires sub-quadratic attention (DESIGN.md §4)"
        if save:
            _save(rec)
        return rec

    from repro.arch.sharding import activation_policy

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh, activation_policy(data_axes(mesh)):
        fn, args = build_step(arch, shape_name, mesh, num_microbatches=num_microbatches)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    from repro.utils.compat import cost_analysis
    cost = cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = collective_schedule(hlo)
    n_dev = len(mesh.devices.reshape(-1))
    rec.update(
        status="ok",
        devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "total_per_device_bytes": int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
            ),
        },
        raw_cost={  # per-device, while-bodies counted once (see module doc)
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
        },
        collectives=colls,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    if verbose:
        fit = rec["memory"]["total_per_device_bytes"] / 16e9  # v5e 16 GB HBM
        print(
            f"[{arch_name} | {shape_name} | {mesh_name}] OK "
            f"compile={t_compile:.1f}s mem/dev={rec['memory']['total_per_device_bytes']/1e9:.2f}GB "
            f"({fit*100:.0f}% of v5e HBM) collectives={ {k: v['count'] for k, v in colls.items() if isinstance(v, dict)} }"
        )
    if save:
        _save(rec)
    return rec


def _save(rec: dict):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (OUT_DIR / name).write_text(json.dumps(rec, indent=2))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None], help="input shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=16)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else [a for a in list_archs() if a != "glucose-lstm"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    dryrun_one(arch, shape, multi_pod=mp, num_microbatches=args.microbatches)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"[{arch} | {shape} | multi_pod={mp}] FAILED: {type(e).__name__}: {e}")
                    failures.append((arch, shape, mp, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS OK")


if __name__ == "__main__":
    main()
