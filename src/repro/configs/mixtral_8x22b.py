"""Mixtral-8x22B (sparse MoE, 8 experts top-2, SWA).

[arXiv:2401.04088] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2.  Sliding window per the Mixtral model
card -> long_500k runs.  Expert count (8) < model axis (16): expert
weights shard their hidden dim; granite (32e) shards the expert dim.
"""
from repro.config import ArchConfig, register_arch


@register_arch("mixtral-8x22b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b",
        family="moe",
        citation="arXiv:2401.04088",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        num_experts=8,
        experts_per_token=2,
        sliding_window=4096,
        rope_theta=1e6,
    )
