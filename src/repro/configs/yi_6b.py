"""Yi-6B (dense, llama-architecture GQA).

[arXiv:2403.04652] 32L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000.  Full attention: long_500k SKIPPED.
"""
from repro.config import ArchConfig, register_arch


@register_arch("yi-6b")
def config() -> ArchConfig:
    return ArchConfig(
        name="yi-6b",
        family="dense",
        citation="arXiv:2403.04652",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5e6,
    )
