"""Granite-3.0-1B-A400M (fine-grained MoE: 32 experts top-8).

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, 32 experts top-8.
Expert dim (32) divides the 16-way model axis -> expert-parallel
sharding.  Full attention: long_500k SKIPPED.
"""
from repro.config import ArchConfig, register_arch


@register_arch("granite-moe-1b-a400m")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        num_experts=32,
        experts_per_token=8,
    )
