"""Importing this package populates the architecture registry: one
module per assigned architecture, each registering its
:class:`repro.config.ArchConfig` under the id ``--arch`` accepts."""
from repro.configs import (  # noqa: F401
    glucose_lstm,
    mistral_large_123b,
    llava_next_mistral_7b,
    yi_34b,
    mixtral_8x22b,
    qwen2_5_3b,
    mamba2_370m,
    recurrentgemma_9b,
    whisper_medium,
    yi_6b,
    granite_moe_1b_a400m,
)
