"""The paper's own model: single-layer LSTM glucose predictor.

Not part of the assigned-architecture pool; registered so the launcher
can select the paper's experiment with ``--arch glucose-lstm``.
[GluADFL paper, §3.2; hidden sweep {128, 256, 512}]
"""
from repro.config import ArchConfig, register_arch


@register_arch("glucose-lstm")
def config() -> ArchConfig:
    return ArchConfig(
        name="glucose-lstm",
        family="lstm",
        citation="GluADFL (Piao et al., 2024), §3.2",
        num_layers=1,
        d_model=128,       # LSTM hidden size (paper's best-performing 128)
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=0,
        dtype="float32",
    )
