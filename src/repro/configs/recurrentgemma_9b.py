"""RecurrentGemma-9B (hybrid: RG-LRU + local attention, 1 attn : 2 rec).

[arXiv:2402.19427] 38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288
vocab=256000, local attention window 2048.  38 layers / pattern length
3 -> 13 super-blocks = 39 effective layers (DESIGN.md §4 note).
Recurrent state + ring local-attn cache -> long_500k runs.
"""
from repro.config import ArchConfig, register_arch


@register_arch("recurrentgemma-9b")
def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        citation="arXiv:2402.19427",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn"),
        lru_width=4096,
        local_attn_window=2048,
    )
