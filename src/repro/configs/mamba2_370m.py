"""Mamba2-370m (attention-free SSM, SSD / state-space duality).

[arXiv:2405.21060] 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128.  d_inner = 2*d = 2048, 32 heads of headdim 64, 1 B/C
group.  O(1) decode state -> long_500k runs.
"""
from repro.config import ArchConfig, register_arch


@register_arch("mamba2-370m")
def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        family="ssm",
        citation="arXiv:2405.21060",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_heads=32,
        ssm_expand=2,
        ssm_chunk=64,
    )
