"""LLaVA-NeXT (Mistral-7B backbone) — VLM family.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000.  The anyres-tiling ViT/CLIP vision encoder +
projector frontend is a STUB: input_specs supplies patch embeddings
(vision_tokens=2048 anyres tokens).  Mistral backbone sliding window
(4096) -> long_500k runs.
"""
from repro.config import ArchConfig, register_arch


@register_arch("llava-next-mistral-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        sliding_window=4096,
        vision_tokens=2048,
        rope_theta=1e6,
    )
