"""Mistral-Large-Instruct-2407 (123B dense GQA).

[hf:mistralai/Mistral-Large-Instruct-2407] 88L d_model=12288 96H
(GQA kv=8) d_ff=28672 vocab=32768.  Sliding-window variant (w=4096,
Mistral-family signature mechanism) enables the long_500k shape.
"""
from repro.config import ArchConfig, register_arch


@register_arch("mistral-large-123b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b",
        family="dense",
        citation="hf:mistralai/Mistral-Large-Instruct-2407",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        sliding_window=4096,
        rope_theta=1e6,
    )
