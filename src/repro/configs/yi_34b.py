"""Yi-34B (dense, llama-architecture GQA).

[arXiv:2403.04652] 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.  Pure full attention: long_500k SKIPPED (DESIGN.md §4).
"""
from repro.config import ArchConfig, register_arch


@register_arch("yi-34b")
def config() -> ArchConfig:
    return ArchConfig(
        name="yi-34b",
        family="dense",
        citation="arXiv:2403.04652",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5e6,
    )
