"""Qwen2.5-3B (dense GQA with QKV bias).

[hf:Qwen/Qwen2.5-0.5B family card] 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936, QKV bias.  Full attention: long_500k SKIPPED.
"""
from repro.config import ArchConfig, register_arch


@register_arch("qwen2.5-3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-3b",
        family="dense",
        citation="hf:Qwen/Qwen2.5-0.5B (2.5 family)",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        attn_bias=True,
        rope_theta=1e6,
    )
