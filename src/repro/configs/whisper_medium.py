"""Whisper-medium (audio encoder-decoder).

[arXiv:2212.04356] 24+24L d_model=1024 16H (MHA) d_ff=4096 vocab=51865.
Mel-spectrogram + conv frontend is a STUB: input_specs supplies
precomputed frame embeddings (B, 1500, d).  Full-attention decoder:
long_500k SKIPPED (DESIGN.md §4).
"""
from repro.config import ArchConfig, register_arch


@register_arch("whisper-medium")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium",
        family="encdec",
        citation="arXiv:2212.04356",
        num_layers=24,
        encoder_layers=24,
        encoder_seq=1500,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        tie_embeddings=True,
    )
