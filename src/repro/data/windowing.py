"""Windowing + normalization (paper §4.1 / §4.4).

  * split each patient's series 60/20/20 by time (train/val/test),
  * z-score with the TRAIN-split mean/SD of the patient's dataset,
  * missing values (NaN) -> 0 AFTER normalization (paper: "all missing
    values are replaced with zero"),
  * sliding windows of length L=12 predicting the sample H=6 ahead;
    windows whose TARGET is missing are dropped (targets must be real),
    windows with missing history are kept (zeros), matching the paper's
    zero-imputation policy.
"""
from __future__ import annotations

import numpy as np


def split_by_time(series: np.ndarray, fracs=(0.6, 0.2, 0.2)) -> tuple[np.ndarray, ...]:
    n = len(series)
    a = int(n * fracs[0])
    b = int(n * (fracs[0] + fracs[1]))
    return series[:a], series[a:b], series[b:]


def zscore_stats(train_parts: list[np.ndarray]) -> tuple[float, float]:
    """Dataset-level mean/SD over all patients' train splits (NaN-aware)."""
    cat = np.concatenate(train_parts)
    mean = float(np.nanmean(cat))
    sd = float(np.nanstd(cat))
    return mean, max(sd, 1e-6)


def normalize(series: np.ndarray, mean: float, sd: float) -> np.ndarray:
    out = (series - mean) / sd
    return np.nan_to_num(out, nan=0.0)


def make_windows(
    norm_series: np.ndarray,
    raw_series: np.ndarray,
    history_len: int = 12,
    horizon: int = 6,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Return (X, y_norm, y_raw): X is (M, L), targets are (M,).

    ``raw_series`` (mg/dL, with NaNs) decides target validity and supplies
    raw-unit targets for the clinical metrics.
    """
    L, H = history_len, horizon
    n = len(norm_series)
    m = n - L - H + 1
    if m <= 0:
        z = np.zeros((0,), np.float32)
        return np.zeros((0, L), np.float32), z, z
    idx = np.arange(m)[:, None] + np.arange(L)[None, :]
    X = norm_series[idx]
    tgt_pos = np.arange(m) + L + H - 1
    y_norm = norm_series[tgt_pos]
    y_raw = raw_series[tgt_pos]
    valid = ~np.isnan(y_raw)
    return (
        X[valid].astype(np.float32),
        y_norm[valid].astype(np.float32),
        y_raw[valid].astype(np.float32),
    )
