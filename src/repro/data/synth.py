"""Synthetic CGM generator — calibrated stand-ins for the four clinical
datasets (OhioT1DM, ABC4D, CTR3, REPLACE-BG).

The real datasets are access-controlled (see DESIGN.md §5).  The generator
reproduces the population statistics the paper reports in Table 1:

  dataset      N    days  records/patient  mean(SD) mg/dL   SD(SD) mg/dL
  ohiot1dm     12     54     ~13871         159.35(16.34)    58.11(6.15)
  abc4d        25    168     ~43259         156.66(24.24)    60.52(14.47)
  ctr3         30    163     ~43421         151.37(13.34)    55.29(8.24)
  replace-bg  226    251     ~66153         160.69(21.18)    60.33(11.65)

Mechanism per patient (5-minute sampling):
  * circadian baseline (24h + 12h sinusoids, patient-specific phase),
  * 3±1 meals/day -> glucose response bumps (gamma-like rise/decay),
  * insulin-like corrective decay pulling toward the patient's basal,
  * AR(1) sensor noise,
  * dataset-specific variability scale (ABC4D largest: pen therapy),
  * clipping to the CGM range [40, 400] mg/dL,
  * missing samples (sensor dropouts) as NaN with dataset-specific rate.

Everything is vectorized numpy (host-side data pipeline, as a real input
pipeline would be) and deterministic given (dataset, patient id, seed).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

SAMPLES_PER_DAY = 288  # 5-minute CGM sampling


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_patients: int
    num_days: int
    mean_bg: float          # population mean of per-patient means
    mean_bg_sd: float       # SD across patients of per-patient means
    sd_bg: float            # population mean of per-patient SDs
    sd_bg_sd: float         # SD across patients of per-patient SDs
    missing_rate: float
    meal_irregularity: float  # ABC4D (pen) > pump datasets
    seed_base: int


DATASET_SPECS: dict[str, DatasetSpec] = {
    "ohiot1dm": DatasetSpec("ohiot1dm", 12, 54, 159.35, 16.34, 58.11, 6.15, 0.04, 0.6, 101),
    "abc4d": DatasetSpec("abc4d", 25, 168, 156.66, 24.24, 60.52, 14.47, 0.05, 1.0, 202),
    "ctr3": DatasetSpec("ctr3", 30, 163, 151.37, 13.34, 55.29, 8.24, 0.03, 0.5, 303),
    "replace-bg": DatasetSpec("replace-bg", 226, 251, 160.69, 21.18, 60.33, 11.65, 0.04, 0.7, 404),
}

# Smoke-scale day counts so tests don't generate 251-day series.
_FAST_DAYS = 6


def node_skew_offsets(num_nodes: int) -> np.ndarray:
    """Deterministic centered per-node offsets in ``[-1, 1]``, float32.

    The non-IID skew axis shifts node ``i``'s glucose distribution by
    ``skew * offsets[i]``: node 0 sits at ``-skew``, the last node at
    ``+skew``, the population mean shift is exactly zero.  Both the
    sweep engine (batch-level shift inside ``_local_step``) and the
    generator-level skew (:func:`generate_dataset`) use this table, so
    a swept scenario's serial twin is a plain ``train()`` on
    pre-shifted host arrays."""
    if num_nodes <= 1:
        return np.zeros((num_nodes,), np.float32)
    i = np.arange(num_nodes, dtype=np.float32)
    return (2.0 * i - (num_nodes - 1)) / np.float32(num_nodes - 1)


def generate_patient_series(
    spec: DatasetSpec,
    patient: int,
    *,
    days: int | None = None,
    seed: int = 0,
    mean_shift: float = 0.0,
) -> np.ndarray:
    """One patient's CGM trace in mg/dL, shape (days*288,), NaN = missing.

    ``mean_shift`` moves the patient's basal level AFTER all RNG draws
    (no stream is consumed), so ``mean_shift=0.0`` is bitwise-identical
    to the unshifted series."""
    days = spec.num_days if days is None else days
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed_base, patient, seed]))
    n = days * SAMPLES_PER_DAY
    t = np.arange(n) / SAMPLES_PER_DAY  # in days

    # patient-specific latent parameters
    basal = rng.normal(spec.mean_bg, spec.mean_bg_sd) + mean_shift
    target_sd = max(20.0, rng.normal(spec.sd_bg, spec.sd_bg_sd))
    phase = rng.uniform(0, 2 * np.pi)
    circ_amp = rng.uniform(5.0, 15.0)

    g = basal + circ_amp * np.sin(2 * np.pi * t + phase) + 0.4 * circ_amp * np.sin(
        4 * np.pi * t + 1.7 * phase
    )

    # meals: ~3 per day with patient/day jitter; gamma-shaped BG response
    resp_len = 48  # 4 hours of response kernel
    k = np.arange(resp_len, dtype=np.float64)
    rise, decay = 5.0, 14.0
    kernel = (k / rise) ** 2 * np.exp(-k / decay)
    kernel /= kernel.max()
    impulses = np.zeros(n)
    for day in range(days):
        n_meals = max(1, rng.poisson(3))
        if spec.meal_irregularity > 0.8:
            base_times = rng.uniform(0, 1, size=n_meals)
        elif n_meals <= 3:
            # the 3-slot template, jittered: min(n_meals, 3) jitter draws
            base_times = np.array([0.3, 0.55, 0.8])[:n_meals] + rng.normal(
                0, 0.03 * spec.meal_irregularity, size=min(n_meals, 3)
            )
        else:
            base_times = rng.uniform(0.2, 0.9, size=n_meals) + rng.normal(
                0, 0.03 * spec.meal_irregularity, size=n_meals
            )
        assert base_times.shape == (n_meals,), (base_times.shape, n_meals)
        for bt in np.atleast_1d(base_times):
            idx = int((day + float(np.clip(bt, 0, 0.999))) * SAMPLES_PER_DAY)
            amp = rng.gamma(4.0, 20.0) * (0.7 + 0.6 * spec.meal_irregularity)
            impulses[idx] += amp
    meal_bg = np.convolve(impulses, kernel)[:n]

    # insulin-like correction: first-order pull toward basal (stronger for pumps)
    alpha = 0.015 * (1.5 - 0.5 * spec.meal_irregularity)
    corrected = np.empty(n)
    level = 0.0
    excess = meal_bg
    for i in range(n):
        level = level * (1 - alpha) + excess[i] * alpha * 2.2
        corrected[i] = excess[i] - min(level, excess[i] * 0.8)
    g = g + corrected

    # AR(1) sensor/physiology noise
    eps = rng.normal(0, 1, n)
    ar = np.empty(n)
    acc = 0.0
    rho = 0.92
    for i in range(n):
        acc = rho * acc + eps[i]
        ar[i] = acc
    ar *= np.sqrt(1 - rho**2)
    g = g + ar * 12.0

    # rescale to hit the patient's target SD, keep mean
    cur_sd = g.std()
    g = (g - g.mean()) * (target_sd / max(cur_sd, 1e-6)) + basal
    g = np.clip(g, 40.0, 400.0)

    # sensor dropouts: contiguous gaps
    miss = rng.uniform(0, 1, n) < spec.missing_rate / 6
    gap_len = 6
    missing_mask = np.convolve(miss.astype(float), np.ones(gap_len))[:n] > 0
    g[missing_mask] = np.nan
    return g.astype(np.float32)


def generate_dataset(
    name: str,
    *,
    fast: bool = False,
    max_patients: int | None = None,
    seed: int = 0,
    skew: float = 0.0,
) -> list[np.ndarray]:
    """All patients' traces for a dataset.  ``fast`` shortens to 6 days.

    ``skew`` introduces a non-IID per-patient distribution shift:
    patient ``p`` is generated with
    ``mean_shift = skew * mean_bg_sd * node_skew_offsets(n)[p]``.
    ``skew=0.0`` is bitwise-identical to the unskewed dataset (the
    shift is applied after all RNG draws)."""
    spec = DATASET_SPECS[name]
    days = _FAST_DAYS if fast else spec.num_days
    n_pat = spec.num_patients if max_patients is None else min(max_patients, spec.num_patients)
    shifts = float(skew) * spec.mean_bg_sd * node_skew_offsets(n_pat)
    return [
        generate_patient_series(spec, p, days=days, seed=seed, mean_shift=float(shifts[p]))
        for p in range(n_pat)
    ]
