"""CGM data layer: synthetic twins of the paper's four datasets
(OhioT1DM / ABC4D / CTR3 / REPLACE-BG), sliding-window featurization
(L=12 history -> H=6 horizon), per-patient normalization, and the
federated loader that stacks patients into padded ``(N, m, L)`` node
arrays (``load_federated_dataset``)."""
from repro.data.synth import DATASET_SPECS, generate_patient_series, generate_dataset
from repro.data.windowing import make_windows, split_by_time, zscore_stats, normalize
from repro.data.pipeline import PatientData, FederatedData, load_federated_dataset, batch_iterator
