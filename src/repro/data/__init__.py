from repro.data.synth import DATASET_SPECS, generate_patient_series, generate_dataset
from repro.data.windowing import make_windows, split_by_time, zscore_stats, normalize
from repro.data.pipeline import PatientData, FederatedData, load_federated_dataset, batch_iterator
