"""Federated data pipeline.

Loads a dataset's synthetic twins, splits/normalizes/windows them, and
packs each patient's windows into fixed-size padded arrays so the whole
federation can be stacked into (N, M, L) tensors and sharded/vmapped.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.synth import DATASET_SPECS, generate_dataset
from repro.data.windowing import make_windows, normalize, split_by_time, zscore_stats


@dataclass
class PatientData:
    """Windowed data for one patient (one federated node)."""

    train_x: np.ndarray  # (Mtr, L)
    train_y: np.ndarray  # (Mtr,)
    val_x: np.ndarray
    val_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    test_y_raw: np.ndarray  # mg/dL targets for clinical metrics
    mean: float
    sd: float


@dataclass
class FederatedData:
    """Whole-federation stacked arrays (node axis first, padded)."""

    name: str
    patients: list[PatientData]
    # stacked + padded for vmapped federated training:
    x: np.ndarray      # (N, M, L) float32
    y: np.ndarray      # (N, M)
    counts: np.ndarray  # (N,) true number of windows per node
    mean: float
    sd: float

    @property
    def num_nodes(self) -> int:
        return len(self.patients)


def load_federated_dataset(
    name: str,
    *,
    history_len: int = 12,
    horizon: int = 6,
    fast: bool = False,
    max_patients: int | None = None,
    seed: int = 0,
) -> FederatedData:
    raw = generate_dataset(name, fast=fast, max_patients=max_patients, seed=seed)
    splits = [split_by_time(s) for s in raw]
    mean, sd = zscore_stats([tr for tr, _, _ in splits])

    patients: list[PatientData] = []
    for tr, va, te in splits:
        ntr = normalize(tr, mean, sd)
        nva = normalize(va, mean, sd)
        nte = normalize(te, mean, sd)
        xtr, ytr, _ = make_windows(ntr, tr, history_len, horizon)
        xva, yva, _ = make_windows(nva, va, history_len, horizon)
        xte, yte, yte_raw = make_windows(nte, te, history_len, horizon)
        patients.append(
            PatientData(xtr, ytr, xva, yva, xte, yte, yte_raw, mean, sd)
        )

    # pad node window counts to the max so the federation stacks
    m = max(p.train_x.shape[0] for p in patients)
    L = history_len
    N = len(patients)
    x = np.zeros((N, m, L), np.float32)
    y = np.zeros((N, m), np.float32)
    counts = np.zeros((N,), np.int32)
    for i, p in enumerate(patients):
        k = p.train_x.shape[0]
        x[i, :k] = p.train_x
        y[i, :k] = p.train_y
        counts[i] = k
    return FederatedData(name, patients, x, y, counts, mean, sd)


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
    """Shuffled epoch iterator over (x, y)."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i : i + batch_size]
            yield x[sel], y[sel]


def denormalize(y_norm: np.ndarray, mean: float, sd: float) -> np.ndarray:
    return y_norm * sd + mean
