"""Optimizers from scratch (no optax): functional (init, update) pairs.

``update(grads, state, params) -> (new_params, new_state)``.  All states
are pytrees so they stack/vmap/shard exactly like parameters — required
for the federated simulation where every node carries its own state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray], momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params):
        step = state["step"] + 1
        cur_lr = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            new_params = jax.tree.map(lambda p, m: p - cur_lr * m, params, mu)
            return new_params, {"step": step, "mu": mu}
        new_params = jax.tree.map(lambda p, g: p - cur_lr * g, params, grads)
        return new_params, {"step": step, "mu": None}

    return Optimizer(init, update)


def adam(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        cur_lr = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p
            return p - cur_lr * step_

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: float | Callable = 1e-3, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adam":
        return adam(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
