"""Minimal pure-pytree optimizers (SGD / Adam / AdamW) and LR schedules;
``Optimizer.init``/``update`` state threads through the FL engines'
vmapped local steps."""
from repro.optim.optimizers import Optimizer, sgd, adam, adamw, get_optimizer
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
