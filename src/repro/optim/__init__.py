from repro.optim.optimizers import Optimizer, sgd, adam, adamw, get_optimizer
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
