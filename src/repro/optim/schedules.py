"""Learning-rate schedules (callables of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * ((1 - alpha) * cos + alpha)

    return fn


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int, alpha: float = 0.0):
    cos = cosine_decay(lr, max(decay_steps - warmup_steps, 1), alpha)

    def fn(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step_f < warmup_steps, warm, cos(step - warmup_steps))

    return fn
