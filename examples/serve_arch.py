"""Serve a (reduced) assigned architecture with batched greedy decoding
through the KV-cache/state path — the same code the decode_32k and
long_500k dry-runs lower at production shape.

    PYTHONPATH=src python examples/serve_arch.py --arch mamba2-370m
"""
import sys

from repro.launch.arch_demo import main

if __name__ == "__main__":
    main()
