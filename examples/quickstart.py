"""Quickstart: train a GluADFL population model on a synthetic OhioT1DM
twin and cross-predict an unseen patient, in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import GluADFL
from repro.data import load_federated_dataset
from repro.metrics import all_metrics
from repro.models import LSTMModel
from repro.optim import adam

# 1. data: 12 synthetic T1D patients (the OhioT1DM twin), windows of
#    L=12 CGM samples predicting H=6 steps (30 min) ahead
fed = load_federated_dataset("ohiot1dm", fast=True)
print(f"{fed.num_nodes} patients, ~{int(fed.counts.mean())} training windows each")

# 2. hold out patient 11 as UNSEEN (cold start) — only 0..10 train
seen_x, seen_y, seen_counts = fed.x[:11], fed.y[:11], fed.counts[:11]

# 3. GluADFL: asynchronous decentralized FL over a random topology
model = LSTMModel(hidden=64).as_model()
cfg = FLConfig(topology="random", num_nodes=11, comm_batch=7,
               rounds=100, inactive_ratio=0.3)
trainer = GluADFL(model, adam(2e-3), cfg)
population, history, _ = trainer.train(
    jax.random.PRNGKey(0), seen_x, seen_y, seen_counts, batch_size=64
)
print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
      f"over {cfg.rounds} rounds (30% of nodes inactive per round)")

# 4. cross-predict the unseen patient with the population model
unseen = fed.patients[11]
pred = np.asarray(model.apply(population, jnp.asarray(unseen.test_x)))
pred_mgdl = pred * fed.sd + fed.mean
metrics = all_metrics(unseen.test_y_raw, pred_mgdl)
print("UNSEEN patient metrics:", {k: round(v, 2) for k, v in metrics.items()})
