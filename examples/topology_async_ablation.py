"""Topology + asynchrony ablation (paper Figs 4-5 in miniature):
convergence of ring/cluster/random gossip, then robustness as the
inactive-node ratio rises; also prints each topology's spectral gap —
the mixing-rate statistic that explains the ordering.

    PYTHONPATH=src python examples/topology_async_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import GluADFL, mixing_matrix, round_adjacency, spectral_gap
from repro.data import load_federated_dataset
from repro.models import LSTMModel
from repro.optim import adam

fed = load_federated_dataset("ohiot1dm", fast=True)
model = LSTMModel(hidden=64).as_model()
vx = jnp.asarray(np.concatenate([p.val_x for p in fed.patients]))
vy = np.concatenate([p.val_y * fed.sd + fed.mean for p in fed.patients])

print("spectral gaps (higher = faster gossip mixing):")
ones = jnp.ones((fed.num_nodes,))
for topo in ("ring", "cluster", "random"):
    adj = round_adjacency(topo, fed.num_nodes, jax.random.PRNGKey(0), 7)
    print(f"  {topo:8s} {spectral_gap(mixing_matrix(adj, ones, 7)):.4f}")

for inactive in (0.0, 0.5, 0.8):
    print(f"\ninactive ratio {inactive:.0%}:")
    for topo in ("ring", "cluster", "random"):
        cfg = FLConfig(topology=topo, num_nodes=fed.num_nodes, comm_batch=7,
                       rounds=80, inactive_ratio=inactive)
        tr = GluADFL(model, adam(2e-3), cfg)
        pop, hist, _ = tr.train(jax.random.PRNGKey(1), fed.x, fed.y,
                                fed.counts, batch_size=64)
        pred = np.asarray(model.apply(pop, vx)) * fed.sd + fed.mean
        rmse = float(np.sqrt(np.mean((pred - vy) ** 2)))
        print(f"  {topo:8s} val RMSE {rmse:6.2f}")
