"""Topology + asynchrony ablation (paper Figs 4-5 in miniature):
convergence of ring/cluster/random gossip as the inactive-node ratio
rises — the WHOLE 3x3 grid trained as ONE batched device program via
``GluADFL.train_sweep`` (stacked per-scenario adjacency + vmapped chunk
scan) — plus each topology's spectral gap, the mixing-rate statistic
that explains the ordering.

    PYTHONPATH=src python examples/topology_async_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import GluADFL, SweepGrid, mixing_matrix, round_adjacency, spectral_gap
from repro.data import load_federated_dataset
from repro.models import LSTMModel
from repro.optim import adam
from repro.utils.pytree import tree_index

fed = load_federated_dataset("ohiot1dm", fast=True)
model = LSTMModel(hidden=64).as_model()
vx = jnp.asarray(np.concatenate([p.val_x for p in fed.patients]))
vy = np.concatenate([p.val_y * fed.sd + fed.mean for p in fed.patients])

TOPOLOGIES = ("ring", "cluster", "random")
RATIOS = (0.0, 0.5, 0.8)

print("spectral gaps (higher = faster gossip mixing):")
ones = jnp.ones((fed.num_nodes,))
for topo in TOPOLOGIES:
    adj = round_adjacency(topo, fed.num_nodes, jax.random.PRNGKey(0), 7)
    print(f"  {topo:8s} {spectral_gap(mixing_matrix(adj, ones, 7)):.4f}")

# all 9 (topology, inactive-ratio) scenarios compile and run as a single
# vmapped scan — one seed key per scenario, federation data broadcast
grid = SweepGrid.build(TOPOLOGIES, RATIOS, seeds=(1,), num_nodes=fed.num_nodes)
cfg = FLConfig(num_nodes=fed.num_nodes, comm_batch=7, rounds=80)
trainer = GluADFL(model, adam(2e-3), cfg)
pops, hists, _ = trainer.train_sweep(fed.x, fed.y, fed.counts, grid=grid,
                                     batch_size=64)

rmse = {}
for g, (topo, ratio, _) in enumerate(grid.labels):
    pred = np.asarray(model.apply(tree_index(pops, g), vx)) * fed.sd + fed.mean
    rmse[(topo, ratio)] = float(np.sqrt(np.mean((pred - vy) ** 2)))

for ratio in RATIOS:
    print(f"\ninactive ratio {ratio:.0%}:")
    for topo in TOPOLOGIES:
        print(f"  {topo:8s} val RMSE {rmse[(topo, ratio)]:6.2f}")
