"""End-to-end driver (deliverable b): the paper's core experiment —
train population models on one dataset via GluADFL for a few hundred
rounds, evaluate seen + cross-dataset unseen patients, compare against
FedAvg and centralized supervised learning, then personalize.

    PYTHONPATH=src python examples/cross_patient.py [--rounds 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import FedAvg, GluADFL, personalize, train_supervised
from repro.data import load_federated_dataset
from repro.metrics import all_metrics
from repro.models import LSTMModel
from repro.optim import adam

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=300)
ap.add_argument("--train-dataset", default="ctr3")
ap.add_argument("--unseen-dataset", default="abc4d")
args = ap.parse_args()

train_fed = load_federated_dataset(args.train_dataset, fast=True)
unseen_fed = load_federated_dataset(args.unseen_dataset, fast=True, max_patients=8)
model = LSTMModel(hidden=128).as_model()


def eval_on(params, fed):
    preds, ys = [], []
    for p in fed.patients:
        pr = np.asarray(model.apply(params, jnp.asarray(p.test_x))) * fed.sd + fed.mean
        preds.append(pr)
        ys.append(p.test_y_raw)
    return all_metrics(np.concatenate(ys), np.concatenate(preds))


# --- GluADFL (the paper's method) -------------------------------------
cfg = FLConfig(topology="random", num_nodes=train_fed.num_nodes,
               comm_batch=7, rounds=args.rounds)
glu = GluADFL(model, adam(2e-3), cfg)
pop, hist, state = glu.train(jax.random.PRNGKey(0), train_fed.x, train_fed.y,
                             train_fed.counts, batch_size=64)
print(f"[gluadfl ] seen {eval_on(pop, train_fed)['rmse']:.2f} RMSE | "
      f"unseen {eval_on(pop, unseen_fed)['rmse']:.2f} RMSE")

# --- FedAvg baseline ----------------------------------------------------
fa = FedAvg(model, adam(2e-3), cfg)
fa_params, _ = fa.train(jax.random.PRNGKey(1), train_fed.x, train_fed.y,
                        train_fed.counts, batch_size=64, rounds=args.rounds // 2)
print(f"[fedavg  ] seen {eval_on(fa_params, train_fed)['rmse']:.2f} RMSE | "
      f"unseen {eval_on(fa_params, unseen_fed)['rmse']:.2f} RMSE")

# --- centralized supervised (privacy-free upper bound) ------------------
x = np.concatenate([p.train_x for p in train_fed.patients])
y = np.concatenate([p.train_y for p in train_fed.patients])
sup, _ = train_supervised(model, adam(2e-3), jax.random.PRNGKey(2), x, y,
                          steps=args.rounds * 2, batch_size=64)
print(f"[mixed   ] seen {eval_on(sup, train_fed)['rmse']:.2f} RMSE | "
      f"unseen {eval_on(sup, unseen_fed)['rmse']:.2f} RMSE")

# --- personalized-from-population (paper Fig 3) --------------------------
p0 = train_fed.patients[0]
pers = personalize(model, adam(5e-4), pop, jax.random.PRNGKey(3),
                   p0.train_x, p0.train_y, steps=100)
pop_m = all_metrics(p0.test_y_raw,
                    np.asarray(model.apply(pop, jnp.asarray(p0.test_x))) * train_fed.sd + train_fed.mean)
per_m = all_metrics(p0.test_y_raw,
                    np.asarray(model.apply(pers, jnp.asarray(p0.test_x))) * train_fed.sd + train_fed.mean)
print(f"[patient0] population {pop_m['rmse']:.2f} RMSE -> "
      f"personalized-from-population {per_m['rmse']:.2f} RMSE")
