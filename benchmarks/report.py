"""Render the dry-run + roofline JSON records into the EXPERIMENTS.md
markdown tables.

    PYTHONPATH=src python -m benchmarks.report [--write]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

ARCH_ORDER = [
    "mistral-large-123b", "llava-next-mistral-7b", "yi-34b", "mixtral-8x22b",
    "qwen2.5-3b", "mamba2-370m", "recurrentgemma-9b", "whisper-medium",
    "yi-6b", "granite-moe-1b-a400m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(dirname: str) -> dict:
    out = {}
    d = ROOT / "experiments" / dirname
    if not d.exists():
        return out
    for f in d.glob("*.json"):
        r = json.loads(f.read_text())
        if "shape" in r:
            out[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return out


def dryrun_table() -> str:
    recs = _load("dryrun")
    lines = [
        "| arch | shape | mesh | status | compile s | mem/dev GB | HBM % | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("pod16x16", "pod2x16x16"):
                r = recs.get((a, s, m))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {a} | {s} | {m} | SKIP (full attention) | — | — | — | — |")
                    continue
                mem = r["memory"]["total_per_device_bytes"]
                colls = ", ".join(
                    f"{k}:{v['count']}" for k, v in r["collectives"].items()
                    if isinstance(v, dict)
                )
                lines.append(
                    f"| {a} | {s} | {m} | ok | {r['compile_s']:.1f} | "
                    f"{mem/1e9:.2f} | {mem/16e9*100:.0f}% | {colls} |"
                )
    return "\n".join(lines)


def roofline_table(dirname: str) -> str:
    recs = _load(dirname)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful 6ND/HLO |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "pod16x16"))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | — | — | — | SKIP | — |")
                continue
            t = r["terms_s"]
            lines.append(
                f"| {a} | {s} | {t['compute']:.3f} | {t['memory']:.3f} | "
                f"{t['collective']:.3f} | **{r['dominant']}** | "
                f"{r['useful_flop_ratio']:.2f} |"
            )
    return "\n".join(lines)


def compare_table() -> str:
    base = _load("roofline_baseline")
    opt = _load("roofline")
    lines = [
        "| arch | shape | baseline bound s | optimized bound s | gain |",
        "|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b = base.get((a, s, "pod16x16"))
            o = opt.get((a, s, "pod16x16"))
            if not b or not o or b["status"] != "ok" or o["status"] != "ok":
                continue
            bb = max(b["terms_s"].values())
            ob = max(o["terms_s"].values())
            lines.append(
                f"| {a} | {s} | {bb:.2f} | {ob:.2f} | {bb/ob:.2f}x |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "baseline", "compare"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("### Dry-run (80 records)\n")
        print(dryrun_table())
    if args.section in ("all", "baseline"):
        print("\n### Roofline — paper-faithful baseline (single-pod)\n")
        print(roofline_table("roofline_baseline"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline — optimized (attention-pinned)\n")
        print(roofline_table("roofline"))
    if args.section in ("all", "compare"):
        print("\n### Baseline vs optimized\n")
        print(compare_table())


if __name__ == "__main__":
    main()
