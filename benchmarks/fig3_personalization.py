"""Figure 3: 'Personalized Model' (from scratch) vs 'Population Model'
(GluADFL Random) vs 'Personalized from Population' (fine-tuned), per
dataset, evaluated per seen patient."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, Scale, load, save_json, train_gluadfl
from repro.core import personalize, train_supervised
from repro.metrics import all_metrics
from repro.models import LSTMModel
from repro.optim import adam


def _patient_metrics(model, params, p, fed):
    pred = np.asarray(model.apply(params, jnp.asarray(p.test_x))) * fed.sd + fed.mean
    return all_metrics(p.test_y_raw, pred)


def run(scale: Scale | None = None, datasets=None) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    out = {}
    for ds in datasets:
        model, pop, _, fed = train_gluadfl(ds, scale, topology="random")
        rows = {"personalized": [], "population": [], "pers_from_pop": []}
        for i, p in enumerate(fed.patients):
            key = jax.random.PRNGKey(1000 + i)
            # personalized from scratch
            scratch, _ = train_supervised(
                model, adam(2e-3), key, p.train_x, p.train_y,
                steps=scale.sup_steps // 4, batch_size=32,
            )
            rows["personalized"].append(_patient_metrics(model, scratch, p, fed))
            # population as-is
            rows["population"].append(_patient_metrics(model, pop, p, fed))
            # personalized from population
            pers = personalize(model, adam(5e-4), pop, key, p.train_x, p.train_y,
                               steps=scale.sup_steps // 8)
            rows["pers_from_pop"].append(_patient_metrics(model, pers, p, fed))
        agg = {
            k: {m: float(np.mean([r[m] for r in v])) for m in v[0]}
            for k, v in rows.items()
        }
        out[ds] = agg
        print(
            f"[{ds:11s}] RMSE personalized {agg['personalized']['rmse']:6.2f} | "
            f"population {agg['population']['rmse']:6.2f} | "
            f"pers-from-pop {agg['pers_from_pop']['rmse']:6.2f} "
            f"(paper: pers-from-pop beats personalized by 0.4-0.8 mg/dL)"
        )
    save_json("fig3_personalization", out)
    return out


if __name__ == "__main__":
    run()
