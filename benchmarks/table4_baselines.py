"""Table 4: BG prediction for seen/unseen patients by ALL population
methods: LR, XGBoost-like GBT, LSTM (supervised), N-BEATS, NHiTS, MAML,
MetaSGD, FedAvg, GluADFL(Ring/Cluster/Random).

The trainable baselines (FedAvg, MAML, MetaSGD, LSTM-supervised) run on
the chunked scan engines: :func:`run_baseline_grid` trains the whole
method grid with ``chunk = rounds`` — ONE compiled execution per method,
<= 4 total, counted through ``chunked.dispatch_chunk`` by
``tests/test_baseline_engines.py``."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    DATASETS,
    Scale,
    eval_population,
    load,
    save_json,
    train_fedavg,
    train_gluadfl,
    train_mixed_supervised,
)
from repro.config import FLConfig
from repro.core import FedAvg, MAML, MetaSGD, train_supervised
from repro.data.pipeline import FederatedData
from repro.metrics import all_metrics
from repro.models import GradientBoostedTrees, LinearModel, LSTMModel, NBeatsModel, NHiTSModel
from repro.models.linear import fit_closed_form
from repro.optim import adam


def _eval_gbt(gbt, params, fed: FederatedData) -> dict:
    preds, ys = [], []
    for p in fed.patients:
        if len(p.test_x) == 0:
            continue
        pred = np.asarray(gbt.predict(params, jnp.asarray(p.test_x)))
        preds.append(pred * fed.sd + fed.mean)
        ys.append(p.test_y_raw)
    return all_metrics(np.concatenate(ys), np.concatenate(preds))


def _train_eval_method(method: str, train_ds: str, scale: Scale):
    """Returns eval-fn(test_fed) -> metrics."""
    fed = load(train_ds, scale)
    x = np.concatenate([p.train_x for p in fed.patients])
    y = np.concatenate([p.train_y for p in fed.patients])

    if method == "lr":
        params = fit_closed_form(jnp.asarray(x), jnp.asarray(y))
        model = LinearModel(history_len=12).as_model()
        return lambda te: eval_population(model, params, te)
    if method == "xgboost":
        gbt = GradientBoostedTrees(num_trees=40, depth=4, lr=0.15)
        params = gbt.fit(x, y)
        return lambda te: _eval_gbt(gbt, params, te)
    if method in ("lstm", "nbeats", "nhits"):
        ctor = {
            "lstm": lambda: LSTMModel(hidden=scale.hidden).as_model(),
            "nbeats": lambda: NBeatsModel(hidden=scale.hidden).as_model(),
            "nhits": lambda: NHiTSModel(hidden=scale.hidden).as_model(),
        }[method]
        model, params, _, _ = train_mixed_supervised(train_ds, scale, model_ctor=ctor)
        return lambda te: eval_population(model, params, te)
    if method in ("maml", "metasgd"):
        model = LSTMModel(hidden=scale.hidden).as_model()
        cls = MAML if method == "maml" else MetaSGD
        meta = cls(model, adam(1e-3), inner_lr=1e-2, inner_steps=3)
        params, _, _ = meta.train(
            jax.random.PRNGKey(0), fed.x, fed.y, fed.counts,
            batch_size=scale.batch_size, steps=scale.rounds,
        )
        # paper: evaluated WITHOUT test-time fine-tuning
        return lambda te: eval_population(model, params, te)
    if method == "fedavg":
        model, params, _, _ = train_fedavg(train_ds, scale)
        return lambda te: eval_population(model, params, te)
    if method.startswith("gluadfl"):
        topo = method.split("-")[1]
        model, pop, _, _ = train_gluadfl(train_ds, scale, topology=topo)
        return lambda te: eval_population(model, pop, te)
    raise KeyError(method)


METHODS = [
    "lr", "xgboost", "lstm", "nbeats", "nhits", "maml", "metasgd",
    "fedavg", "gluadfl-ring", "gluadfl-cluster", "gluadfl-random",
]

# The four baselines with a compiled scan engine behind them.
BASELINE_GRID_METHODS = ("fedavg", "maml", "metasgd", "lstm")


def run_baseline_grid(train_ds: str, scale: Scale | None = None,
                      methods=BASELINE_GRID_METHODS, *, engine: str = "scan",
                      seed: int = 0) -> dict:
    """Train the Table-4 trainable-baseline grid on one dataset.

    With ``engine="scan"`` each method runs its whole round budget as a
    single donated chunk (``chunk = rounds``), so the full grid
    dispatches <= len(methods) <= 4 compiled executions through
    ``chunked.dispatch_chunk`` — the budget
    ``tests/test_baseline_engines.py`` pins by monkeypatching the
    chokepoint.  ``engine="loop"`` runs the original per-round jit loops
    (the serial arm of the ``table4-batched`` wall-clock benchmark).

    Returns ``{method: {"model", "params", "history"}}``.
    """
    scale = scale or Scale()
    fed = load(train_ds, scale)
    out: dict = {}
    for method in methods:
        model = LSTMModel(hidden=scale.hidden).as_model()
        if method == "fedavg":
            cfg = FLConfig(num_nodes=fed.num_nodes, rounds=scale.rounds,
                           local_steps=2, seed=seed)
            fa = FedAvg(model, adam(2e-3), cfg)
            params, hist = fa.train(
                jax.random.PRNGKey(seed), fed.x, fed.y, fed.counts,
                batch_size=scale.batch_size, engine=engine, chunk=scale.rounds,
            )
        elif method in ("maml", "metasgd"):
            cls = MAML if method == "maml" else MetaSGD
            meta = cls(model, adam(1e-3), inner_lr=1e-2, inner_steps=3)
            params, _, hist = meta.train(
                jax.random.PRNGKey(seed), fed.x, fed.y, fed.counts,
                batch_size=scale.batch_size, steps=scale.rounds,
                engine=engine, chunk=scale.rounds,
            )
        elif method == "lstm":
            x = np.concatenate([p.train_x for p in fed.patients])
            y = np.concatenate([p.train_y for p in fed.patients])
            params, hist = train_supervised(
                model, adam(2e-3), jax.random.PRNGKey(seed), x, y,
                steps=scale.rounds, batch_size=scale.batch_size,
                engine=engine, chunk=scale.rounds,
            )
        else:
            raise KeyError(method)
        out[method] = {"model": model, "params": params, "history": hist}
    return out


def run(scale: Scale | None = None, datasets=None, methods=None) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    methods = methods or METHODS
    out: dict = {}
    for train_ds in datasets:
        out[train_ds] = {}
        for method in methods:
            ev = _train_eval_method(method, train_ds, scale)
            seen = ev(load(train_ds, scale))
            unseen = [ev(load(d, scale)) for d in datasets if d != train_ds]
            unseen_mean = {
                k: float(np.mean([u[k] for u in unseen])) for k in seen
            } if unseen else {}
            out[train_ds][method] = {"seen": seen, "unseen": unseen_mean}
            print(
                f"[{train_ds:11s}] {method:16s} seen RMSE {seen['rmse']:6.2f} "
                f"gRMSE {seen['grmse']:6.2f} | unseen RMSE "
                f"{unseen_mean.get('rmse', float('nan')):6.2f}"
            )
    save_json("table4_baselines", out)
    return out


if __name__ == "__main__":
    run()
