"""Figure 4: convergence of GluADFL under ring / cluster / random
topologies (B=7), per dataset — validation RMSE vs communication round.

Default path: the whole topology grid runs as ONE batched device program
via ``GluADFL.train_sweep`` (stacked adjacency matrices, vmapped chunk
scan, in-scan streaming eval returning a ``(grid, chunk)`` record
stack).  ``--serial`` (or ``run(serial=True)``) keeps the original
one-config-at-a-time loop as a parity fallback — same numbers, G compiles
and G executions instead of one.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, Scale, load, save_json
from repro.config import FLConfig, SweepConfig
from repro.core import GluADFL, SweepGrid
from repro.models import LSTMModel
from repro.optim import adam

# Fig 4 sweeps the same canonical topology axis as Fig 5
TOPOLOGIES = list(SweepConfig().topologies)


def _val_rmse_fn(model, fed):
    """Traceable (mg/dL) val RMSE: runs INSIDE the scanned chunk via the
    streaming-eval branch — no per-round host sync."""

    def val_rmse(params, val_x, val_y):
        pred = model.apply(params, val_x) * fed.sd + fed.mean
        return {"val_rmse": jnp.sqrt(jnp.mean(jnp.square(pred - val_y)))}

    return val_rmse


def run(scale: Scale | None = None, datasets=None, eval_every: int = 10,
        serial: bool = False) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    out = {}
    for ds in datasets:
        fed = load(ds, scale)
        model = LSTMModel(hidden=scale.hidden).as_model()
        vx = np.concatenate([p.val_x for p in fed.patients])
        vy_raw = np.concatenate([(p.val_y * fed.sd + fed.mean) for p in fed.patients])
        val_rmse = _val_rmse_fn(model, fed)

        out[ds] = {}
        if serial:
            for topo in TOPOLOGIES:
                cfg = FLConfig(topology=topo, num_nodes=fed.num_nodes,
                               comm_batch=7, rounds=scale.rounds)
                tr = GluADFL(model, adam(2e-3), cfg)
                _, hist, _ = tr.train(
                    jax.random.PRNGKey(0), fed.x, fed.y, fed.counts,
                    batch_size=scale.batch_size, eval_every=eval_every,
                    eval_fn=val_rmse, val_data=(vx, vy_raw),
                )
                out[ds][topo] = [(h["round"], h["val_rmse"])
                                 for h in hist if "val_rmse" in h]
        else:
            # the whole topology axis as one vmapped program: the grid's
            # per-scenario (round, val_rmse) curves come back as a
            # (grid, chunk) record stack from the in-scan eval branch
            grid = SweepGrid.build(TOPOLOGIES, [0.0], [0],
                                   num_nodes=fed.num_nodes)
            cfg = FLConfig(topology=TOPOLOGIES[0], num_nodes=fed.num_nodes,
                           comm_batch=7, rounds=scale.rounds)
            tr = GluADFL(model, adam(2e-3), cfg)
            _, hists, _ = tr.train_sweep(
                fed.x, fed.y, fed.counts, grid=grid,
                batch_size=scale.batch_size, eval_every=eval_every,
                eval_fn=val_rmse, val_data=(vx, vy_raw),
            )
            for (topo, _, _), hist in zip(grid.labels, hists):
                out[ds][topo] = [(h["round"], h["val_rmse"])
                                 for h in hist if "val_rmse" in h]

        for topo in TOPOLOGIES:
            print(f"[{ds:11s}] {topo:8s} final val RMSE "
                  f"{out[ds][topo][-1][1]:.2f}")
        finals = {t: out[ds][t][-1][1] for t in TOPOLOGIES}
        order = sorted(finals, key=finals.get)
        print(f"[{ds:11s}] convergence order: {' < '.join(order)} "
              "(paper: random < cluster < ring)")
    save_json("fig4_topology", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serial", action="store_true",
                    help="one-config-at-a-time parity fallback instead "
                         "of the batched train_sweep path")
    args = ap.parse_args()
    run(serial=args.serial)
