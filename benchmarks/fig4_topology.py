"""Figure 4: convergence of GluADFL under ring / cluster / random
topologies (B=7), per dataset — validation RMSE vs communication round."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, Scale, load, save_json
from repro.config import FLConfig
from repro.core import GluADFL
from repro.models import LSTMModel
from repro.optim import adam

TOPOLOGIES = ["ring", "cluster", "random"]


def run(scale: Scale | None = None, datasets=None, eval_every: int = 10) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    out = {}
    for ds in datasets:
        fed = load(ds, scale)
        model = LSTMModel(hidden=scale.hidden).as_model()
        vx = np.concatenate([p.val_x for p in fed.patients])
        vy_raw = np.concatenate([(p.val_y * fed.sd + fed.mean) for p in fed.patients])

        # traceable (mg/dL) val RMSE: runs INSIDE the scanned chunk via
        # the streaming-eval branch — no per-round host sync
        def val_rmse(params, val_x, val_y):
            pred = model.apply(params, val_x) * fed.sd + fed.mean
            return {"val_rmse": jnp.sqrt(jnp.mean(jnp.square(pred - val_y)))}

        out[ds] = {}
        for topo in TOPOLOGIES:
            cfg = FLConfig(topology=topo, num_nodes=fed.num_nodes, comm_batch=7,
                           rounds=scale.rounds)
            tr = GluADFL(model, adam(2e-3), cfg)
            _, hist, _ = tr.train(
                jax.random.PRNGKey(0), fed.x, fed.y, fed.counts,
                batch_size=scale.batch_size, eval_every=eval_every,
                eval_fn=val_rmse, val_data=(vx, vy_raw),
            )
            curve = [(h["round"], h["val_rmse"]) for h in hist if "val_rmse" in h]
            out[ds][topo] = curve
            print(f"[{ds:11s}] {topo:8s} final val RMSE {curve[-1][1]:.2f}")
        finals = {t: out[ds][t][-1][1] for t in TOPOLOGIES}
        order = sorted(finals, key=finals.get)
        print(f"[{ds:11s}] convergence order: {' < '.join(order)} "
              "(paper: random < cluster < ring)")
    save_json("fig4_topology", out)
    return out


if __name__ == "__main__":
    run()
