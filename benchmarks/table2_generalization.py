"""Table 2: generalization of GluADFL(Random) population models —
train on each dataset, evaluate on ALL datasets (diagonal = seen
patients, off-diagonal = unseen / cross-prediction)."""
from __future__ import annotations

from benchmarks.common import DATASETS, Scale, eval_population, load, print_metric_table, save_json, train_gluadfl


def run(scale: Scale | None = None) -> dict:
    scale = scale or Scale()
    rows = {}
    for train_ds in DATASETS:
        model, pop, _, _ = train_gluadfl(train_ds, scale, topology="random")
        rows[train_ds] = {
            test_ds: eval_population(model, pop, load(test_ds, scale))
            for test_ds in DATASETS
        }
    print_metric_table("Table 2 — GluADFL(Random) population generalization", rows)
    # paper's headline check: unseen-vs-seen RMSE gap
    gaps = []
    for tr in DATASETS:
        seen = rows[tr][tr]["rmse"]
        for te in DATASETS:
            if te != tr:
                gaps.append(rows[tr][te]["rmse"] - seen)
    summary = {"rows": rows, "mean_unseen_minus_seen_rmse": float(sum(gaps) / len(gaps))}
    print(f"\nmean unseen-seen RMSE gap: {summary['mean_unseen_minus_seen_rmse']:.2f} mg/dL "
          "(paper: <=0.5 for 78% of metrics)")
    save_json("table2_generalization", summary)
    return summary


if __name__ == "__main__":
    run()
