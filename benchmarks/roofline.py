import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (harness §Roofline): per (arch x input shape),
derive the three roofline terms from compiled dry-run artifacts on the
single-pod production mesh.

Methodology (loop-corrected component lowering — see
launch/dryrun.py's note on XLA while-body cost semantics):

  * ONE layer of each kind is lowered + compiled under the production
    mesh and sharding rules, inside ``unroll_scans()`` so inner loops
    (flash-attention KV blocks, SSD chunk recurrence) are fully present
    in the HLO.  For train shapes the lowered function is
    grad(remat(layer)) — forward + recompute + backward, exactly what
    one layer costs inside the real train step.
  * The embedding + LM-head + loss path is lowered separately.
  * Totals: layer cost x num_layers x num_microbatches + head cost x
    num_microbatches (+ analytic optimizer-update bytes/FLOPs).
  * Collective bytes come from the same compiled artifacts
    (launch.dryrun.collective_schedule) with identical multipliers.

Terms (v5e constants):
    compute_s    = device_FLOPs / 197e12
    memory_s     = device_bytes_accessed / 819e9
    collective_s = device_collective_wire_bytes / 50e9

Output: experiments/roofline/<arch>__<shape>.json + a printed table.
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.arch import build_arch
from repro.arch.api import SHAPES
from repro.arch.sharding import activation_policy, data_axes, param_pspecs
from repro.config import get_arch_config, list_archs
from repro.launch.dryrun import batch_shardings, collective_schedule, decode_state_shardings
from repro.launch.mesh import make_production_mesh
from repro.nn.unroll import unroll_scans

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "roofline"

# override for §Perf microbatch-count experiments (None = B // dp_size)
TRAIN_MB_OVERRIDE: int | None = None

# attention-internal sharding pinning (§Perf H1: 22x collective win on
# 32k full-attention prefill).  False reproduces the baseline table
# (archived in experiments/roofline_baseline/).
ATTN_PIN = True


def _policy(dp, *, train: bool = False):
    if ATTN_PIN:
        return activation_policy(dp, attn_axis="model", attn_axis_size=16,
                                 attn_seq_fallback=not train)
    return activation_policy(dp)


def _cost(compiled) -> dict:
    from repro.utils.compat import cost_analysis
    c = cost_analysis(compiled)
    colls = collective_schedule(compiled.as_text())
    return {
        "flops": float(c.get("flops", 0.0)),
        "bytes": float(c.get("bytes accessed", 0.0)),
        "coll_bytes": float(colls.get("total_wire_bytes", 0.0)),
        "colls": {k: v["count"] for k, v in colls.items() if isinstance(v, dict)},
    }


def _scale(c: dict, mult: float) -> dict:
    return {
        "flops": c["flops"] * mult,
        "bytes": c["bytes"] * mult,
        "coll_bytes": c["coll_bytes"] * mult,
    }


def _add(*cs) -> dict:
    out = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    for c in cs:
        for k in out:
            out[k] += c[k]
    return out


def _param_shardings(mesh, spec_tree, *, fsdp: bool):
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    rules = param_pspecs(
        spec_tree, axis_size=mesh.shape["model"],
        fsdp_axes=dp if fsdp else (), fsdp_size=dp_size if fsdp else 1,
    )
    return jax.tree.map(lambda s: NamedSharding(mesh, s), rules,
                        is_leaf=lambda x: isinstance(x, P))


def _sds(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


# ---------------------------------------------------------------------------
# per-family component lowering
# ---------------------------------------------------------------------------


def _layer_cost(arch, shape_name: str, mesh) -> tuple[dict, float]:
    """(per-layer compiled cost, layer multiplier)."""
    cfg = arch.cfg
    sh = SHAPES[shape_name]
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    train = sh.kind == "train"
    mb = max(sh.global_batch // dp_size, 1) if train else sh.global_batch
    if train and TRAIN_MB_OVERRIDE:
        mb = TRAIN_MB_OVERRIDE
    rows = sh.global_batch // mb if train else sh.global_batch  # rows per lowered call
    seq = sh.seq_len
    dtype = jnp.dtype(cfg.dtype)

    # single-layer params (template from eval_shape of one layer)
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.arch import lm

        lp_spec = jax.eval_shape(lambda k: lm.init_layer(k, cfg), jax.random.PRNGKey(0))
        positions = jnp.arange(seq)

        def fwd(lp, x):
            out, _, _ = lm.layer_forward(x, lp, cfg, positions)
            return out

        mult = cfg.num_layers * (mb if train else 1)
    elif cfg.family == "ssm":
        from repro.nn.ssm import init_mamba2_block, mamba2_block
        from repro.arch.ssm_lm import _dims

        dims = _dims(cfg)
        lp_spec = jax.eval_shape(
            lambda k: {"mamba": init_mamba2_block(k, cfg.d_model, **dims)},
            jax.random.PRNGKey(0),
        )

        def fwd(lp, x):
            return x + mamba2_block(x, lp["mamba"], chunk=cfg.ssm_chunk, **dims)

        mult = cfg.num_layers * (mb if train else 1)
    elif cfg.family == "hybrid":
        from repro.arch import hybrid_lm

        full_spec = jax.eval_shape(
            lambda k: hybrid_lm.init_params(k, cfg), jax.random.PRNGKey(0)
        )
        lp_spec = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                               full_spec["blocks"])
        positions = jnp.arange(seq)

        def fwd(sp, x):
            out, _ = hybrid_lm._super_forward(x, sp, cfg, positions)
            return out

        mult = hybrid_lm.num_super_blocks(cfg) * (mb if train else 1)
    elif cfg.family == "encdec":
        # decoder layer dominates (encoder seq 1500 << decoder 4k/32k);
        # encoder cost added via the enc/dec layer ratio below.
        from repro.arch import encdec

        full_spec = jax.eval_shape(
            lambda k: encdec.init_params(k, cfg), jax.random.PRNGKey(0)
        )
        lp_spec = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                               full_spec["dec_layers"])
        enc_out_spec = jax.ShapeDtypeStruct((rows, cfg.encoder_seq, cfg.d_model), dtype)

        from repro.nn.layers import gelu_ffn, layer_norm

        def fwd(lp, x, enc_out):
            h = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
            x = x + encdec._mha(h, lp["self_attn"], cfg, causal=True)
            h = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
            x = x + encdec._mha(h, lp["cross_attn"], cfg, kv=enc_out, causal=False)
            h = layer_norm(x, lp["ln3"]["scale"], lp["ln3"]["bias"])
            return x + gelu_ffn(h, lp["mlp"])

        mult = cfg.num_layers * (mb if train else 1)
    else:
        raise KeyError(cfg.family)

    x_spec = jax.ShapeDtypeStruct((rows, seq, cfg.d_model), dtype)
    lp_sh = _param_shardings(mesh, lp_spec, fsdp=not train)
    x_sh = NamedSharding(mesh, P(dp, None, None) if rows % dp_size == 0 else P())

    if train:
        if cfg.family == "encdec":
            def step(lp, x, eo):
                f = jax.checkpoint(
                    lambda lp_, x_, eo_: jnp.sum(fwd(lp_, x_, eo_).astype(jnp.float32))
                )
                return jax.grad(f, argnums=(0, 1, 2))(lp, x, eo)

            eo_sh = x_sh if rows % dp_size == 0 else NamedSharding(mesh, P())
            args = (lp_spec, x_spec, enc_out_spec)
            shardings = (lp_sh, x_sh, eo_sh)
        else:
            def step(lp, x):
                f = jax.checkpoint(lambda lp_, x_: jnp.sum(fwd(lp_, x_).astype(jnp.float32)))
                return jax.grad(f, argnums=(0, 1))(lp, x)

            args = (lp_spec, x_spec)
            shardings = (lp_sh, x_sh)
    else:
        if cfg.family == "encdec":
            step = lambda lp, x, eo: fwd(lp, x, eo)
            args = (lp_spec, x_spec, enc_out_spec)
            shardings = (lp_sh, x_sh, x_sh if rows % dp_size == 0 else NamedSharding(mesh, P()))
        else:
            step = fwd
            args = (lp_spec, x_spec)
            shardings = (lp_sh, x_sh)

    with mesh, _policy(dp, train=train), unroll_scans():
        compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    return _cost(compiled), mult


def _decode_layer_cost(arch, shape_name: str, mesh) -> tuple[dict, float]:
    """One decode step's per-layer cost via the full decode_fn divided by
    L is unreliable (loop-once) — instead lower a single layer decode."""
    cfg = arch.cfg
    sh = SHAPES[shape_name]
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bsz = sh.global_batch
    dtype = jnp.dtype(cfg.dtype)
    b_shardable = bsz % dp_size == 0 and bsz >= dp_size

    # whole-model decode state; slice layer 0 for the single-layer call
    params_spec = jax.eval_shape(arch.init_params, jax.random.PRNGKey(0))
    state_spec = jax.eval_shape(
        lambda p: arch.init_decode_state(p, bsz, sh.seq_len), params_spec
    )
    state_l0 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), state_spec
    )
    state_sh_full = decode_state_shardings(mesh, state_spec)
    state_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*s.spec[1:])), state_sh_full
    )

    x_spec = jax.ShapeDtypeStruct((bsz, 1, cfg.d_model), dtype)
    x_sh = NamedSharding(mesh, P(dp, None, None) if b_shardable else P())
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        from repro.arch import lm

        lp_spec = jax.eval_shape(lambda k: lm.init_layer(k, cfg), jax.random.PRNGKey(0))

        def step(lp, cache, x, pos):
            return lm.layer_decode(x, lp, cache, cfg, pos)

        mult = cfg.num_layers
    elif cfg.family == "ssm":
        from repro.nn.ssm import init_mamba2_block, mamba2_decode
        from repro.arch.ssm_lm import _dims

        dims = _dims(cfg)
        lp_spec = jax.eval_shape(
            lambda k: init_mamba2_block(k, cfg.d_model, **dims), jax.random.PRNGKey(0)
        )

        def step(lp, st, x, pos):
            return mamba2_decode(x[:, 0, :], lp, st, **dims)

        mult = cfg.num_layers
    elif cfg.family == "hybrid":
        from repro.arch import hybrid_lm

        full_spec = jax.eval_shape(
            lambda k: hybrid_lm.init_params(k, cfg), jax.random.PRNGKey(0)
        )
        lp_spec = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                               full_spec["blocks"])

        # reuse the scan body by calling decode on a 1-super-block model
        def step(sp, st, x, pos):
            import jax.numpy as jnp_

            from repro.nn.layers import dense, rms_norm, rope, swiglu_ffn
            from repro.nn.attention import decode_attention
            from repro.nn.rglru import recurrent_block_decode

            pat = hybrid_lm._pattern(cfg)
            new_st = dict(st)
            for i, kind in enumerate(pat):
                bp = sp[i]
                h = rms_norm(x, bp["ln1_scale"], cfg.norm_eps)
                if kind == "rglru":
                    out, new_st[f"rec{i}"] = recurrent_block_decode(
                        h[:, 0, :], bp["mix"]["rec"], st[f"rec{i}"]
                    )
                    mix = out[:, None, :]
                else:
                    b = x.shape[0]
                    hh, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
                    q = dense(h, bp["mix"]["wq"]).reshape(b, 1, hh, hd)
                    k = dense(h, bp["mix"]["wk"]).reshape(b, 1, kh, hd)
                    v = dense(h, bp["mix"]["wv"]).reshape(b, 1, kh, hd)
                    q = rope(q, pos.reshape(1), cfg.rope_theta)
                    k = rope(k, pos.reshape(1), cfg.rope_theta)
                    cache = st[f"kv{i}"].append(k, v)
                    attn = decode_attention(q, cache, window=cfg.local_attn_window)
                    new_st[f"kv{i}"] = cache
                    mix = dense(attn.reshape(b, 1, -1), bp["mix"]["wo"])
                x = x + mix
                h = rms_norm(x, bp["ln2_scale"], cfg.norm_eps)
                x = x + swiglu_ffn(h, bp["mlp"])
            return x, new_st

        mult = hybrid_lm.num_super_blocks(cfg)
    elif cfg.family == "encdec":
        from repro.arch import encdec
        from repro.nn.attention import decode_attention, plain_attention
        from repro.nn.layers import dense, gelu_ffn, layer_norm

        full_spec = jax.eval_shape(
            lambda k: encdec.init_params(k, cfg), jax.random.PRNGKey(0)
        )
        lp_spec = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                               full_spec["dec_layers"])

        def step(lp, st, x, pos):
            b = x.shape[0]
            h, hd = cfg.num_heads, cfg.head_dim
            hst = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
            q = dense(hst, lp["self_attn"]["wq"], lp["self_attn"]["bq"]).reshape(b, 1, h, hd)
            k = dense(hst, lp["self_attn"]["wk"]).reshape(b, 1, h, hd)
            v = dense(hst, lp["self_attn"]["wv"], lp["self_attn"]["bv"]).reshape(b, 1, h, hd)
            cache = st["self"].append(k, v)
            attn = decode_attention(q, cache)
            x = x + dense(attn.reshape(b, 1, -1), lp["self_attn"]["wo"], lp["self_attn"]["bo"])
            hst = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
            qc = dense(hst, lp["cross_attn"]["wq"], lp["cross_attn"]["bq"]).reshape(b, 1, h, hd)
            cattn = plain_attention(qc, st["cross"]["k"], st["cross"]["v"], causal=False)
            x = x + dense(cattn.reshape(b, 1, -1), lp["cross_attn"]["wo"], lp["cross_attn"]["bo"])
            hst = layer_norm(x, lp["ln3"]["scale"], lp["ln3"]["bias"])
            return x + gelu_ffn(hst, lp["mlp"]), cache

        state_l0 = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
            {"self": state_spec["self"], "cross": state_spec["cross"]},
        )
        state_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*s.spec[1:])),
            {"self": state_sh_full["self"], "cross": state_sh_full["cross"]},
        )
        mult = cfg.num_layers
    else:
        raise KeyError(cfg.family)

    lp_sh = _param_shardings(mesh, lp_spec, fsdp=True)
    with mesh, _policy(dp), unroll_scans():
        compiled = (
            jax.jit(step, in_shardings=(lp_sh, state_sh, x_sh, NamedSharding(mesh, P())))
            .lower(lp_spec, state_l0, x_spec, pos)
            .compile()
        )
    return _cost(compiled), mult


def _head_cost(arch, shape_name: str, mesh) -> dict:
    """Embedding + LM head + loss (train) or head only (serve)."""
    cfg = arch.cfg
    sh = SHAPES[shape_name]
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    train = sh.kind == "train"
    mb = max(sh.global_batch // dp_size, 1) if train else 1
    if train and TRAIN_MB_OVERRIDE:
        mb = TRAIN_MB_OVERRIDE
    rows = sh.global_batch // mb if train else sh.global_batch
    seq = 1 if sh.kind == "decode" else sh.seq_len
    dtype = jnp.dtype(cfg.dtype)
    from repro.nn.layers import pad_vocab

    vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    emb_spec = {
        "embed": jax.ShapeDtypeStruct((vp, d), jnp.float32 if train else dtype),
        "lm_head": jax.ShapeDtypeStruct((d, vp), jnp.float32 if train else dtype),
    }
    emb_sh = _param_shardings(mesh, emb_spec, fsdp=not train)
    tok_spec = jax.ShapeDtypeStruct((rows, seq), jnp.int32)
    b_shardable = rows % dp_size == 0 and rows >= dp_size
    tok_sh = NamedSharding(mesh, P(dp, None) if b_shardable else P())

    from repro.arch.common import cross_entropy

    if train:
        def head(p, tokens, labels):
            x = jnp.take(p["embed"], tokens, axis=0).astype(dtype)
            # stand-in residual: embedding feeds the head directly; the
            # layer stack cost is accounted separately
            logits = x @ p["lm_head"].astype(x.dtype)
            return cross_entropy(logits, labels)

        fn = jax.grad(head, argnums=0)
        args = (emb_spec, tok_spec, tok_spec)
        shardings = (emb_sh, tok_sh, tok_sh)
        mult = mb
    else:
        def head(p, tokens):
            x = jnp.take(p["embed"], tokens, axis=0).astype(dtype)
            return x @ p["lm_head"].astype(x.dtype)

        fn = head
        args = (emb_spec, tok_spec)
        shardings = (emb_sh, tok_sh)
        mult = 1

    with mesh, _policy(dp):
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    return _scale(_cost(compiled), mult)


def analyze(arch_name: str, shape_name: str, *, save: bool = True, verbose: bool = True) -> dict:
    cfg = get_arch_config(arch_name)
    arch = build_arch(cfg)
    sh = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": "pod16x16", "status": "skipped"}
    if not arch.supports(shape_name):
        rec["reason"] = "long_500k requires sub-quadratic attention"
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=False)
    n_dev = 256
    if sh.kind == "decode":
        layer, mult = _decode_layer_cost(arch, shape_name, mesh)
    else:
        layer, mult = _layer_cost(arch, shape_name, mesh)
    head = _head_cost(arch, shape_name, mesh)
    total = _add(_scale(layer, mult), head)

    if sh.kind == "train":
        # optimizer update (analytic): adam reads p,m,v,g + writes p,m,v
        pcount_dev = cfg.param_count() / n_dev  # FSDP or TP — amortized view
        total["bytes"] += 28.0 * pcount_dev
        total["flops"] += 10.0 * pcount_dev
        # and whisper's encoder stack (decoder layer was lowered above)
        if cfg.family == "encdec":
            total = _add(total, _scale(layer, 0.35 * mult))  # enc ~1500/4096 of dec cost

    compute_s = total["flops"] / PEAK_FLOPS
    memory_s = total["bytes"] / HBM_BW
    coll_s = total["coll_bytes"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]

    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        model_flops = 6 * n_active * tokens
    elif sh.kind == "prefill":
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * tokens
    device_model_flops = model_flops / n_dev
    useful = device_model_flops / total["flops"] if total["flops"] else 0.0

    rec.update(
        status="ok",
        kind=sh.kind,
        layer_mult=mult,
        per_device={
            "flops": total["flops"], "bytes": total["bytes"],
            "collective_wire_bytes": total["coll_bytes"],
        },
        terms_s={
            "compute": compute_s, "memory": memory_s, "collective": coll_s,
        },
        dominant=dominant,
        model_flops_global=model_flops,
        useful_flop_ratio=useful,
        layer_collectives=layer["colls"],
    )
    if verbose:
        print(
            f"[{arch_name:24s} {shape_name:12s}] compute {compute_s*1e3:9.3f}ms | "
            f"memory {memory_s*1e3:9.3f}ms | collective {coll_s*1e3:9.3f}ms | "
            f"dominant={dominant:10s} | useful={useful:5.2f}"
        )
    if save:
        _save(rec)
    return rec


def _save(rec):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{rec['arch']}__{rec['shape']}.json").write_text(json.dumps(rec, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [a for a in list_archs() if a != "glucose-lstm"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    failures = []
    for a in archs:
        for s in shapes:
            try:
                analyze(a, s)
            except Exception as e:  # noqa: BLE001
                print(f"[{a} {s}] FAILED {type(e).__name__}: {e}")
                failures.append((a, s, str(e)[:200]))
    if failures:
        raise SystemExit(f"{len(failures)} roofline failures: {failures}")


if __name__ == "__main__":
    main()
