"""Bench-regression gate: compare a fresh ``rounds_per_sec.py`` run
against the committed baseline ``BENCH_rounds_per_sec.json`` and fail
(exit 1) on a >20% regression.

What is gated
-------------
CI runners are heterogeneous (the committed baseline was produced on a
different machine than the PR run), so absolute rounds/sec are noise.
The gate therefore compares MACHINE-PORTABLE ratios by default — each
engine's speedup over the loop engine measured in the SAME process:

  * per-engine ``rounds_per_sec[e] / rounds_per_sec["loop"]`` must not
    drop more than ``--threshold`` (default 0.2) below the baseline's
    ratio — this is exactly "the compiled path lost its speed";
  * ``scan_eval_relative_throughput`` (scan-eval / scan) must stay
    >= 0.9: the in-scan streaming eval is supposed to be ~free;
  * ``sweep_scan_speedup_vs_serial`` (sweep-scan / serial-sweep) must
    stay >= ``--sweep-floor`` (default 2.0): batching the ablation grid
    into one vmapped program has to actually beat running it serially.
    The two sweep rows are END-TO-END wall clock with compile time
    included, so they are deliberately EXCLUDED from the loop-ratio rule
    above (that ratio is not machine-portable for compile-bound rows)
    and gated only by this same-run speedup;
  * ``sparse_gossip_speedup_vs_dense`` (sparse-gossip-n226 /
    dense-gossip-n226, same process, same federation, only the mixing
    representation differs) must stay >= ``--sparse-floor`` (default
    0.9): the O(N·B) neighbor table must not lose to the (N, N) matrix
    at paper scale (nominal claim >= 1.0; the floor concedes 10% to
    shared-runner jitter).  The representation rows and the sparse-only
    ``sparse-gossip-10k`` / ``sparse-gossip-100k`` scaling rows (the
    latter is the sharded gather-table schedule,
    ``gossip_impl="gather"``) are wall-clock/alternate-config rows —
    excluded from the loop-ratio rule, presence-checked instead (a
    vanished row is how a scale path would quietly stop being
    measured).  The 100k row additionally pins the presence of the
    ``gather_table_memory_bytes`` record — the analytic per-device
    mixing memory of allgather vs the gather tables;
  * ``masked_gossip_overhead_vs_allgather`` (sharded-scan /
    masked-sharded-scan, same process, only ``gossip_impl`` differs)
    must stay <= ``--masked-ceiling`` (default 4.0): pairwise-masked
    secure aggregation buys privacy with C(B+1, 2) per-row PRNG mask
    draws per round — measured ~3x at bench scale, where the model is
    small enough that mask generation dominates the round — and this
    caps what that costs relative to the allgather row it is
    bitwise-equal to.  The masked row itself is excluded from
    the loop-ratio rule (its cost is owned by this same-run ceiling)
    but presence-checked like the other special rows;
  * ``table4_batched_speedup_vs_serial`` (table4-batched /
    table4-serial-loops: the Table-4 trainable-baseline grid — FedAvg,
    MAML, MetaSGD, supervised LSTM — on the chunked scan engines vs the
    per-round ``engine="loop"`` oracles, same run, warm steady state)
    must stay >= ``--table4-floor`` (default 1.5): batching each
    method's whole round budget into one compiled execution has to
    actually beat dispatching round-by-round.  The pair runs a
    different workload than the GluADFL engine rows, so it is excluded
    from the loop-ratio rule and presence-checked like the rows above.

``--absolute`` additionally gates raw rounds/sec (same-machine
comparisons, e.g. a perf bisect on one box).

The serving gate (``--serve-only``)
-----------------------------------
The serve CI job gates ``benchmarks/serve_latency.py`` output against
the committed ``BENCH_serve.json`` — a SEPARATE report with its own
rules (latencies are wall clock, never ratio-gated):

  * every per-bucket row in the baseline must be PRESENT in the fresh
    run (a vanished bucket row is how a configured batch shape would
    quietly stop being measured);
  * ``personalize_batch_speedup_vs_serial`` (one scan+vmap-batched
    cold-start program vs the historical per-patient loop, same run)
    must stay >= ``--personalize-floor`` (default 2.0) — the serving
    tentpole's acceptance criterion;
  * ``bucket_batching_gain`` (forecasts/sec at the largest bucket over
    the smallest, same run) must stay >= ``--batching-floor`` (default
    1.0): batching requests must never LOSE to serving them one at a
    time.

``--serve-only`` checks only the serve report (the serve job);
the default invocation checks only the training report (the bench
job) — the two jobs own their own baselines.

Usage:
    python benchmarks/check_bench_regression.py \
        [--fresh experiments/paper/rounds_per_sec.json] \
        [--baseline BENCH_rounds_per_sec.json] \
        [--threshold 0.2] [--absolute] [--update]
    python benchmarks/check_bench_regression.py --serve-only \
        [--serve-fresh experiments/paper/serve_latency.json] \
        [--serve-baseline BENCH_serve.json] \
        [--personalize-floor 2.0] [--batching-floor 1.0] [--update]

``--update`` rewrites the checked baseline from the fresh run (for
deliberate re-baselining commits) instead of checking.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
# acceptance target: scan-eval within 10% of scan.  Ratios are best-of-3
# in one process, but shared CI runners still jitter — if a green-history
# runner class starts flaking here with no code change, loosen via
# --eval-floor (and/or --threshold) in the workflow rather than deleting
# the gate.
DEFAULT_EVAL_FLOOR = 0.9
# acceptance target: the batched sweep engine >= 2x the serial sweep at
# bench scale (same jitter caveat as above applies)
DEFAULT_SWEEP_FLOOR = 2.0
# acceptance target: the sparse gossip representation never slower than
# dense at N=226 — nominally >= 1.0, gated at 0.9 for runner jitter
DEFAULT_SPARSE_FLOOR = 0.9
# acceptance target: one batched cold-start program >= 2x the historical
# per-patient personalization loop at a 16-patient cohort
DEFAULT_PERSONALIZE_FLOOR = 2.0
# acceptance target: the Table-4 trainable-baseline grid on the chunked
# scan engines (<= 4 compiled executions) >= 1.5x the serial per-round
# loops, same run, end-to-end wall clock
DEFAULT_TABLE4_FLOOR = 1.5
# acceptance target: batched forecasting never loses to one-at-a-time
DEFAULT_BATCHING_FLOOR = 1.0
# acceptance ceiling: masked (secure-aggregation) gossip at most 4x the
# allgather row it is bitwise-equal to, measured in the same run — the
# committed baseline sits ~3x (mask generation is C(B+1,2) normal draws
# per row per round; the bench model is small enough that it dominates)
DEFAULT_MASKED_CEILING = 4.0


# wall-clock rows (compile time included by design) — their ratio to the
# steady-state loop row is NOT machine-portable (a faster-executing
# runner inflates loop rps without touching compile-bound rows), so they
# are excluded from the loop-ratio rule and gated by the same-run
# sweep_scan_speedup_vs_serial floor plus a presence check (a baseline
# wall-clock row silently vanishing from the fresh run must fail —
# that is how a benched engine path quietly stops being measured)
WALL_CLOCK_ROWS = ("serial-sweep", "sweep-scan", "sweep-sharded-psum")

# rows gated by a same-run floor / presence instead of the loop ratio:
# the representation pair runs a different model width than the engine
# rows (their loop ratio would compare apples to oranges) and the 10k /
# 100k rows are compile-included wall clock by design (the 100k row is
# the sharded gather-table schedule, gossip_impl="gather")
SPARSE_ROWS = ("dense-gossip-n226", "sparse-gossip-n226", "sparse-gossip-10k",
               "sparse-gossip-100k")

# the secure-aggregation row: its whole point is its same-run overhead
# ratio against sharded-scan (gated by --masked-ceiling), so the loop
# ratio would double-gate it; presence-checked like the rows above
MASKED_ROWS = ("masked-sharded-scan",)

# the Table-4 trainable-baseline grid pair (FedAvg + MAML + MetaSGD +
# supervised LSTM, serial per-round loops vs the chunked scan engines):
# a different workload than the GluADFL engine rows, so its loop ratio
# is apples-to-oranges — gated by the same-run --table4-floor speedup
# plus the presence rule
TABLE4_ROWS = ("table4-serial-loops", "table4-batched")


def _ratios(report: dict) -> dict[str, float]:
    rps = report["rounds_per_sec"]
    loop = rps.get("loop")
    if not loop:
        raise SystemExit("report has no loop-engine rounds/sec to normalize by")
    skip = ("loop",) + WALL_CLOCK_ROWS + SPARSE_ROWS + MASKED_ROWS + TABLE4_ROWS
    return {e: v / loop for e, v in rps.items() if e not in skip}


def check_serve(args) -> int:
    """The serving gate: bucket-row presence + the same-run
    personalization-speedup and batching-gain floors (see module
    docstring).  Latency values themselves are wall clock and never
    compared across machines."""
    fresh = json.loads(Path(args.serve_fresh).read_text())
    if args.update:
        Path(args.serve_baseline).write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"serve baseline updated -> {args.serve_baseline}")
        return 0

    base = json.loads(Path(args.serve_baseline).read_text())
    failures: list[str] = []

    for bucket in sorted(base.get("buckets", {}), key=int):
        present = bucket in fresh.get("buckets", {})
        print(f"{'bucket ' + bucket:>20s}: latency row "
              f"{'present' if present else 'MISSING'} "
              f"{'ok' if present else 'FAIL'}")
        if not present:
            failures.append(f"bucket {bucket} present in the baseline but "
                            f"missing from the fresh run")

    speedup = fresh.get("personalize_batch_speedup_vs_serial")
    if speedup is None:
        failures.append("fresh run reports no "
                        "personalize_batch_speedup_vs_serial")
    else:
        verdict = "FAIL" if speedup < args.personalize_floor else "ok"
        print(f"{'personalize batched':>20s}: {speedup:6.2f}x vs serial loop "
              f"(floor {args.personalize_floor}x) {verdict}")
        if speedup < args.personalize_floor:
            failures.append(
                f"batched personalization only {speedup:.2f}x the serial "
                f"per-patient loop (floor {args.personalize_floor}x)")

    gain = fresh.get("bucket_batching_gain")
    if gain is None:
        failures.append("fresh run reports no bucket_batching_gain")
    else:
        verdict = "FAIL" if gain < args.batching_floor else "ok"
        print(f"{'bucket batching gain':>20s}: {gain:6.2f}x "
              f"(floor {args.batching_floor}x) {verdict}")
        if gain < args.batching_floor:
            failures.append(
                f"largest-bucket throughput only {gain:.2f}x the "
                f"one-at-a-time bucket (floor {args.batching_floor}x)")

    if failures:
        print("\nSERVE BENCH GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nserve bench gate: green")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh",
                    default=str(ROOT / "experiments/paper/rounds_per_sec.json"))
    ap.add_argument("--baseline",
                    default=str(ROOT / "BENCH_rounds_per_sec.json"))
    ap.add_argument("--serve-only", action="store_true",
                    help="check the serving report instead of the "
                         "training one (the serve CI job)")
    ap.add_argument("--serve-fresh",
                    default=str(ROOT / "experiments/paper/serve_latency.json"))
    ap.add_argument("--serve-baseline",
                    default=str(ROOT / "BENCH_serve.json"))
    ap.add_argument("--personalize-floor", type=float,
                    default=DEFAULT_PERSONALIZE_FLOOR,
                    help="min allowed batched-personalization speedup "
                         "over the serial per-patient loop")
    ap.add_argument("--batching-floor", type=float,
                    default=DEFAULT_BATCHING_FLOOR,
                    help="min allowed largest/smallest-bucket "
                         "forecasts-per-sec gain")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max allowed fractional drop vs baseline")
    ap.add_argument("--eval-floor", type=float, default=DEFAULT_EVAL_FLOOR,
                    help="min allowed scan-eval/scan relative throughput")
    ap.add_argument("--sweep-floor", type=float, default=DEFAULT_SWEEP_FLOOR,
                    help="min allowed sweep-scan/serial-sweep speedup")
    ap.add_argument("--sparse-floor", type=float, default=DEFAULT_SPARSE_FLOOR,
                    help="min allowed sparse/dense gossip speedup at N=226")
    ap.add_argument("--masked-ceiling", type=float,
                    default=DEFAULT_MASKED_CEILING,
                    help="max allowed masked-gossip overhead over the "
                         "same-run allgather row")
    ap.add_argument("--table4-floor", type=float, default=DEFAULT_TABLE4_FLOOR,
                    help="min allowed table4-batched/table4-serial-loops "
                         "speedup of the baseline grid")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate raw rounds/sec (same-machine runs only)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh run")
    args = ap.parse_args(argv)

    if args.serve_only:
        return check_serve(args)

    fresh = json.loads(Path(args.fresh).read_text())
    if args.update:
        Path(args.baseline).write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"baseline updated -> {args.baseline}")
        return 0

    base = json.loads(Path(args.baseline).read_text())
    failures: list[str] = []

    # wall-clock / alternate-config rows skip the ratio rule but must
    # not silently vanish
    for row in WALL_CLOCK_ROWS + SPARSE_ROWS + MASKED_ROWS + TABLE4_ROWS:
        if row in base.get("rounds_per_sec", {}):
            present = row in fresh.get("rounds_per_sec", {})
            print(f"{row:>20s}: wall-clock row "
                  f"{'present' if present else 'MISSING'} "
                  f"{'ok' if present else 'FAIL'}")
            if not present:
                failures.append(f"wall-clock row {row!r} present in the "
                                f"baseline but missing from the fresh run")

    base_r, fresh_r = _ratios(base), _ratios(fresh)
    for engine, b in sorted(base_r.items()):
        f = fresh_r.get(engine)
        if f is None:
            failures.append(f"engine {engine!r} present in baseline but "
                            f"missing from the fresh run")
            continue
        floor = b * (1.0 - args.threshold)
        verdict = "FAIL" if f < floor else "ok"
        print(f"{engine:>20s}: speedup-vs-loop {f:6.2f}x "
              f"(baseline {b:6.2f}x, floor {floor:6.2f}x) {verdict}")
        if f < floor:
            failures.append(
                f"{engine}: speedup-vs-loop {f:.2f}x fell >"
                f"{args.threshold:.0%} below baseline {b:.2f}x")

    rel = fresh.get("scan_eval_relative_throughput")
    if rel is not None:
        verdict = "FAIL" if rel < args.eval_floor else "ok"
        print(f"{'scan-eval/scan':>20s}: {rel:6.3f} "
              f"(floor {args.eval_floor}) {verdict}")
        if rel < args.eval_floor:
            failures.append(
                f"streaming eval costs {1 - rel:.0%} of scan throughput "
                f"(floor {args.eval_floor})")

    sweep = fresh.get("sweep_scan_speedup_vs_serial")
    if sweep is not None:
        verdict = "FAIL" if sweep < args.sweep_floor else "ok"
        print(f"{'sweep-scan/serial':>20s}: {sweep:6.2f}x "
              f"(floor {args.sweep_floor}x) {verdict}")
        if sweep < args.sweep_floor:
            failures.append(
                f"batched sweep only {sweep:.2f}x the serial sweep "
                f"(floor {args.sweep_floor}x)")
    elif "sweep-scan" in base.get("rounds_per_sec", {}):
        failures.append("baseline has a sweep-scan row but the fresh run "
                        "reports no sweep_scan_speedup_vs_serial")

    sparse = fresh.get("sparse_gossip_speedup_vs_dense")
    if sparse is not None:
        verdict = "FAIL" if sparse < args.sparse_floor else "ok"
        print(f"{'sparse/dense gossip':>20s}: {sparse:6.2f}x "
              f"(floor {args.sparse_floor}x) {verdict}")
        if sparse < args.sparse_floor:
            failures.append(
                f"sparse gossip only {sparse:.2f}x the dense representation "
                f"at N=226 (floor {args.sparse_floor}x)")
    elif "sparse-gossip-n226" in base.get("rounds_per_sec", {}):
        failures.append("baseline has a sparse-gossip-n226 row but the fresh "
                        "run reports no sparse_gossip_speedup_vs_dense")

    t4 = fresh.get("table4_batched_speedup_vs_serial")
    if t4 is not None:
        verdict = "FAIL" if t4 < args.table4_floor else "ok"
        print(f"{'table4 batched/serial':>20s}: {t4:6.2f}x "
              f"(floor {args.table4_floor}x) {verdict}")
        if t4 < args.table4_floor:
            failures.append(
                f"batched Table-4 baseline grid only {t4:.2f}x the serial "
                f"per-round loops (floor {args.table4_floor}x)")
    elif "table4-batched" in base.get("rounds_per_sec", {}):
        failures.append("baseline has a table4-batched row but the fresh "
                        "run reports no table4_batched_speedup_vs_serial")

    # the 100k gather-table row ships its analytic per-device memory
    # record; a baseline that has the row but a fresh run without the
    # record means the memory claim quietly stopped being written
    if "sparse-gossip-100k" in base.get("rounds_per_sec", {}):
        mem = fresh.get("gather_table_memory_bytes")
        present = (
            isinstance(mem, dict)
            and "allgather_gathered_bytes_per_device" in mem
            and "gather_table_bytes_per_device" in mem
        )
        print(f"{'gather-table memory':>20s}: per-device record "
              f"{'present' if present else 'MISSING'} "
              f"{'ok' if present else 'FAIL'}")
        if not present:
            failures.append(
                "baseline has a sparse-gossip-100k row but the fresh run "
                "reports no gather_table_memory_bytes record")

    masked = fresh.get("masked_gossip_overhead_vs_allgather")
    if masked is not None:
        verdict = "FAIL" if masked > args.masked_ceiling else "ok"
        print(f"{'masked/allgather cost':>20s}: {masked:6.2f}x "
              f"(ceiling {args.masked_ceiling}x) {verdict}")
        if masked > args.masked_ceiling:
            failures.append(
                f"masked gossip costs {masked:.2f}x the allgather row "
                f"(ceiling {args.masked_ceiling}x)")
    elif "masked-sharded-scan" in base.get("rounds_per_sec", {}):
        failures.append("baseline has a masked-sharded-scan row but the "
                        "fresh run reports no "
                        "masked_gossip_overhead_vs_allgather")

    if args.absolute:
        for engine, b in sorted(base["rounds_per_sec"].items()):
            f = fresh["rounds_per_sec"].get(engine, 0.0)
            floor = b * (1.0 - args.threshold)
            verdict = "FAIL" if f < floor else "ok"
            print(f"{engine:>20s}: {f:8.2f} rps "
                  f"(baseline {b:8.2f}, floor {floor:8.2f}) {verdict}")
            if f < floor:
                failures.append(
                    f"{engine}: {f:.2f} rounds/sec fell >"
                    f"{args.threshold:.0%} below baseline {b:.2f}")

    if failures:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nbench regression gate: green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
