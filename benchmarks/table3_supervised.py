"""Table 3: generalization of population models trained by MIXING data
(traditional supervised learning) — the privacy-free comparator for
Table 2."""
from __future__ import annotations

from benchmarks.common import DATASETS, Scale, eval_population, load, print_metric_table, save_json, train_mixed_supervised


def run(scale: Scale | None = None) -> dict:
    scale = scale or Scale()
    rows = {}
    for train_ds in DATASETS:
        model, params, _, _ = train_mixed_supervised(train_ds, scale)
        rows[train_ds] = {
            test_ds: eval_population(model, params, load(test_ds, scale))
            for test_ds in DATASETS
        }
    print_metric_table("Table 3 — mixed-data supervised generalization", rows)
    save_json("table3_supervised", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
