"""Training-engine throughput benchmark: rounds/sec of the GluADFL hot
path under its execution strategies.

  * loop          — the per-round Python-loop DEBUG fallback: one jit
                    dispatch and one device->host ``float(loss)`` sync
                    per round;
  * scan          — ``train_chunk``: the whole chunk is ONE ``lax.scan``
                    program with donated FLState buffers, host syncs the
                    stacked losses once per chunk;
  * scan-eval     — scan engine with the in-scan streaming-eval branch
                    armed (``--eval-every``): val RMSE of the population
                    model computed under ``lax.cond`` at boundaries.
                    The claim under test: within ~10% of plain scan;
  * sharded-scan  — scan engine with ``mixer="sharded"`` (allgather
                    impl): the federation axis split over devices,
                    gossip as a real collective (needs >1 device; this
                    script forces an 8-device CPU topology when
                    XLA_FLAGS isn't already set);
  * sharded-psum-scan — same, with ``gossip_impl="psum"``: the
                    memory-scaled reduce-scatter schedule;
  * masked-sharded-scan — sharded-scan with ``gossip_impl="masked"``:
                    the pairwise-masked secure-aggregation wrapper
                    (core/secure_agg.py) on top of the allgather
                    schedule — per-round, per-edge antisymmetric masks
                    generated and cancelled (the mixed result is
                    bitwise the allgather row's, pinned by
                    tests/test_secure_agg.py).  The same-run ratio
                    sharded-scan / masked-sharded-scan prices the
                    masking (``masked_gossip_overhead_vs_allgather`` in
                    the JSON; the gate caps it at ``--masked-ceiling``);
  * serial-sweep   — the Fig-4/Fig-5 ablation shape the sweep engine
                    replaces: G (topology x inactive-ratio) scenarios
                    run one-at-a-time, each config tracing + compiling
                    its OWN scan program then executing it once.  This
                    row (and sweep-scan) is END-TO-END wall clock,
                    compiles included — each scenario runs exactly once
                    in the real workload, so there is no steady state to
                    amortize a compile into.  Rounds/sec counts ALL
                    G x rounds scenario-rounds;
  * sweep-scan    — the same G scenarios as ONE vmapped program
                    (``GluADFL.train_sweep``): stacked adjacency +
                    per-scenario ratios batched over the chunked scan —
                    one compile and one per-chunk host sync for the
                    whole grid.  The claim under test: >= 2x the serial
                    sweep's wall clock at bench scale
                    (``sweep_scan_speedup_vs_serial`` in the JSON);
  * sweep-sharded-psum — the same Fig-5 grid as ONE program on the 2-D
                    ("grid", "node") sweep mesh (``launch.mesh.
                    make_sweep_mesh``): scenarios batch over the grid
                    axis while the psum gossip collectives stay scoped
                    to the node axis — the memory-scaled sweep schedule
                    (per-device state O(G/grid · N/node · D)).  Like the
                    other sweep rows this is END-TO-END wall clock,
                    compile included, and on CPU it prices collective
                    overhead rather than a speedup — the row exists so
                    the schedule's cost stays measured and its presence
                    gated;
  * dense-gossip-n226 / sparse-gossip-n226 — the paper-scale federation
                    (N=226, REPLACE-BG) under the dense (N, N)
                    ``mixing_matrix`` representation vs the O(N·B)
                    neighbor-table one (``gossip_repr="sparse"``),
                    steady-state scan engine, everything else identical.
                    The claim under test: the sparse representation is
                    never slower at paper scale
                    (``sparse_gossip_speedup_vs_dense`` in the JSON);
  * sparse-gossip-10k — the row the dense representation CANNOT run: a
                    10 000-node ring federation, where the dense path
                    would materialize a 10k x 10k f32 matrix (400 MB)
                    per round while the neighbor table holds 10k x 3
                    entries.  Sparse-only END-TO-END wall clock (compile
                    included — population scale runs once, like the
                    sweep rows); the gate checks presence, not a ratio;
  * sparse-gossip-100k — the row even the sparse ALLGATHER schedule
                    cannot run forever: a 100 000-node ring federation
                    under ``gossip_impl="gather"`` (backend
                    ``sharded_gather_tables``), where the neighbor
                    tables AND node rows stay sharded over the node
                    mesh axis and the local row block ring-rotates via
                    ``ppermute`` — no device ever materializes the
                    gathered (N, D) federation.  END-TO-END wall clock
                    like the 10k row, presence-gated; the JSON also
                    records the analytic per-device mixing memory of
                    the allgather schedule vs the gather tables
                    (``gather_table_memory_bytes``), which is the
                    number this schedule exists to shrink;
  * table4-serial-loops / table4-batched — the Table-4 trainable-
                    baseline grid (FedAvg, MAML, MetaSGD, supervised
                    LSTM — the same four configs
                    ``benchmarks/table4_baselines.py::run_baseline_grid``
                    trains) with every method's round budget dispatched
                    per-round (``engine="loop"``: one jit dispatch + one
                    ``float(loss)`` sync per round per method) vs as ONE
                    donated chunk per method (``engine="scan"``,
                    ``chunk=rounds`` — <= 4 compiled executions for the
                    whole grid).  Warm steady state (trainers built
                    once, compiles excluded via warmup) so the same-run
                    ratio ``table4_batched_speedup_vs_serial`` isolates
                    the per-round dispatch+sync overhead the batched
                    grid removes; the gate floors it at
                    ``--table4-floor`` (default 1.5);
  * multihost-psum-scan — OPTIONAL (``--processes P``, P >= 2): the same
                    psum schedule but with the node axis spanning P REAL
                    ``jax.distributed`` processes over localhost TCP
                    (each forced to 8/P CPU devices) — this row prices
                    the cross-process hop.  Spawned as subprocesses of
                    this script; absent from the committed baseline, so
                    the regression gate ignores it.

Usage:
    PYTHONPATH=src python benchmarks/rounds_per_sec.py \
        [--nodes 32] [--rounds 64] [--hidden 16] [--batch 16] \
        [--chunk 32] [--eval-every 8] [--processes 2]

Writes experiments/paper/rounds_per_sec.json (the bench-regression gate
compares this against the committed BENCH_rounds_per_sec.json baseline —
see benchmarks/check_bench_regression.py) and prints one CSV line per
engine: ``engine,rounds_per_sec,speedup_vs_loop``.
"""
from __future__ import annotations

import os

# must precede the first jax import; harmless if the caller already set it
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import numpy as np


def synth_federation(n: int, m: int, hist_len: int, seed: int = 0):
    """Linear teacher federation — enough signal that losses stay finite,
    small enough that round time is dominated by engine overhead (the
    quantity under test), not model FLOPs."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m, hist_len)).astype(np.float32)
    w = rng.normal(size=(hist_len,)).astype(np.float32)
    y = (x @ w + 0.01 * rng.normal(size=(n, m))).astype(np.float32)
    return x, y, np.full((n,), m, np.int32)


def bench_engine(trainer, x, y, counts, *, rounds: int, batch_size: int,
                 chunk: int, engine: str, eval_every: int = 0,
                 val_data=None, reps: int = 3) -> float:
    """Returns steady-state rounds/sec: best of ``reps`` timed runs
    (compile excluded via warmup; best-of defends against noisy shared
    CPUs — the engines' ordering, not absolute numbers, is the claim)."""
    import jax
    import jax.numpy as jnp

    x, y = jnp.asarray(x), jnp.asarray(y)
    counts = jnp.asarray(counts)
    eval_kw = {}
    if eval_every and val_data is not None:
        eval_kw = dict(
            val_x=jnp.asarray(val_data[0]), val_y=jnp.asarray(val_data[1]),
            eval_every=eval_every, eval_fn=trainer._resolve_eval_fn(None),
        )

    def run(state):
        if engine == "loop":
            for _ in range(rounds):
                state, loss = trainer._round_jit(
                    state, x, y, counts, batch_size=batch_size
                )
                float(loss)  # the per-round host sync the loop engine pays
        else:
            t = 0
            while t < rounds:
                c = min(chunk, rounds - t)
                state, aux = trainer.train_chunk(
                    state, x, y, counts, batch_size=batch_size, chunk=c,
                    **eval_kw,
                )
                # one sync per chunk (losses, plus eval records if armed)
                jax.tree.map(np.asarray, aux)
                t += c
        jax.block_until_ready(state.params)

    def fresh_state(seed):
        # outside the timed region: init cost is not a property of the
        # engine (a new state per run is still required — train_chunk
        # donates its input buffers)
        state = trainer.init(jax.random.PRNGKey(seed), x[0, :1])
        jax.block_until_ready(state.params)
        return state

    run(fresh_state(0))  # warmup: compile every chunk shape
    best = 0.0
    for rep in range(reps):
        state = fresh_state(1 + rep)
        t0 = time.perf_counter()
        run(state)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


# the sweep-row scenario grid IS the paper's Fig-5 grid (3 topologies x
# 5 inactive ratios, seed 0 = 15 scenarios — exactly the workload the
# sweep engine was built to batch), sourced from its canonical home in
# config.SweepConfig.  Safe to import here: config pulls no jax, so the
# XLA_FLAGS line above still precedes the first jax import.
from repro.config import SweepConfig

SWEEP_TOPOLOGIES = SweepConfig().topologies
SWEEP_RATIOS = SweepConfig().inactive_ratios


def bench_sweep(make_trainer, x, y, counts, *, nodes: int, rounds: int,
                batch_size: int, chunk: int, reps: int = 3) -> tuple[float, float]:
    """End-to-end wall clock of the ablation grid, both ways; returns
    ``(serial_rps, sweep_rps)`` in scenario-rounds/sec (G x rounds per
    timed run).

    Unlike the steady-state engine rows above, compile time is PART of
    this measurement on purpose: it reproduces how the figure benchmarks
    actually execute the grid — every scenario config runs exactly once,
    so there is no steady state to amortize a compile into.  The serial
    path re-traces per config (the topology string and inactive ratio
    are baked into each trainer's trace), paying G compiles; the batched
    path traces the vmapped program once.  Removing those G-1 compiles
    (plus batching the execution) is precisely what the sweep engine is
    for, so the row prices it."""
    import dataclasses

    import jax

    from repro.core import SweepGrid

    grid = SweepGrid.build(SWEEP_TOPOLOGIES, SWEEP_RATIOS, (0,), num_nodes=nodes)
    g = grid.size

    def run_serial():
        # fresh trainer per scenario, exactly like the pre-sweep
        # fig4/fig5 loops: each config compiles its own chunk program
        for topo, ratio, seed in grid.labels:
            tr = make_trainer("tree")
            tr.cfg = dataclasses.replace(
                tr.cfg, topology=topo, inactive_ratio=ratio
            )
            tr.train(jax.random.PRNGKey(seed), x, y, counts,
                     batch_size=batch_size, rounds=rounds, chunk=chunk)

    def run_sweep():
        tr = make_trainer("tree")
        tr.train_sweep(x, y, counts, grid=grid, batch_size=batch_size,
                       rounds=rounds, chunk=chunk)

    serial_best = sweep_best = 0.0
    for _ in range(reps):  # fresh trainers each rep -> compiles recur
        t0 = time.perf_counter()
        run_serial()
        serial_best = max(serial_best, g * rounds / (time.perf_counter() - t0))
        t0 = time.perf_counter()
        run_sweep()
        sweep_best = max(sweep_best, g * rounds / (time.perf_counter() - t0))
    return serial_best, sweep_best


def bench_sweep_sharded(make_trainer, x, y, counts, *, nodes: int, rounds: int,
                        batch_size: int, chunk: int, reps: int = 3) -> float:
    """End-to-end wall clock of the same Fig-5 grid on the 2-D
    (grid, node) sweep mesh with the memory-scaled psum schedule —
    scenario-rounds/sec, compile included (same measurement contract as
    :func:`bench_sweep`: the grid runs exactly once in the real
    workload).  On CPU the collectives cost more than the batched
    einsum they replace; what this row buys is per-device memory
    O(G/grid · N/node · D) — the committed number prices that trade."""
    import jax

    from repro.core import SweepGrid

    grid = SweepGrid.build(SWEEP_TOPOLOGIES, SWEEP_RATIOS, (0,), num_nodes=nodes)

    def run():
        tr = make_trainer("sharded", "psum")
        tr.train_sweep(x, y, counts, grid=grid, batch_size=batch_size,
                       rounds=rounds, chunk=chunk)

    best = 0.0
    for _ in range(reps):  # fresh trainer each rep -> the compile recurs
        t0 = time.perf_counter()
        run()
        best = max(best, grid.size * rounds / (time.perf_counter() - t0))
    return best


def bench_sparse_gossip(args) -> dict:
    """The sparse-representation family: dense vs sparse at the paper's
    N=226, plus the 10k-node row only the sparse path can run.

    The N=226 pair shares one federation, model, and config — the ONLY
    difference is ``gossip_repr`` — so the ratio isolates the mixing
    representation.  The 10k row is end-to-end (compile included):
    population-scale federations run once, and its point is existence —
    the dense twin would build a 400 MB (10k, 10k) f32 matrix every
    round."""
    import jax

    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.models import LSTMModel
    from repro.optim import sgd

    n = args.sparse_nodes
    rounds = args.sparse_rounds
    cfg = FLConfig(topology="ring", num_nodes=n, rounds=rounds,
                   comm_batch=7, inactive_ratio=0.3)
    x, y, counts = synth_federation(n, 4, 12, seed=2)

    # hidden=32 (not the engine rows' 16): the parameter dimension must
    # be large enough that the O(N^2 · D) dense contraction is a real
    # share of the round, or the ratio just measures scheduler noise
    out = {}
    for name, repr_ in (("dense-gossip-n226", "dense"),
                        ("sparse-gossip-n226", "sparse")):
        tr = GluADFL(LSTMModel(hidden=args.sparse_hidden).as_model(),
                     sgd(1e-2), cfg, gossip_repr=repr_)
        out[name] = bench_engine(tr, x, y, counts, rounds=rounds,
                                 batch_size=4, chunk=rounds, engine="scan")

    nb = args.sparse_big_nodes
    if nb:
        cfg_big = FLConfig(topology="ring", num_nodes=nb, rounds=2,
                           comm_batch=7, inactive_ratio=0.2)
        xb, yb, cb = synth_federation(nb, 2, 12, seed=3)

        def run_big():
            tr = GluADFL(LSTMModel(hidden=4).as_model(), sgd(1e-2), cfg_big,
                         gossip_repr="sparse")
            tr.train(jax.random.PRNGKey(0), xb, yb, cb, batch_size=2,
                     rounds=2, chunk=2)

        t0 = time.perf_counter()
        run_big()
        out["sparse-gossip-10k"] = 2 / (time.perf_counter() - t0)

    nh = args.sparse_huge_nodes
    if nh:
        from repro.core.distributed import _default_federation_mesh

        cfg_huge = FLConfig(topology="ring", num_nodes=nh, rounds=2,
                            comm_batch=7, inactive_ratio=0.2)
        xh, yh, ch = synth_federation(nh, 2, 12, seed=4)
        model = LSTMModel(hidden=4).as_model()

        def run_huge():
            tr = GluADFL(model, sgd(1e-2), cfg_huge, mixer="sharded",
                         gossip_impl="gather", gossip_repr="sparse")
            tr.train(jax.random.PRNGKey(0), xh, yh, ch, batch_size=2,
                     rounds=2, chunk=2)

        t0 = time.perf_counter()
        run_huge()
        out["sparse-gossip-100k"] = 2 / (time.perf_counter() - t0)

        # the number the gather-table schedule exists to shrink: analytic
        # per-device bytes the MIXING step must hold resident.  allgather
        # materializes the full (N, D) federation on every device; the
        # gather tables keep the local (N/shards, D) block plus one
        # ring-rotating block of the same size in flight
        p0 = model.init(jax.random.PRNGKey(0))
        node_bytes = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(p0)
        )
        shards = _default_federation_mesh(nh).shape["node"]
        out["gather-table-memory"] = {
            "num_nodes": nh,
            "node_shards": shards,
            "param_bytes_per_node": node_bytes,
            "allgather_gathered_bytes_per_device": nh * node_bytes,
            "gather_table_bytes_per_device":
                2 * (nh // shards) * node_bytes,
        }
    return out


def bench_table4(args) -> dict:
    """The Table-4 trainable-baseline grid, serial per-round loops vs the
    chunked scan engines — the same four method configs
    ``run_baseline_grid`` trains, on the same federation.

    The trainers are built ONCE and each engine gets a warmup pass
    before timing (compiles excluded): a fresh-trainer end-to-end
    measurement is compile-dominated at any practical round budget
    (every method re-traces per construction), which would price XLA's
    compiler instead of the engines.  What the batched grid actually
    removes is the per-round dispatch + ``float(loss)`` host sync paid
    ``4 x rounds`` times by the loops — the warm ratio isolates exactly
    that.  Rows are method-rounds/sec (``4 x rounds`` per grid pass,
    best of ``--table4-reps``)."""
    import sys as _sys

    root = str(Path(__file__).resolve().parents[1])
    if root not in _sys.path:
        _sys.path.insert(0, root)
    import jax

    from benchmarks.common import Scale, load
    from repro.config import FLConfig
    from repro.core import FedAvg, MAML, MetaSGD, train_supervised
    from repro.models import LSTMModel
    from repro.optim import adam

    rounds = args.table4_rounds
    scale = Scale(fast=True, rounds=rounds, sup_steps=rounds,
                  max_patients=args.table4_patients,
                  hidden=args.table4_hidden, batch_size=args.table4_batch)
    fed = load(args.table4_dataset, scale)
    pooled_x = np.concatenate([p.train_x for p in fed.patients])
    pooled_y = np.concatenate([p.train_y for p in fed.patients])

    # the same four constructions as run_baseline_grid, built once so
    # both engines hit warm jit caches
    model = LSTMModel(hidden=scale.hidden).as_model()
    fa = FedAvg(model, adam(2e-3),
                FLConfig(num_nodes=fed.num_nodes, rounds=rounds,
                         local_steps=2, seed=0))
    metas = {"maml": MAML(model, adam(1e-3), inner_lr=1e-2, inner_steps=3),
             "metasgd": MetaSGD(model, adam(1e-3), inner_lr=1e-2,
                                inner_steps=3)}
    # one optimizer instance across passes: train_supervised's jit cache
    # is keyed on it, and each adam() call is a distinct (unequal) object
    sup_opt = adam(2e-3)

    def grid_pass(engine):
        fa.train(jax.random.PRNGKey(0), fed.x, fed.y, fed.counts,
                 batch_size=scale.batch_size, engine=engine, chunk=rounds)
        for meta in metas.values():
            meta.train(jax.random.PRNGKey(0), fed.x, fed.y, fed.counts,
                       batch_size=scale.batch_size, steps=rounds,
                       engine=engine, chunk=rounds)
        train_supervised(model, sup_opt, jax.random.PRNGKey(0),
                         pooled_x, pooled_y, steps=rounds,
                         batch_size=scale.batch_size, engine=engine,
                         chunk=rounds)

    out = {}
    for name, engine in (("table4-serial-loops", "loop"),
                         ("table4-batched", "scan")):
        grid_pass(engine)  # warmup: compile every method's program
        best = 0.0
        for _ in range(args.table4_reps):
            t0 = time.perf_counter()
            grid_pass(engine)
            best = max(best, 4 * rounds / (time.perf_counter() - t0))
        out[name] = best
    return out


def _bench_multihost_worker(args) -> None:
    """One process of the multihost row: join the localhost cluster,
    place this host's node rows, and time the psum scan engine.  Only
    process 0 prints the machine-readable MULTIHOST_RPS line."""
    from repro.launch import multihost

    multihost.initialize(
        f"127.0.0.1:{args.port}", args.processes, args.multihost_worker
    )
    import jax

    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.core.distributed import _default_federation_mesh
    from repro.models import LSTMModel
    from repro.optim import sgd

    cfg = FLConfig(topology=args.topology, num_nodes=args.nodes,
                   rounds=args.rounds, comm_batch=7)
    trainer = GluADFL(LSTMModel(hidden=args.hidden).as_model(), sgd(1e-2),
                      cfg, mixer="sharded", gossip_impl="psum")
    mesh = _default_federation_mesh(args.nodes)
    x, y, counts = synth_federation(args.nodes, args.windows, 12)
    gx, gy, gc, _ = multihost.place_federation(mesh, x, y, counts)

    def fresh_state(seed):
        # outside the timed region, like bench_engine: init cost is not
        # a property of the engine (train_chunk donates its input)
        state = trainer.init_sharded(jax.random.PRNGKey(seed), mesh)
        jax.block_until_ready(state.params)
        return state

    def run(state):
        t = 0
        while t < args.rounds:
            c = min(args.chunk, args.rounds - t)
            state, losses = trainer.train_chunk(
                state, gx, gy, gc, batch_size=args.batch, chunk=c
            )
            multihost.fetch_replicated(losses)  # the per-chunk host sync
            t += c
        jax.block_until_ready(state.params)

    run(fresh_state(0))  # warmup: compile every chunk shape
    best = 0.0
    for rep in range(3):
        state = fresh_state(1 + rep)
        multihost.barrier(f"bench_rep_{rep}")  # start reps in lockstep
        t0 = time.perf_counter()
        run(state)
        best = max(best, args.rounds / (time.perf_counter() - t0))
    if multihost.is_primary():
        print(f"MULTIHOST_RPS {best:.6f}", flush=True)
    multihost.barrier("bench_done")


def _bench_multihost(args) -> float:
    """Spawn the P-process cluster (8/P forced CPU devices each) running
    THIS script in worker mode; return process 0's rounds/sec."""
    import socket
    import subprocess
    import sys

    import jax

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    # split the PARENT's device pool (whatever XLA_FLAGS it honored)
    # across the workers so this row benches the same global device
    # count as the in-process rows it is read against
    devices = max(1, len(jax.devices()) // args.processes)
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={devices}"]
    )
    procs = [
        subprocess.Popen(
            [sys.executable, __file__,
             "--multihost-worker", str(i), "--processes", str(args.processes),
             "--port", str(port), "--nodes", str(args.nodes),
             "--rounds", str(args.rounds), "--windows", str(args.windows),
             "--hidden", str(args.hidden), "--batch", str(args.batch),
             "--chunk", str(args.chunk), "--topology", args.topology],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        )
        for i in range(args.processes)
    ]
    outs = []
    try:
        for p in procs:
            outs.append(p.communicate(timeout=1200))
    finally:
        # a crashed worker leaves its siblings blocked at the
        # distributed barrier — never orphan them
        for p in procs:
            if p.poll() is None:
                p.kill()
    for p, (out, err) in zip(procs, outs):
        if p.returncode != 0:
            raise RuntimeError(f"multihost bench worker failed:\n{err[-3000:]}")
    for line in outs[0][0].splitlines():
        if line.startswith("MULTIHOST_RPS "):
            return float(line.split()[1])
    raise RuntimeError(f"no MULTIHOST_RPS line:\n{outs[0][0][-2000:]}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--windows", type=int, default=64, help="samples per node")
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--eval-every", type=int, default=8,
                    help="streaming-eval cadence for the scan-eval row "
                         "(0 disables the row)")
    ap.add_argument("--topology", default="random")
    ap.add_argument("--sparse-nodes", type=int, default=226,
                    help="federation size for the dense-vs-sparse "
                         "gossip-representation pair (paper scale)")
    ap.add_argument("--sparse-rounds", type=int, default=8,
                    help="steady-state rounds for the representation pair")
    ap.add_argument("--sparse-hidden", type=int, default=32,
                    help="model width for the representation pair (large "
                         "enough that mixing is a real share of the round)")
    ap.add_argument("--sparse-big-nodes", type=int, default=10000,
                    help="node count for the sparse-only scaling row "
                         "(0 skips it)")
    ap.add_argument("--sparse-huge-nodes", type=int, default=100000,
                    help="node count for the sharded gather-table row "
                         "(gossip_impl='gather'; 0 skips it)")
    ap.add_argument("--table4-rounds", type=int, default=128,
                    help="rounds/steps per method for the Table-4 "
                         "baseline-grid pair (0 skips both rows)")
    ap.add_argument("--table4-hidden", type=int, default=8,
                    help="model width for the Table-4 grid pair")
    ap.add_argument("--table4-patients", type=int, default=4,
                    help="patients (fast synth cohort) for the grid pair")
    ap.add_argument("--table4-batch", type=int, default=8,
                    help="batch size for the grid pair")
    ap.add_argument("--table4-dataset", default="ohiot1dm",
                    help="dataset for the grid pair (fast synth cohort)")
    ap.add_argument("--table4-reps", type=int, default=3,
                    help="timed grid passes per engine (best-of, filters "
                         "scheduler spikes on busy CI runners)")
    ap.add_argument("--processes", type=int, default=0,
                    help="add the multihost-psum-scan row: split the node "
                         "axis over this many REAL jax.distributed "
                         "processes (0 = skip the row)")
    ap.add_argument("--multihost-worker", type=int, default=None,
                    help=argparse.SUPPRESS)  # internal: worker process id
    ap.add_argument("--port", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.multihost_worker is not None:
        _bench_multihost_worker(args)
        return None

    import jax

    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.models import LSTMModel
    from repro.optim import sgd

    print(f"devices={len(jax.devices())} nodes={args.nodes} rounds={args.rounds} "
          f"chunk={args.chunk} hidden={args.hidden} eval_every={args.eval_every}")

    cfg = FLConfig(topology=args.topology, num_nodes=args.nodes,
                   rounds=args.rounds, comm_batch=7)
    x, y, counts = synth_federation(args.nodes, args.windows, 12)
    rng = np.random.default_rng(1)
    val_x = rng.normal(size=(128, 12)).astype(np.float32)
    val_y = rng.normal(size=(128,)).astype(np.float32)

    def make(mixer, gossip_impl="allgather"):
        return GluADFL(LSTMModel(hidden=args.hidden).as_model(), sgd(1e-2),
                       cfg, mixer=mixer, gossip_impl=gossip_impl)

    cases = [
        ("loop", "tree", "allgather", "loop", 0),
        ("scan", "tree", "allgather", "scan", 0),
        ("sharded-scan", "sharded", "allgather", "scan", 0),
        ("sharded-psum-scan", "sharded", "psum", "scan", 0),
        ("masked-sharded-scan", "sharded", "masked", "scan", 0),
    ]
    if args.eval_every:
        cases.insert(2, ("scan-eval", "tree", "allgather", "scan", args.eval_every))

    results = {}
    for name, mixer, impl, engine, eval_every in cases:
        rps = bench_engine(make(mixer, impl), x, y, counts, rounds=args.rounds,
                           batch_size=args.batch, chunk=args.chunk,
                           engine=engine, eval_every=eval_every,
                           val_data=(val_x, val_y))
        results[name] = rps

    # the scenario-sweep rows: G ablation configs serial vs one vmapped
    # program (rounds/sec here counts scenario-rounds, G x rounds per run)
    serial_rps, sweep_rps = bench_sweep(
        make, x, y, counts, nodes=args.nodes, rounds=args.rounds,
        batch_size=args.batch, chunk=args.chunk,
    )
    results["serial-sweep"] = serial_rps
    results["sweep-scan"] = sweep_rps
    results["sweep-sharded-psum"] = bench_sweep_sharded(
        make, x, y, counts, nodes=args.nodes, rounds=args.rounds,
        batch_size=args.batch, chunk=args.chunk,
    )

    sparse_rows = bench_sparse_gossip(args)
    gather_memory = sparse_rows.pop("gather-table-memory", None)
    results.update(sparse_rows)

    if args.table4_rounds:
        results.update(bench_table4(args))

    if args.processes and args.processes >= 2:
        results["multihost-psum-scan"] = _bench_multihost(args)

    out = {"config": vars(args), "devices": len(jax.devices()),
           "rounds_per_sec": results,
           "scan_speedup_vs_loop": results["scan"] / results["loop"],
           # batching the ablation grid must beat running it serially:
           # acceptance target >= 2x at bench scale
           "sweep_scan_speedup_vs_serial": sweep_rps / serial_rps,
           # the O(N·B) representation must never lose to the (N, N)
           # matrix at paper scale: acceptance target >= the gate's
           # --sparse-floor (1.0 nominal, 0.9 gated for CPU noise)
           "sparse_gossip_speedup_vs_dense":
               results["sparse-gossip-n226"] / results["dense-gossip-n226"],
           # what masking costs, measured in the SAME process against the
           # allgather row it is bitwise-equal to: >1 = slower.  The gate
           # caps this at --masked-ceiling so mask generation can never
           # silently blow up the round
           "masked_gossip_overhead_vs_allgather":
               results["sharded-scan"] / results["masked-sharded-scan"]}
    if "scan-eval" in results:
        # streaming-eval overhead: 1.0 = free, acceptance target >= 0.9
        out["scan_eval_relative_throughput"] = results["scan-eval"] / results["scan"]
    if gather_memory is not None:
        # per-device mixing memory, analytic: what the gather-table
        # schedule buys over allgather at the 100k row's scale
        out["gather_table_memory_bytes"] = gather_memory
    if "table4-batched" in results:
        # the compiled baseline grid vs the per-round loops it demoted,
        # warm steady state: acceptance target >= the gate's
        # --table4-floor (1.5)
        out["table4_batched_speedup_vs_serial"] = (
            results["table4-batched"] / results["table4-serial-loops"])
    out_dir = Path(__file__).resolve().parents[1] / "experiments" / "paper"
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "rounds_per_sec.json").write_text(json.dumps(out, indent=2))

    for name, rps in results.items():
        print(f"{name},{rps:.2f},{rps / results['loop']:.2f}x")
    if "scan_eval_relative_throughput" in out:
        print(f"scan-eval relative throughput: "
              f"{out['scan_eval_relative_throughput']:.3f} (target >= 0.9)")
    print(f"sweep-scan speedup vs serial sweep: "
          f"{out['sweep_scan_speedup_vs_serial']:.2f}x (target >= 2)")
    print(f"sparse gossip speedup vs dense @ N={args.sparse_nodes}: "
          f"{out['sparse_gossip_speedup_vs_dense']:.2f}x (target >= 1)")
    print(f"masked gossip overhead vs allgather: "
          f"{out['masked_gossip_overhead_vs_allgather']:.2f}x (ceiling <= 4)")
    if gather_memory is not None:
        m = gather_memory
        print(f"gather-table per-device mixing memory @ N={m['num_nodes']}: "
              f"{m['gather_table_bytes_per_device'] / 2**20:.1f} MiB vs "
              f"allgather {m['allgather_gathered_bytes_per_device'] / 2**20:.1f} "
              f"MiB ({m['node_shards']} shards)")
    if "table4_batched_speedup_vs_serial" in out:
        print(f"table4 batched grid vs serial loops: "
              f"{out['table4_batched_speedup_vs_serial']:.2f}x (floor 1.5)")
    return out


if __name__ == "__main__":
    main()
