"""Serving-path latency/throughput benchmark: the BG-forecast service's
two device-side programs, priced per padded batch-size bucket.

  * per-bucket forecast rows — for each configured bucket B, a batch of
    B requests (random param-store rows x random CGM windows) runs
    through the servable's compiled ``forecast`` method back-to-back;
    the committed numbers are p50/p99 per-call latency (ms) and
    forecasts/sec.  Latencies are WALL CLOCK (machine-specific), so the
    regression gate checks bucket-row PRESENCE, not values — a vanished
    bucket row is how a configured batch shape would quietly stop being
    measured;
  * ``bucket_batching_gain`` — forecasts/sec at the largest bucket over
    the smallest: the same-run, machine-portable payoff of batching
    requests at all (dispatch amortization; the default ``batch_mode=
    "map"`` servable runs rows sequentially inside the program, so this
    gain is dispatch, not SIMD).  Acceptance target >= the gate's
    ``--batching-floor``;
  * ``personalize_batch_speedup_vs_serial`` — the tentpole claim: a
    16-patient cold-start cohort fine-tuned as ONE scan+vmap-batched
    program (``core.personalize.personalize_batch``) vs the historical
    per-patient Python loop (``personalize_loop``, one jitted step per
    iteration, re-traced per patient — exactly how personalization ran
    before the batched engine).  END-TO-END wall clock, compiles
    included on both sides (a cold-start cohort arrives once; there is
    no steady state to amortize a compile into), best-of-``--reps``.
    Acceptance target >= 2x (the gate's ``--personalize-floor``);
  * ``stream`` — the full service loop (MicroBatcher admission/timeout
    policy + padded forecasts) replaying a synthetic request stream;
    committed for the runbook's reference numbers, presence-only in the
    gate.

Usage:
    PYTHONPATH=src python benchmarks/serve_latency.py \
        [--checkpoint experiments/checkpoints/gluadfl_ohiot1dm_ring.npz] \
        [--buckets 1,4,16,64] [--cohort 16] [--steps 50] [--reps 3]

Writes experiments/paper/serve_latency.json; the serve CI job gates it
against the committed BENCH_serve.json via
``benchmarks/check_bench_regression.py --serve-only``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]


def bench_buckets(servable, buckets, *, history_len: int, calls: int,
                  reps: int, seed: int = 0) -> dict:
    """Per-bucket p50/p99 latency (ms) and forecasts/sec of the compiled
    forecast method, timed call-by-call after warmup (compiles excluded:
    serving pays them once at ``warmup()``, not per request).  Best rep
    by throughput; percentiles come from that rep's per-call samples."""
    import jax

    rng = np.random.default_rng(seed)
    servable.warmup(history_len=history_len)
    out = {}
    for b in buckets:
        rows = rng.integers(0, servable.num_rows, size=b)
        windows = rng.normal(size=(b, history_len)).astype(np.float32)
        params = servable.params_rows(rows)
        best_fps, best_lat = 0.0, None
        for _ in range(reps):
            lat = np.empty(calls)
            for i in range(calls):
                t0 = time.perf_counter()
                jax.block_until_ready(servable.forecast(params, windows))
                lat[i] = time.perf_counter() - t0
            fps = b * calls / lat.sum()
            if fps > best_fps:
                best_fps, best_lat = fps, lat
        out[str(b)] = {
            "p50_latency_ms": float(np.percentile(best_lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(best_lat, 99) * 1e3),
            "forecasts_per_sec": best_fps,
        }
    return out


def bench_personalize(model, pop, *, cohort: int, windows: int, steps: int,
                      reps: int, seed: int = 0) -> tuple[float, float]:
    """END-TO-END wall clock of cold-starting a ``cohort`` of patients:
    the historical per-patient loop vs one batched program.  Returns
    ``(serial_s, batched_s)`` (best of ``reps`` each).  Fresh jit caches
    per rep on BOTH sides — the loop re-traces per patient and the
    batched engine re-traces per rep, exactly the costs each pays when a
    cohort arrives at a cold service."""
    import jax
    import jax.numpy as jnp

    from repro.core.personalize import personalize_batch, personalize_loop
    from repro.optim import adam

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(cohort, windows, 12)).astype(np.float32)
    y = rng.normal(size=(cohort, windows)).astype(np.float32)
    counts = rng.integers(4, windows + 1, size=cohort).astype(np.int32)
    keys = jax.random.split(jax.random.PRNGKey(seed), cohort)

    serial_best = batched_best = float("inf")
    for _ in range(reps):
        opt = adam(5e-4)  # fresh optimizer object -> fresh jit caches
        t0 = time.perf_counter()
        for i in range(cohort):
            jax.block_until_ready(jax.tree.leaves(personalize_loop(
                model, opt, pop, keys[i], x[i], y[i],
                steps=steps, count=counts[i],
            ))[0])
        serial_best = min(serial_best, time.perf_counter() - t0)

        opt = adam(5e-4)
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(personalize_batch(
            model, opt, pop, keys, jnp.asarray(x), jnp.asarray(y), counts,
            steps=steps,
        ))[0])
        batched_best = min(batched_best, time.perf_counter() - t0)
    return serial_best, batched_best


def bench_stream(servable, buckets, *, history_len: int, n_requests: int,
                 seed: int = 0) -> dict:
    """The whole service loop: replay a synthetic stream through the
    MicroBatcher (real clock) and return its stats() — reference numbers
    for the runbook, presence-only in the gate."""
    from repro.serve import MicroBatcher, Request, replay

    rng = np.random.default_rng(seed)
    servable.warmup(history_len=history_len)
    batcher = MicroBatcher(buckets)
    reqs = [
        Request(rid=i, patient=int(rng.integers(0, servable.num_rows)),
                window=rng.normal(size=(history_len,)).astype(np.float32))
        for i in range(n_requests)
    ]
    replay(servable, batcher, reqs)
    return batcher.stats()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--checkpoint",
                    default=str(ROOT / "experiments/checkpoints/"
                                       "gluadfl_ohiot1dm_ring.npz"))
    ap.add_argument("--buckets", default="1,4,16,64")
    ap.add_argument("--calls", type=int, default=50,
                    help="timed forecast calls per bucket per rep")
    ap.add_argument("--cohort", type=int, default=16,
                    help="cold-start cohort size for the personalization "
                         "speedup row (the paper-claim scale is 16)")
    ap.add_argument("--windows", type=int, default=24,
                    help="padded history windows per cohort patient")
    ap.add_argument("--steps", type=int, default=50,
                    help="fine-tune steps per cohort patient")
    ap.add_argument("--requests", type=int, default=256,
                    help="synthetic stream length for the stream row")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.serve import GlucoseServable, load_population

    buckets = tuple(int(b) for b in args.buckets.split(",") if b)
    model, pop = load_population(args.checkpoint)
    servable = GlucoseServable(model, pop, buckets=buckets)
    L = 12  # the committed checkpoint's history length

    # a few personalized rows so bucket batches mix param rows like the
    # real service (store gathers are part of the priced path)
    rng = np.random.default_rng(args.seed)
    k = min(4, args.cohort)
    servable.personalize(
        [f"bench-{i}" for i in range(k)],
        jax.random.split(jax.random.PRNGKey(args.seed), k),
        rng.normal(size=(k, args.windows, L)).astype(np.float32),
        rng.normal(size=(k, args.windows)).astype(np.float32),
        np.full((k,), args.windows, np.int32),
    )

    bucket_rows = bench_buckets(servable, buckets, history_len=L,
                                calls=args.calls, reps=args.reps,
                                seed=args.seed)
    serial_s, batched_s = bench_personalize(
        model, pop, cohort=args.cohort, windows=args.windows,
        steps=args.steps, reps=args.reps, seed=args.seed,
    )
    stream = bench_stream(servable, buckets, history_len=L,
                          n_requests=args.requests, seed=args.seed)

    out = {
        "config": vars(args),
        "devices": len(jax.devices()),
        "buckets": bucket_rows,
        # same-run dispatch-amortization payoff of batching at all:
        # acceptance target >= the gate's --batching-floor
        "bucket_batching_gain": (
            bucket_rows[str(buckets[-1])]["forecasts_per_sec"]
            / bucket_rows[str(buckets[0])]["forecasts_per_sec"]
        ),
        # the tentpole claim: one batched cold-start program >= 2x the
        # historical per-patient loop at a 16-patient cohort
        "personalize_cohort": args.cohort,
        "personalize_serial_s": serial_s,
        "personalize_batched_s": batched_s,
        "personalize_batch_speedup_vs_serial": serial_s / batched_s,
        "stream": stream,
    }
    out_dir = ROOT / "experiments" / "paper"
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "serve_latency.json").write_text(json.dumps(out, indent=2))

    for b, row in bucket_rows.items():
        print(f"bucket {b:>3s}: p50 {row['p50_latency_ms']:7.2f}ms  "
              f"p99 {row['p99_latency_ms']:7.2f}ms  "
              f"{row['forecasts_per_sec']:8.0f} forecasts/sec")
    print(f"bucket batching gain ({buckets[-1]} vs {buckets[0]}): "
          f"{out['bucket_batching_gain']:.2f}x")
    print(f"personalize {args.cohort}-patient cohort: serial loop "
          f"{serial_s:.2f}s, one batched program {batched_s:.2f}s -> "
          f"{out['personalize_batch_speedup_vs_serial']:.2f}x (target >= 2)")
    print(f"stream: {stream['completed']} served, "
          f"p50 {stream['p50_latency_ms']:.2f}ms  "
          f"p99 {stream['p99_latency_ms']:.2f}ms  "
          f"{stream['forecasts_per_sec']:.0f} forecasts/sec")
    return out


if __name__ == "__main__":
    main()
