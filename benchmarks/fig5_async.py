"""Figure 5: robustness to inactive-node ratio per topology — the
paper's asynchrony/wait-free experiment (stability up to ~70% inactive,
random topology most robust).

Default path: the full (topology x inactive-ratio x seed) grid runs as
ONE batched device program via ``GluADFL.train_sweep`` — stacked
per-scenario mixing inputs, vmapped chunk scan, a couple of compiled
executions for the whole figure.  ``--serial`` (or ``run(serial=True)``)
keeps the original per-config loop as a parity fallback.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DATASETS, Scale, eval_population, load, save_json, train_gluadfl,
)
from repro.config import FLConfig, SweepConfig
from repro.core import GluADFL, SweepGrid
from repro.models import LSTMModel
from repro.optim import adam
from repro.utils.pytree import tree_index

# the canonical Fig-5 grid lives in config.SweepConfig
RATIOS = list(SweepConfig().inactive_ratios)
TOPOLOGIES = list(SweepConfig().topologies)


def _run_sweep(ds: str, scale: Scale, ratios) -> dict:
    """One train_sweep call per dataset: G = topologies x ratios x seeds
    scenarios in a single vmapped program; test-split clinical metrics
    are evaluated host-side per scenario and averaged over seeds."""
    fed = load(ds, scale)
    model = LSTMModel(hidden=scale.hidden).as_model()
    seeds = list(range(scale.seeds))
    grid = SweepGrid.build(TOPOLOGIES, ratios, seeds, num_nodes=fed.num_nodes)
    cfg = FLConfig(topology=TOPOLOGIES[0], num_nodes=fed.num_nodes,
                   comm_batch=7, rounds=scale.rounds)
    tr = GluADFL(model, adam(2e-3), cfg)
    pops, _, _ = tr.train_sweep(fed.x, fed.y, fed.counts, grid=grid,
                                batch_size=scale.batch_size)
    rmse_by = {}
    for g, (topo, ratio, _) in enumerate(grid.labels):
        m = eval_population(model, tree_index(pops, g), fed)
        rmse_by.setdefault((topo, ratio), []).append(m["rmse"])
    return {
        topo: [(r, float(np.mean(rmse_by[(topo, r)]))) for r in ratios]
        for topo in TOPOLOGIES
    }


def _run_serial(ds: str, scale: Scale, ratios) -> dict:
    """Same grid, one config at a time — iterates the same seeds as the
    sweep path so the two stay numerically comparable."""
    out = {}
    for topo in TOPOLOGIES:
        curve = []
        for r in ratios:
            vals = []
            for seed in range(scale.seeds):
                model, pop, _, fed = train_gluadfl(
                    ds, scale, topology=topo, inactive_ratio=r, seed=seed
                )
                vals.append(eval_population(model, pop, fed)["rmse"])
            curve.append((r, float(np.mean(vals))))
        out[topo] = curve
    return out


def run(scale: Scale | None = None, datasets=None, ratios=None,
        serial: bool = False) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    ratios = ratios or RATIOS
    out = {}
    for ds in datasets:
        out[ds] = (_run_serial if serial else _run_sweep)(ds, scale, ratios)
        for topo in TOPOLOGIES:
            print(f"[{ds:11s}] {topo:8s} " +
                  "  ".join(f"{r:.0%}:{v:.2f}" for r, v in out[ds][topo]))
        # stability check at 70%
        for topo in TOPOLOGIES:
            base = out[ds][topo][0][1]
            at70 = dict(out[ds][topo]).get(0.7, base)
            print(f"[{ds:11s}] {topo:8s} RMSE at 70% inactive vs active: "
                  f"{at70 - base:+.2f} mg/dL")
    save_json("fig5_async", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serial", action="store_true",
                    help="one-config-at-a-time parity fallback instead "
                         "of the batched train_sweep path")
    args = ap.parse_args()
    run(serial=args.serial)
