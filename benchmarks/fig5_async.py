"""Figure 5: robustness to inactive-node ratio per topology — the
paper's asynchrony/wait-free experiment (stability up to ~70% inactive,
random topology most robust)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DATASETS, Scale, eval_population, load, save_json, train_gluadfl

RATIOS = [0.0, 0.3, 0.5, 0.7, 0.9]
TOPOLOGIES = ["ring", "cluster", "random"]


def run(scale: Scale | None = None, datasets=None, ratios=None) -> dict:
    scale = scale or Scale()
    datasets = datasets or DATASETS
    ratios = ratios or RATIOS
    out = {}
    for ds in datasets:
        out[ds] = {}
        for topo in TOPOLOGIES:
            curve = []
            for r in ratios:
                model, pop, _, fed = train_gluadfl(
                    ds, scale, topology=topo, inactive_ratio=r
                )
                m = eval_population(model, pop, fed)
                curve.append((r, m["rmse"]))
            out[ds][topo] = curve
            print(f"[{ds:11s}] {topo:8s} " +
                  "  ".join(f"{r:.0%}:{v:.2f}" for r, v in curve))
        # stability check at 70%
        for topo in TOPOLOGIES:
            base = out[ds][topo][0][1]
            at70 = dict(out[ds][topo]).get(0.7, base)
            print(f"[{ds:11s}] {topo:8s} RMSE at 70% inactive vs active: "
                  f"{at70 - base:+.2f} mg/dL")
    save_json("fig5_async", out)
    return out


if __name__ == "__main__":
    run()
