"""Benchmark driver — one function per paper table/figure + kernel
micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full]

Paper experiments run on synthetic-twin data (DESIGN.md §5) at reduced
scale by default; --full restores paper-scale rounds (hours).
The roofline table (harness §Roofline) is produced by
``python -m benchmarks.roofline`` (512-device dry-run; summarized here
if its JSON output exists).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def _bench(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6


def bench_kernels() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import gossip_mix, lstm_cell, swa_attention
    from repro.kernels.ref import gossip_mix_ref, lstm_cell_ref, swa_attention_ref

    lines = []
    # gossip mix: federation of 226 nodes (REPLACE-BG); 10k-param slab
    # (interpret mode runs one python iteration per D-tile — sized so the
    # CPU bench stays seconds; the TPU path is compiled)
    n, d = 226, 9_984
    mix = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (n, n)), axis=-1)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    act = jnp.ones((n,))
    f_kernel = jax.jit(gossip_mix)
    f_ref = jax.jit(gossip_mix_ref)
    _, us_k = _bench(lambda: jax.block_until_ready(f_kernel(mix, w, act)))
    _, us_r = _bench(lambda: jax.block_until_ready(f_ref(mix, w, act)))
    gbs = (n * d * 4 * 2) / (us_k / 1e6) / 1e9
    lines.append(f"kernel.gossip_mix.interp,{us_k:.1f},ref_us={us_r:.1f};GBps={gbs:.2f}")

    # lstm cell: B=256 H=128 (paper's model)
    bsz, hsz = 256, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    xx = jax.random.normal(ks[0], (bsz, 1))
    hh = jax.random.normal(ks[1], (bsz, hsz))
    cc = jax.random.normal(ks[2], (bsz, hsz))
    wx = jax.random.normal(ks[3], (1, 4 * hsz))
    wh = jax.random.normal(ks[4], (hsz, 4 * hsz))
    bb = jnp.zeros((4 * hsz,))
    fk = jax.jit(lambda a, b, c2, d2, e, f: lstm_cell(a, b, c2, d2, e, f)[0])
    fr = jax.jit(lambda a, b, c2, d2, e, f: lstm_cell_ref(a, b, c2, d2, e, f)[0])
    _, us_k = _bench(lambda: jax.block_until_ready(fk(xx, hh, cc, wx, wh, bb)))
    _, us_r = _bench(lambda: jax.block_until_ready(fr(xx, hh, cc, wx, wh, bb)))
    lines.append(f"kernel.lstm_cell.interp,{us_k:.1f},ref_us={us_r:.1f}")

    # swa attention: 1x1024x4x64, window 256
    q = jax.random.normal(ks[5], (1, 1024, 4, 64))
    import functools
    fk = jax.jit(functools.partial(swa_attention, window=256))
    fr = jax.jit(functools.partial(swa_attention_ref, window=256))
    _, us_k = _bench(lambda: jax.block_until_ready(fk(q, q, q)))
    _, us_r = _bench(lambda: jax.block_until_ready(fr(q, q, q)))
    lines.append(f"kernel.swa_attention.interp,{us_k:.1f},ref_us={us_r:.1f}")
    return lines


def bench_fl_round() -> list[str]:
    """GluADFL round throughput (the paper's training loop hot path)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import Scale, load
    from repro.config import FLConfig
    from repro.core import GluADFL
    from repro.models import LSTMModel
    from repro.optim import adam

    scale = Scale()
    fed = load("ohiot1dm", scale)
    model = LSTMModel(hidden=scale.hidden).as_model()
    lines = []
    for topo in ("ring", "random"):
        cfg = FLConfig(topology=topo, num_nodes=fed.num_nodes, rounds=5, comm_batch=7)
        tr = GluADFL(model, adam(2e-3), cfg)
        state = tr.init(jax.random.PRNGKey(0), fed.x[0, :1])
        x, y, c = jnp.asarray(fed.x), jnp.asarray(fed.y), jnp.asarray(fed.counts)
        tr._round_jit(state, x, y, c, batch_size=64)  # compile
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            state, loss = tr._round_jit(state, x, y, c, batch_size=64)
        jax.block_until_ready(state.params)
        us = (time.perf_counter() - t0) / reps * 1e6
        lines.append(f"fl.gluadfl_round.{topo},{us:.0f},nodes={fed.num_nodes}")
    return lines


def summarize_roofline() -> list[str]:
    out_dir = Path(__file__).resolve().parents[1] / "experiments" / "roofline"
    lines = []
    if not out_dir.exists():
        return ["roofline.missing,0,run `python -m benchmarks.roofline`"]
    for f in sorted(out_dir.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        t = r["terms_s"]
        bound = max(t.values())
        lines.append(
            f"roofline.{r['arch']}.{r['shape']},{bound*1e6:.0f},"
            f"dominant={r['dominant']};compute_ms={t['compute']*1e3:.2f};"
            f"memory_ms={t['memory']*1e3:.2f};collective_ms={t['collective']*1e3:.2f};"
            f"useful={r['useful_flop_ratio']:.2f}"
        )
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="kernels + reduced tables")
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    args = ap.parse_args()

    from benchmarks.common import Scale

    scale = Scale.full() if args.full else Scale()
    if args.quick:
        scale = Scale(rounds=20, sup_steps=150, max_patients=6, hidden=32)

    print("name,us_per_call,derived")
    for line in bench_kernels():
        print(line)
    for line in bench_fl_round():
        print(line)

    from benchmarks import (
        fig3_personalization,
        fig4_topology,
        fig5_async,
        table2_generalization,
        table3_supervised,
        table4_baselines,
    )

    t0 = time.time()
    s2 = table2_generalization.run(scale)
    print(f"table2.generalization,{(time.time()-t0)*1e6:.0f},"
          f"mean_unseen_gap_rmse={s2['mean_unseen_minus_seen_rmse']:.3f}")

    t0 = time.time()
    table3_supervised.run(scale)
    print(f"table3.supervised,{(time.time()-t0)*1e6:.0f},ok")

    t0 = time.time()
    datasets = ["ohiot1dm", "abc4d"] if args.quick else None
    table4_baselines.run(scale, datasets=datasets)
    print(f"table4.baselines,{(time.time()-t0)*1e6:.0f},ok")

    # figures: 2 datasets by default (all 4 with --full; 1 with --quick)
    fig_ds = (["ohiot1dm"] if args.quick
              else None if args.full else ["ohiot1dm", "abc4d"])
    t0 = time.time()
    fig3_personalization.run(scale, datasets=fig_ds)
    print(f"fig3.personalization,{(time.time()-t0)*1e6:.0f},ok")

    t0 = time.time()
    fig4_topology.run(scale, datasets=fig_ds)
    print(f"fig4.topology,{(time.time()-t0)*1e6:.0f},ok")

    t0 = time.time()
    fig5_async.run(scale, datasets=fig_ds,
                   ratios=[0.0, 0.5, 0.9] if args.quick
                   else [0.0, 0.3, 0.7, 0.9] if not args.full else None)
    print(f"fig5.async,{(time.time()-t0)*1e6:.0f},ok")

    for line in summarize_roofline():
        print(line)


if __name__ == "__main__":
    main()
