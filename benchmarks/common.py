"""Shared experiment engine for the paper benchmarks (Tables 2-4,
Figures 3-5).

Scale note: the paper trains for thousands of rounds on months of CGM
per patient with an RTX 3090 Ti.  The benchmark harness runs the SAME
experiment graph on synthetic-twin data at reduced scale by default
(``--full`` restores paper-scale rounds/patients) so the whole suite
finishes on a CPU container.  Numbers are therefore comparable ACROSS
methods/topologies (the paper's claims are relative), not absolute
mg/dL matches.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import GluADFL, FedAvg, train_supervised
from repro.data import load_federated_dataset
from repro.data.pipeline import FederatedData
from repro.metrics import all_metrics
from repro.models import LSTMModel
from repro.optim import adam

DATASETS = ["ohiot1dm", "abc4d", "ctr3", "replace-bg"]

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "paper"


@dataclass
class Scale:
    """Benchmark scale knobs (fast CPU defaults vs paper-scale)."""

    fast: bool = True
    rounds: int = 50
    sup_steps: int = 350
    max_patients: int = 8
    hidden: int = 48
    batch_size: int = 64
    seeds: int = 1

    @staticmethod
    def full() -> "Scale":
        return Scale(fast=False, rounds=1000, sup_steps=5000,
                     max_patients=None, hidden=128, seeds=4)


_FED_CACHE: dict = {}


def load(dataset: str, scale: Scale) -> FederatedData:
    key = (dataset, scale.fast, scale.max_patients)
    if key not in _FED_CACHE:
        _FED_CACHE[key] = load_federated_dataset(
            dataset, fast=scale.fast, max_patients=scale.max_patients
        )
    return _FED_CACHE[key]


def eval_population(model, params, fed: FederatedData) -> dict:
    """Clinical metrics of a population model over a dataset's test split."""
    preds, ys = [], []
    for p in fed.patients:
        if len(p.test_x) == 0:
            continue
        pred = model.apply(params, jnp.asarray(p.test_x))
        preds.append(np.asarray(pred) * fed.sd + fed.mean)
        ys.append(p.test_y_raw)
    return all_metrics(np.concatenate(ys), np.concatenate(preds))


def train_gluadfl(dataset: str, scale: Scale, *, topology: str = "random",
                  inactive_ratio: float = 0.0, seed: int = 0, rounds=None):
    fed = load(dataset, scale)
    model = LSTMModel(hidden=scale.hidden).as_model()
    cfg = FLConfig(
        topology=topology, num_nodes=fed.num_nodes, comm_batch=7,
        rounds=rounds or scale.rounds, inactive_ratio=inactive_ratio, seed=seed,
    )
    tr = GluADFL(model, adam(2e-3), cfg)
    pop, hist, state = tr.train(
        jax.random.PRNGKey(seed), fed.x, fed.y, fed.counts,
        batch_size=scale.batch_size,
    )
    return model, pop, hist, fed


def train_fedavg(dataset: str, scale: Scale, *, seed: int = 0,
                 engine: str = "scan", chunk: int | None = None):
    fed = load(dataset, scale)
    model = LSTMModel(hidden=scale.hidden).as_model()
    cfg = FLConfig(num_nodes=fed.num_nodes, rounds=scale.rounds, local_steps=2, seed=seed)
    fa = FedAvg(model, adam(2e-3), cfg)
    params, hist = fa.train(
        jax.random.PRNGKey(seed), fed.x, fed.y, fed.counts,
        batch_size=scale.batch_size, engine=engine, chunk=chunk,
    )
    return model, params, hist, fed


def train_mixed_supervised(dataset: str, scale: Scale, *, model_ctor=None,
                           seed: int = 0, engine: str = "scan",
                           chunk: int | None = None):
    fed = load(dataset, scale)
    ctor = model_ctor or (lambda: LSTMModel(hidden=scale.hidden).as_model())
    model = ctor()
    x = np.concatenate([p.train_x for p in fed.patients])
    y = np.concatenate([p.train_y for p in fed.patients])
    vx = np.concatenate([p.val_x for p in fed.patients])
    vy = np.concatenate([p.val_y for p in fed.patients])
    params, hist = train_supervised(
        model, adam(2e-3), jax.random.PRNGKey(seed), x, y,
        steps=scale.sup_steps, batch_size=scale.batch_size, val=(vx, vy),
        engine=engine, chunk=chunk,
    )
    return model, params, hist, fed


def save_json(name: str, payload) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2))
    return path


def print_metric_table(title: str, rows: dict[str, dict[str, dict[str, float]]]):
    """rows: {row_label: {col_label: metrics dict}} — prints paper-style."""
    print(f"\n== {title} ==")
    cols = sorted({c for r in rows.values() for c in r})
    header = "train\\test".ljust(14) + "".join(c.rjust(13) for c in cols)
    print(header)
    for metric in ("rmse", "mard", "mae", "grmse", "time_lag"):
        print(f"-- {metric} --")
        for rl, r in rows.items():
            line = rl.ljust(14)
            for c in cols:
                v = r.get(c, {}).get(metric)
                line += (f"{v:13.2f}" if v is not None else " " * 13)
            print(line)
