"""Doc lint: every module under ``src/repro/`` must open with a module
docstring (package ``__init__.py`` files included — they are the layer
map a reader meets first).

    python tools/check_docstrings.py [--root src/repro] [--junit PATH]

Exit 0 when clean; exit 1 listing every bare module.  ``--junit`` also
writes a one-suite junit XML (one testcase per module) so CI can upload
the result like the test jobs do.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from xml.sax.saxutils import escape

ROOT = Path(__file__).resolve().parents[1]


def bare_modules(root: Path) -> tuple[list[Path], list[Path]]:
    """Returns ``(checked, bare)`` module paths under ``root``."""
    checked, bare = [], []
    for path in sorted(root.rglob("*.py")):
        checked.append(path)
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError as e:  # a broken module is also a failure
            print(f"SYNTAX ERROR: {path}: {e}", file=sys.stderr)
            bare.append(path)
            continue
        if ast.get_docstring(tree) is None:
            bare.append(path)
    return checked, bare


def write_junit(path: Path, checked: list[Path], bare: list[Path]) -> None:
    bare_set = set(bare)
    cases = []
    for mod in checked:
        name = escape(str(mod))
        if mod in bare_set:
            cases.append(
                f'<testcase name="{name}">'
                f'<failure message="missing module docstring"/></testcase>'
            )
        else:
            cases.append(f'<testcase name="{name}"/>')
    path.write_text(
        '<?xml version="1.0" encoding="utf-8"?>\n'
        f'<testsuite name="check_docstrings" tests="{len(checked)}" '
        f'failures="{len(bare)}">{"".join(cases)}</testsuite>\n'
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=str(ROOT / "src" / "repro"))
    ap.add_argument("--junit", default=None,
                    help="also write a junit XML report here")
    args = ap.parse_args(argv)

    checked, bare = bare_modules(Path(args.root))
    if args.junit:
        write_junit(Path(args.junit), checked, bare)
    if bare:
        print(f"{len(bare)}/{len(checked)} modules missing a module "
              f"docstring:", file=sys.stderr)
        for mod in bare:
            print(f"  {mod}", file=sys.stderr)
        return 1
    print(f"docstring lint: {len(checked)} modules clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
