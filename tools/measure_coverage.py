"""Stdlib line-coverage estimator for calibrating the CI coverage floor.

The CI ``coverage`` job runs pytest-cov (installed there) with a
``--cov-fail-under`` floor over ``src/repro/core`` + ``src/repro/data``.
This tool measures the same line rate with nothing but the standard
library (``sys.settrace`` + ``co_lines()``), so the floor can be
calibrated on boxes where installing pytest-cov is off the table:

    PYTHONPATH=src python tools/measure_coverage.py -- -x -q tests/test_gluadfl.py ...

Everything after ``--`` is passed to pytest verbatim.  The tracer only
pays per-line cost inside the target trees (every other frame opts out
at call time), and the denominator is the union of ``co_lines()`` over
every compiled code object in the targets — close to coverage.py's
statement set (coverage.py's AST parser additionally excludes a handful
of docstring/constant lines, so its reported rate runs a touch HIGHER
than this tool's; a floor set a few points under this measurement is
safe on both).
"""
import argparse
import os
import sys
import threading


def executable_lines(path):
    """All line numbers carrying code in ``path``, via compiled co_lines."""
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    code = compile(src, path, "exec")
    lines = set()
    stack = [code]
    code_t = type(code)
    while stack:
        co = stack.pop()
        for _start, _end, ln in co.co_lines():
            if ln is not None:
                lines.add(ln)
        for const in co.co_consts:
            if isinstance(const, code_t):
                stack.append(const)
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--targets", default="src/repro/core,src/repro/data",
        help="comma-separated source dirs to measure",
    )
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest (prefix with --)")
    args = ap.parse_args(argv)
    roots = [os.path.abspath(t) for t in args.targets.split(",") if t]
    for r in roots:
        if not os.path.isdir(r):
            raise SystemExit(f"target dir not found: {r}")

    hit = {}

    def local_trace(frame, event, arg):
        if event == "line":
            s = hit.get(frame.f_code.co_filename)
            if s is None:
                s = hit.setdefault(frame.f_code.co_filename, set())
            s.add(frame.f_lineno)
        return local_trace

    def global_trace(frame, event, arg):
        fn = frame.f_code.co_filename
        for r in roots:
            if fn.startswith(r):
                return local_trace
        return None

    import pytest  # imported before the tracer goes live

    threading.settrace(global_trace)
    sys.settrace(global_trace)
    try:
        rc = pytest.main(args.pytest_args)
    finally:
        sys.settrace(None)
        threading.settrace(None)

    total_exec = total_hit = 0
    rows = []
    for root in roots:
        for dirpath, _dirs, files in os.walk(root):
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                p = os.path.join(dirpath, f)
                ex = executable_lines(p)
                h = hit.get(p, set()) & ex
                total_exec += len(ex)
                total_hit += len(h)
                rows.append((os.path.relpath(p), len(h), len(ex)))

    width = max(len(r[0]) for r in rows) if rows else 10
    print(f"\n{'file':<{width}}  {'hit':>5} {'exec':>5}  rate")
    for name, nh, ne in rows:
        pct = 100.0 * nh / ne if ne else 100.0
        print(f"{name:<{width}}  {nh:>5} {ne:>5}  {pct:5.1f}%")
    overall = 100.0 * total_hit / total_exec if total_exec else 100.0
    print(f"{'TOTAL':<{width}}  {total_hit:>5} {total_exec:>5}  {overall:5.1f}%")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
