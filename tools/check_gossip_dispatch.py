"""Dispatch lint: no gossip-knob string dispatch in ``core/`` outside
the plan module.

The GossipPlan refactor (``core.gossip_plan``) moved every ``mixer ==
"..."`` / ``gossip_impl == "..."`` / ``gossip_repr == "..."`` decision
into one resolution step; this lint keeps the maze from growing back.
It flags, in every ``src/repro/core/*.py`` except ``gossip_plan.py``:

  * ``==`` / ``!=`` comparisons between a name or attribute called
    ``mixer`` / ``gossip_impl`` / ``gossip_repr`` / ``impl`` (any
    dotted prefix, e.g. ``self.mixer`` or ``args.gossip_repr``) and a
    string literal;
  * ``in`` / ``not in`` tests of such a name against a LITERAL tuple /
    list / set of strings.

Membership tests against NAMED registries (``impl not in GOSSIP_IMPLS``,
``impl not in _DENSE_WIRE_SCHEDULES``) are the sanctioned validation
pattern and are NOT flagged — the registry is the single source of
truth, a literal tuple is a fork of it.

    python tools/check_gossip_dispatch.py [--root src/repro/core]

Exit 0 when clean; exit 1 listing every offending comparison with file,
line, and source text.  Wired into the docs CI job next to the
docstring lint.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# knob names whose string dispatch belongs in the plan resolver.  Bare
# `impl` is included: it is the knob's spelling inside the gossip layers
KNOB_NAMES = {"mixer", "gossip_impl", "gossip_repr", "impl"}

# modules allowed to dispatch: the plan module IS the dispatcher
EXEMPT = {"gossip_plan.py"}


def _knob_name(node: ast.expr) -> str | None:
    """The trailing identifier of a Name/Attribute if it is a knob."""
    if isinstance(node, ast.Name) and node.id in KNOB_NAMES:
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in KNOB_NAMES:
        return node.attr
    return None


def _is_string_literal(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, str)


def _is_literal_string_container(node: ast.expr) -> bool:
    if not isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return False
    return bool(node.elts) and all(_is_string_literal(e) for e in node.elts)


def dispatch_sites(tree: ast.AST) -> list[ast.Compare]:
    """Every Compare node that string-dispatches on a gossip knob."""
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left, *node.comparators]
        names = [_knob_name(o) for o in operands]
        if not any(names):
            continue
        for op, right_i in zip(node.ops, range(1, len(operands))):
            left, right = operands[right_i - 1], operands[right_i]
            if isinstance(op, (ast.Eq, ast.NotEq)):
                pair = (
                    (_knob_name(left) and _is_string_literal(right))
                    or (_knob_name(right) and _is_string_literal(left))
                )
                if pair:
                    hits.append(node)
                    break
            elif isinstance(op, (ast.In, ast.NotIn)):
                if _knob_name(left) and _is_literal_string_container(right):
                    hits.append(node)
                    break
    return hits


def check(root: Path) -> list[str]:
    """Returns human-readable violation lines for every file in root."""
    violations = []
    for path in sorted(root.rglob("*.py")):
        if path.name in EXEMPT:
            continue
        src = path.read_text()
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            violations.append(f"{path}: SYNTAX ERROR: {e}")
            continue
        lines = src.splitlines()
        for node in dispatch_sites(tree):
            text = lines[node.lineno - 1].strip() if node.lineno <= len(lines) else ""
            violations.append(f"{path}:{node.lineno}: {text}")
    return violations


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(ROOT / "src" / "repro" / "core"))
    args = ap.parse_args(argv)
    violations = check(Path(args.root))
    if violations:
        print(
            "gossip-knob string dispatch outside core/gossip_plan.py "
            "(register a backend / resolve in the plan instead):",
            file=sys.stderr,
        )
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print(f"OK: no gossip-knob string dispatch under {args.root}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
